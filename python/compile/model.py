"""Layer-2 JAX models: the paper's five benchmark kernels + a Llama block.

Each workload is a jit-able function whose compute hot-spots go through the
Layer-1 Pallas kernels (flash_attention, tiled matmul). `aot.py` lowers each
of these to HLO text under `artifacts/` where the rust runtime
(rust/src/runtime/) loads and executes them via PJRT — Python never runs on
the request path.

Shapes are reduced replicas of the paper's benchmarks (§3.1):
  llama3_attention    — self-attention layer of Llama-3-8B   (GQA heads)
  deepseek_moe        — MoE layer of DeepSeek-R1             (top-2 routing)
  flux_attention      — self-attention layer of FLUX          (non-causal)
  flux_conv           — convolution layer of FLUX             (im2col + matmul)
  llama4_mlp          — MLP layer of Llama-4-Scout            (SwiGLU)
  llama_block         — one full Llama block (e2e anchor)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import flash_attention, matmul
from .kernels import ref


# ---------------------------------------------------------------------------
# Reduced shape configs. dims chosen so pallas tiles divide evenly and AOT
# compile stays fast; the rust-side search operates on the *full-size*
# workload descriptions (rust/src/workloads/), these artifacts anchor
# absolute latency + numerics.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    batch: int = 1
    heads: int = 8
    seq: int = 128
    head_dim: int = 64
    causal: bool = True

    @property
    def d_model(self) -> int:
        return self.heads * self.head_dim


LLAMA3_ATTN = AttnConfig(batch=1, heads=8, seq=128, head_dim=64, causal=True)
FLUX_ATTN = AttnConfig(batch=1, heads=8, seq=256, head_dim=64, causal=False)


def attention_layer(cfg: AttnConfig, x, wq, wk, wv, wo):
    """x:(B,S,D) -> (B,S,D); projections via the pallas matmul, core via
    the pallas flash-attention kernel."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    q = matmul(x2, wq).reshape(b, s, cfg.heads, cfg.head_dim)
    k = matmul(x2, wk).reshape(b, s, cfg.heads, cfg.head_dim)
    v = matmul(x2, wv).reshape(b, s, cfg.heads, cfg.head_dim)
    # (B,S,H,Dh) -> (B*H, S, Dh)
    def to_bh(t):
        return t.transpose(0, 2, 1, 3).reshape(b * cfg.heads, s, cfg.head_dim)
    o = flash_attention(to_bh(q), to_bh(k), to_bh(v), causal=cfg.causal)
    o = o.reshape(b, cfg.heads, s, cfg.head_dim).transpose(0, 2, 1, 3)
    o = o.reshape(b * s, d)
    return matmul(o, wo).reshape(b, s, d)


def llama3_attention(x, wq, wk, wv, wo):
    return attention_layer(LLAMA3_ATTN, x, wq, wk, wv, wo)


def flux_attention(x, wq, wk, wv, wo):
    return attention_layer(FLUX_ATTN, x, wq, wk, wv, wo)


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    tokens: int = 128
    d_model: int = 256
    d_ff: int = 512
    n_experts: int = 4
    top_k: int = 2


DEEPSEEK_MOE = MoeConfig()


def deepseek_moe(x, w_router, eg, eu, ed):
    """Dense-compute MoE (all experts evaluated, mixed by top-k gates).

    Dense evaluation keeps shapes static for AOT lowering; the rust-side
    search space still models the sparse-dispatch schedule axis.
    Expert FFNs run through the pallas matmul kernel.
    """
    cfg = DEEPSEEK_MOE
    x32 = x.astype(jnp.float32)
    logits = matmul(x32, w_router)
    top_vals, top_idx = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    mix = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], top_idx].set(gates)

    outs = []
    for e in range(cfg.n_experts):
        g = jax.nn.silu(matmul(x32, eg[e]))
        u = matmul(x32, eu[e])
        outs.append(matmul(g * u, ed[e]))
    stacked = jnp.stack(outs, axis=0)                 # (E, T, D)
    return jnp.einsum("te,etd->td", mix, stacked).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    batch: int = 1
    h: int = 32
    w: int = 32
    c_in: int = 64
    c_out: int = 128
    kh: int = 3
    kw: int = 3
    stride: int = 1


FLUX_CONV = ConvConfig()


def flux_conv(x, w):
    """NHWC conv as im2col + pallas matmul (the classic GEMM lowering)."""
    cfg = FLUX_CONV
    patches = ref.im2col_ref(x, cfg.kh, cfg.kw, cfg.stride)
    n, oh, ow, kdim = patches.shape
    flat = patches.reshape(n * oh * ow, kdim)
    w2 = w.reshape(kdim, cfg.c_out)
    out = matmul(flat, w2)
    return out.reshape(n, oh, ow, cfg.c_out)


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    tokens: int = 128
    d_model: int = 256
    d_ff: int = 1024


LLAMA4_MLP = MlpConfig()


def llama4_mlp(x, w_gate, w_up, w_down):
    """SwiGLU MLP through the pallas matmul kernel."""
    g = jax.nn.silu(matmul(x, w_gate))
    u = matmul(x, w_up)
    return matmul(g * u, w_down)


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    batch: int = 1
    heads: int = 4
    seq: int = 64
    head_dim: int = 32
    d_ff: int = 256

    @property
    def d_model(self) -> int:
        return self.heads * self.head_dim


LLAMA_BLOCK = BlockConfig()


def llama_block(x, w_attn_norm, wq, wk, wv, wo, w_mlp_norm, wg, wu, wd):
    """One pre-norm Llama decoder block: the e2e numeric anchor."""
    cfg = LLAMA_BLOCK
    acfg = AttnConfig(batch=cfg.batch, heads=cfg.heads, seq=cfg.seq,
                      head_dim=cfg.head_dim, causal=True)
    h = x + attention_layer(acfg, ref.rmsnorm_ref(x, w_attn_norm),
                            wq, wk, wv, wo)
    b, s, d = h.shape
    h2 = ref.rmsnorm_ref(h, w_mlp_norm).reshape(b * s, d)
    return h + llama4_mlp_like(h2, wg, wu, wd).reshape(b, s, d)


def llama4_mlp_like(x, wg, wu, wd):
    g = jax.nn.silu(matmul(x, wg))
    u = matmul(x, wu)
    return matmul(g * u, wd)


# ---------------------------------------------------------------------------
# Example-argument builders (shared by aot.py and the tests).
# ---------------------------------------------------------------------------

def _key(seed: int):
    return jax.random.PRNGKey(seed)


def attn_example_args(cfg: AttnConfig, seed: int = 0):
    ks = jax.random.split(_key(seed), 5)
    d = cfg.d_model
    scale = 1.0 / (d ** 0.5)
    x = jax.random.normal(ks[0], (cfg.batch, cfg.seq, d), jnp.float32)
    mk = lambda k: jax.random.normal(k, (d, d), jnp.float32) * scale
    return (x, mk(ks[1]), mk(ks[2]), mk(ks[3]), mk(ks[4]))


def moe_example_args(seed: int = 0):
    cfg = DEEPSEEK_MOE
    ks = jax.random.split(_key(seed), 5)
    s1 = 1.0 / (cfg.d_model ** 0.5)
    s2 = 1.0 / (cfg.d_ff ** 0.5)
    x = jax.random.normal(ks[0], (cfg.tokens, cfg.d_model), jnp.float32)
    w_router = jax.random.normal(ks[1], (cfg.d_model, cfg.n_experts)) * s1
    eg = jax.random.normal(ks[2], (cfg.n_experts, cfg.d_model, cfg.d_ff)) * s1
    eu = jax.random.normal(ks[3], (cfg.n_experts, cfg.d_model, cfg.d_ff)) * s1
    ed = jax.random.normal(ks[4], (cfg.n_experts, cfg.d_ff, cfg.d_model)) * s2
    return (x, w_router, eg, eu, ed)


def conv_example_args(seed: int = 0):
    cfg = FLUX_CONV
    ks = jax.random.split(_key(seed), 2)
    x = jax.random.normal(ks[0], (cfg.batch, cfg.h, cfg.w, cfg.c_in))
    w = jax.random.normal(
        ks[1], (cfg.kh, cfg.kw, cfg.c_in, cfg.c_out)) / (cfg.kh * cfg.kw * cfg.c_in) ** 0.5
    return (x, w)


def mlp_example_args(seed: int = 0):
    cfg = LLAMA4_MLP
    ks = jax.random.split(_key(seed), 4)
    s1 = 1.0 / (cfg.d_model ** 0.5)
    s2 = 1.0 / (cfg.d_ff ** 0.5)
    x = jax.random.normal(ks[0], (cfg.tokens, cfg.d_model))
    wg = jax.random.normal(ks[1], (cfg.d_model, cfg.d_ff)) * s1
    wu = jax.random.normal(ks[2], (cfg.d_model, cfg.d_ff)) * s1
    wd = jax.random.normal(ks[3], (cfg.d_ff, cfg.d_model)) * s2
    return (x, wg, wu, wd)


def block_example_args(seed: int = 0):
    cfg = LLAMA_BLOCK
    ks = jax.random.split(_key(seed), 10)
    d, f = cfg.d_model, cfg.d_ff
    s1, s2 = 1.0 / d ** 0.5, 1.0 / f ** 0.5
    x = jax.random.normal(ks[0], (cfg.batch, cfg.seq, d))
    norm1 = jnp.ones((d,), jnp.float32)
    norm2 = jnp.ones((d,), jnp.float32)
    mk = lambda k, shape, s: jax.random.normal(k, shape) * s
    return (x, norm1, mk(ks[1], (d, d), s1), mk(ks[2], (d, d), s1),
            mk(ks[3], (d, d), s1), mk(ks[4], (d, d), s1), norm2,
            mk(ks[5], (d, f), s1), mk(ks[6], (d, f), s1), mk(ks[7], (f, d), s2))


WORKLOADS = {
    "llama3_attention": (llama3_attention,
                         lambda: attn_example_args(LLAMA3_ATTN)),
    "flux_attention": (flux_attention,
                       lambda: attn_example_args(FLUX_ATTN, seed=1)),
    "deepseek_moe": (deepseek_moe, moe_example_args),
    "flux_conv": (flux_conv, conv_example_args),
    "llama4_mlp": (llama4_mlp, mlp_example_args),
    "llama_block": (llama_block, block_example_args),
}

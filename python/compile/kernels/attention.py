"""Flash-attention Pallas kernel (Layer 1).

Online-softmax attention: for each Q tile we stream K/V tiles through VMEM,
maintaining a running max and running sum so the full (seq_q, seq_kv) score
matrix never materializes. This is the TPU re-think of the CUDA
flash-attention insight: BlockSpec expresses the HBM->VMEM schedule that the
original paper expressed with threadblocks + shared memory, and the (bq, d)
x (d, bk) products target the MXU.

Grid: (batch*heads, seq_q/bq, seq_kv/bk) with the KV axis innermost so each
Q tile revisits its output block while the online-softmax state (m, l) lives
in VMEM scratch.

Runs under ``interpret=True`` on this image (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, n_kv: int, causal: bool, bq: int, bk: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)          # (bk, d)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    if causal:
        q_idx = pl.program_id(1)
        rows = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, _NEG_INF)

    m_prev = m_ref[...]                       # (bq,)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)

    p = jnp.exp(s - m_new[:, None])           # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)           # rescale of old accumulator
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)

    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == n_kv - 1)
    def _finalize():
        # Guard against fully-masked rows (l == 0 can only happen with an
        # all -inf row, which causal masking never produces for valid rows).
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = False, bq: int = 128,
                    bk: int = 128) -> jax.Array:
    """Softmax(Q K^T / sqrt(d)) V with online softmax.

    Shapes: q, k, v are (batch_heads, seq, d) -> (batch_heads, seq, d).
    Callers with separate batch/head dims reshape before/after.
    """
    bh, sq, d = q.shape
    bh2, skv, d2 = k.shape
    assert (bh, d) == (bh2, d2), "q/k shape mismatch"
    assert v.shape == k.shape, "k/v shape mismatch"
    if causal:
        assert sq == skv, "causal attention requires square score matrix"
    bq = _pick_block(sq, bq)
    bk_ = _pick_block(skv, bk)
    n_kv = skv // bk_
    grid = (bh, sq // bq, n_kv)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, n_kv=n_kv,
                          causal=causal, bq=bq, bk=bk_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max  m
            pltpu.VMEM((bq,), jnp.float32),      # running sum  l
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=True,
    )(q, k, v)

"""Tiled matmul Pallas kernel (Layer 1).

TPU-idiomatic tiling: blocks are chosen to keep the working set in VMEM and
to feed the MXU systolic array with (bm, bk) x (bk, bn) tiles whose lane
dimensions are multiples of the 128-wide MXU where shapes allow. On this
image the kernel always runs under ``interpret=True`` (CPU PJRT); the VMEM /
MXU analysis lives in DESIGN.md §Perf.

The grid walks (M/bm, N/bn, K/bk); the K axis is the innermost grid
dimension so each (i, j) output tile sees its K-partials in order and can
accumulate in place — the canonical Pallas revisiting-output pattern, which
double-buffers the A/B tiles between HBM and VMEM automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One grid step: accumulate a (bm, bk) @ (bk, bn) partial product."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # fp32 accumulation regardless of input dtype: this is the MXU contract
    # (bf16 inputs, f32 accumulate).
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (>= 1)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128) -> jax.Array:
    """C = A @ B with a Pallas tiled kernel (interpret mode).

    Shapes: a (M, K), b (K, N) -> (M, N). Block sizes are clipped to the
    largest divisors of the respective dims so odd shapes still work.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,
    )(a, b)

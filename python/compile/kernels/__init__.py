"""Layer-1 Pallas kernels (build-time only).

All kernels run under ``interpret=True`` so they lower to plain HLO that the
rust PJRT CPU client can execute (real-TPU lowering emits a Mosaic
custom-call the CPU plugin cannot run; see DESIGN.md §Hardware-Adaptation).
"""

from .attention import flash_attention
from .matmul import matmul

__all__ = ["flash_attention", "matmul"]

"""Pure-jnp oracles for the Pallas kernels and the L2 workloads.

These are the correctness ground truth: pytest asserts the Pallas kernels
and the composed models match these references to float tolerance.
No pallas, no tricks — straight jnp so the math is auditable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        jnp.promote_types(a.dtype, b.dtype))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = False) -> jax.Array:
    """Softmax(Q K^T / sqrt(d)) V over (batch_heads, seq, d)."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, skv), dtype=bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def swiglu_mlp_ref(x, w_gate, w_up, w_down):
    """Llama-style gated MLP: (silu(x W_g) * (x W_u)) W_d."""
    x32 = x.astype(jnp.float32)
    g = jax.nn.silu(x32 @ w_gate.astype(jnp.float32))
    u = x32 @ w_up.astype(jnp.float32)
    return ((g * u) @ w_down.astype(jnp.float32)).astype(x.dtype)


def moe_ref(x, w_router, experts_gate, experts_up, experts_down, *, top_k=2):
    """Dense-evaluated mixture-of-experts with softmax-of-top-k routing.

    Every expert is evaluated and the result is mixed by the (renormalized)
    top-k gate — the standard dense MoE reference used to validate sparse
    dispatch implementations.
    """
    x32 = x.astype(jnp.float32)
    logits = x32 @ w_router.astype(jnp.float32)         # (tokens, n_exp)
    top_vals, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)           # (tokens, top_k)
    mix = jnp.zeros_like(logits).at[
        jnp.arange(logits.shape[0])[:, None], top_idx].set(gates)

    def one_expert(wg, wu, wd):
        g = jax.nn.silu(x32 @ wg.astype(jnp.float32))
        u = x32 @ wu.astype(jnp.float32)
        return (g * u) @ wd.astype(jnp.float32)

    outs = jax.vmap(one_expert)(experts_gate, experts_up, experts_down)
    return jnp.einsum("te,etd->td", mix, outs).astype(x.dtype)


def conv2d_ref(x, w, *, stride=1):
    """NHWC conv with HWIO weights, VALID padding."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)


def im2col_ref(x, kh, kw, stride=1):
    """Extract conv patches: (N, OH, OW, KH*KW*C) for VALID padding."""
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                x[:, i:i + stride * oh:stride, j:j + stride * ow:stride, :])
    return jnp.concatenate(patches, axis=-1).reshape(n, oh, ow, kh * kw * c)

"""AOT pipeline: lower every L2 workload to HLO *text* under artifacts/.

HLO text — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's bundled xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``). The HLO text parser on the rust side reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts
Python runs exactly once, at build time; the rust binary is self-contained
afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_workload(name: str):
    fn, args_fn = model.WORKLOADS[name]
    args = args_fn()
    # Wrap in a 1-tuple so the rust side can always unwrap with to_tuple1().
    tupled = lambda *a: (fn(*a),)
    lowered = jax.jit(tupled).lower(*args)
    return to_hlo_text(lowered), args


def arg_manifest(args) -> list[dict]:
    return [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args]


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", nargs="*", default=None,
                   help="subset of workload names")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    names = args.only or list(model.WORKLOADS)
    for name in names:
        text, ex_args = lower_workload(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest[name] = {
            "hlo": f"{name}.hlo.txt",
            "sha256_16": digest,
            "args": arg_manifest(ex_args),
        }
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    man_path = os.path.join(args.out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")


if __name__ == "__main__":
    main()

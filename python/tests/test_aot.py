"""AOT pipeline contract: every workload lowers to parseable HLO text with
the entry signature the rust runtime expects."""

import json
import os

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", list(model.WORKLOADS))
def test_lowering_produces_hlo_text(name):
    text, args = aot.lower_workload(name)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True must yield a tuple root so rust can to_tuple1()
    assert "tuple(" in text or "tuple(" in text.lower()
    assert len(args) >= 1


def test_manifest_arg_shapes_roundtrip():
    _, args = aot.lower_workload("llama4_mlp")
    man = aot.arg_manifest(args)
    assert man[0]["shape"] == [model.LLAMA4_MLP.tokens, model.LLAMA4_MLP.d_model]
    assert all(m["dtype"] == "float32" for m in man)


def test_artifacts_dir_contents_if_built():
    """If `make artifacts` has run, the manifest must agree with disk."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built yet")
    with open(man_path) as f:
        man = json.load(f)
    for name, entry in man.items():
        hlo = os.path.join(art, entry["hlo"])
        assert os.path.exists(hlo), hlo
        with open(hlo) as f:
            head = f.read(4096)
        assert "HloModule" in head

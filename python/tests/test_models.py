"""L2 model correctness: composed workloads vs pure-jnp references, plus
shape/manifest contracts that the rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_llama3_attention_matches_ref():
    cfg = model.LLAMA3_ATTN
    x, wq, wk, wv, wo = model.attn_example_args(cfg)
    out = model.llama3_attention(x, wq, wk, wv, wo)

    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    def proj(w):
        t = ref.matmul_ref(x2, w).reshape(b, s, cfg.heads, cfg.head_dim)
        return t.transpose(0, 2, 1, 3).reshape(b * cfg.heads, s, cfg.head_dim)
    o = ref.attention_ref(proj(wq), proj(wk), proj(wv), causal=True)
    o = o.reshape(b, cfg.heads, s, cfg.head_dim).transpose(0, 2, 1, 3)
    expect = ref.matmul_ref(o.reshape(b * s, d), wo).reshape(b, s, d)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_flux_attention_not_causal():
    """Non-causal: permuting KV tokens must permute nothing in the output
    (softmax over all keys is permutation-invariant w.r.t. key order)."""
    x, wq, wk, wv, wo = model.attn_example_args(model.FLUX_ATTN, seed=1)
    out1 = model.flux_attention(x, wq, wk, wv, wo)
    assert out1.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out1)))


def test_deepseek_moe_matches_dense_ref():
    x, w_router, eg, eu, ed = model.moe_example_args()
    out = model.deepseek_moe(x, w_router, eg, eu, ed)
    expect = ref.moe_ref(x, w_router, eg, eu, ed,
                         top_k=model.DEEPSEEK_MOE.top_k)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_moe_gates_convex():
    """Top-k gate weights must be a convex combination (sum to 1)."""
    x, w_router, *_ = model.moe_example_args()
    logits = ref.matmul_ref(x, w_router)
    top_vals, _ = jax.lax.top_k(logits, model.DEEPSEEK_MOE.top_k)
    gates = jax.nn.softmax(top_vals, axis=-1)
    np.testing.assert_allclose(gates.sum(-1), np.ones(x.shape[0]), rtol=1e-6)


def test_flux_conv_matches_lax_conv():
    x, w = model.conv_example_args()
    out = model.flux_conv(x, w)
    expect = ref.conv2d_ref(x, w, stride=model.FLUX_CONV.stride)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_im2col_shapes():
    x, _ = model.conv_example_args()
    cfg = model.FLUX_CONV
    p = ref.im2col_ref(x, cfg.kh, cfg.kw, cfg.stride)
    oh = (cfg.h - cfg.kh) // cfg.stride + 1
    ow = (cfg.w - cfg.kw) // cfg.stride + 1
    assert p.shape == (cfg.batch, oh, ow, cfg.kh * cfg.kw * cfg.c_in)


def test_llama4_mlp_matches_ref():
    x, wg, wu, wd = model.mlp_example_args()
    out = model.llama4_mlp(x, wg, wu, wd)
    expect = ref.swiglu_mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_llama_block_finite_and_shaped():
    args = model.block_example_args()
    out = model.llama_block(*args)
    assert out.shape == args[0].shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_llama_block_residual_identity_weights():
    """With zero projection weights the block must be the identity
    (residual path only)."""
    args = list(model.block_example_args())
    x = args[0]
    zeroed = [args[0], args[1]] + [jnp.zeros_like(a) for a in args[2:6]] \
        + [args[6]] + [jnp.zeros_like(a) for a in args[7:]]
    out = model.llama_block(*zeroed)
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


def test_workload_registry_complete():
    assert set(model.WORKLOADS) == {
        "llama3_attention", "flux_attention", "deepseek_moe",
        "flux_conv", "llama4_mlp", "llama_block"}
    for name, (fn, args_fn) in model.WORKLOADS.items():
        args = args_fn()
        assert all(hasattr(a, "shape") for a in args), name

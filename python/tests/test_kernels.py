"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/dtypes; fixed cases pin the paper-benchmark shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, matmul
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------- matmul ---

@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (128, 128, 128),
                                   (64, 256, 32), (33, 17, 5), (1, 128, 1)])
def test_matmul_matches_ref(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a, b = rnd(k1, (m, k)), rnd(k2, (k, n))
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (32, 8, 64), (128, 128, 128)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the chosen tiling."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a, b = rnd(k1, (64, 128)), rnd(k2, (128, 32))
    base = ref.matmul_ref(a, b)
    np.testing.assert_allclose(matmul(a, b, bm=bm, bn=bn, bk=bk), base,
                               rtol=1e-5, atol=1e-5)


def test_matmul_bf16_accumulates_in_f32():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = rnd(k1, (64, 512), jnp.bfloat16)
    b = rnd(k2, (512, 64), jnp.bfloat16)
    out = matmul(a, b)
    assert out.dtype == jnp.bfloat16
    exact = jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(out.astype(jnp.float32), exact,
                               rtol=5e-2, atol=5e-1)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, b = rnd(k1, (m, k)), rnd(k2, (k, n))
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       m=st.sampled_from([16, 32, 64]), n=st.sampled_from([16, 64]))
def test_matmul_hypothesis_dtypes(dtype, m, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a, b = rnd(k1, (m, 32), dtype), rnd(k2, (32, n), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(
        matmul(a, b).astype(jnp.float32),
        ref.matmul_ref(a, b).astype(jnp.float32), rtol=tol, atol=tol)


# ------------------------------------------------------- flash attention ---

@pytest.mark.parametrize("bh,seq,d,causal", [
    (2, 64, 32, False), (2, 64, 32, True),
    (8, 128, 64, True),          # llama3 attention shape
    (8, 256, 64, False),         # flux attention shape
    (1, 128, 16, True),
])
def test_flash_attention_matches_ref(bh, seq, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rnd(kk, (bh, seq, d)) for kk in ks)
    out = flash_attention(q, k, v, causal=causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (128, 128), (64, 16)])
def test_flash_attention_block_invariance(bq, bk):
    """Online-softmax result must not depend on the KV tiling."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q, k, v = (rnd(kk, (4, 128, 32)) for kk in ks)
    base = ref.attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    np.testing.assert_allclose(out, base, rtol=2e-4, atol=2e-4)


def test_flash_attention_cross_attention_rect():
    """seq_q != seq_kv (non-causal cross attention)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = rnd(ks[0], (2, 64, 32))
    k = rnd(ks[1], (2, 192, 32))
    v = rnd(ks[2], (2, 192, 32))
    np.testing.assert_allclose(
        flash_attention(q, k, v),
        ref.attention_ref(q, k, v), rtol=2e-4, atol=2e-4)


def test_flash_attention_extreme_logits_stable():
    """Large-magnitude scores: online softmax must not overflow."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = rnd(ks[0], (1, 64, 32), scale=30.0)
    k = rnd(ks[1], (1, 64, 32), scale=30.0)
    v = rnd(ks[2], (1, 64, 32))
    out = flash_attention(q, k, v)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    bh=st.integers(1, 4),
    seq=st.sampled_from([16, 48, 64, 96, 128]),
    d=st.sampled_from([8, 16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_hypothesis(bh, seq, d, causal, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q, k, v = (rnd(kk, (bh, seq, d)) for kk in ks)
    np.testing.assert_allclose(
        flash_attention(q, k, v, causal=causal),
        ref.attention_ref(q, k, v, causal=causal), rtol=5e-4, atol=5e-4)


def test_flash_attention_rows_sum_property():
    """With v = identity-ish one-hot stack, output rows are convex combos:
    each output element must lie within [min(v), max(v)]."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q, k = rnd(ks[0], (2, 64, 16)), rnd(ks[1], (2, 64, 16))
    v = jax.random.uniform(ks[2], (2, 64, 16))
    out = flash_attention(q, k, v)
    assert float(out.min()) >= float(v.min()) - 1e-5
    assert float(out.max()) <= float(v.max()) + 1e-5

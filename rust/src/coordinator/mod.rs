//! Experiment coordinator: run specifications, a thread-pooled runner, and
//! the end-to-end (full-model) tuning driver.
//!
//! Every paper table/figure is a matrix of [`RunSpec`]s; the runner
//! executes them across OS threads (each run is independent and
//! deterministic in its seed) and the `experiments` binary assembles the
//! paper-shaped tables from the [`SearchResult`]s.

pub mod distributed;
pub mod report;
pub mod serve;

pub use distributed::{run_fleet, run_lanes, FleetOpts, FleetResult};

use crate::baselines;
use crate::mcts::evalcache::EvalCache;
use crate::mcts::{Routing, SearchConfig, SearchResult};
use crate::schedule::Schedule;
use crate::sim::Target;
use crate::workloads::scenarios::ScenarioSpec;
use crate::workloads::{self, llama_e2e::E2eGraph};
use std::sync::Arc;

/// Which searcher to run.
#[derive(Clone, Debug, PartialEq)]
pub enum Searcher {
    /// Single-LLM MCTS baseline with the given model.
    Single(String),
    /// LiteCoOp with n models under the given largest model.
    Coop { n: usize, largest: String },
    /// Appendix-G ablation: same pool, random routing.
    RandomRouting { n: usize, largest: String },
    /// Appendix-G ablation: same pool, round-robin routing.
    RoundRobinRouting { n: usize, largest: String },
    /// LLM-free evolutionary baseline.
    Evolutionary,
}

impl Searcher {
    pub fn label(&self) -> String {
        match self {
            Searcher::Single(m) => format!("Single({m})"),
            Searcher::Coop { n, .. } => format!("LiteCoOp({n} LLMs)"),
            Searcher::RandomRouting { .. } => "Random".into(),
            Searcher::RoundRobinRouting { .. } => "Round-Robin".into(),
            Searcher::Evolutionary => "Evolutionary".into(),
        }
    }
}

/// One experiment run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub workload: String,
    pub target: Target,
    pub searcher: Searcher,
    pub budget: usize,
    pub seed: u64,
    pub lambda: f64,
    /// Course-alteration threshold (None = disabled).
    pub ca_threshold: Option<usize>,
    /// In-search tree parallelism (`--search-threads`): worker threads
    /// *within* this one search, independent of the across-spec thread
    /// pool. 1 = serial engine (bit-identical to the pre-parallel
    /// engine); results are deterministic per (seed, search_threads).
    pub search_threads: usize,
    /// Warm-start evaluation cache shared into the search (see
    /// [`SearchConfig::warm_cache`]); set by the cached driver paths
    /// ([`crate::runtime::driver::run_specs_cached`]). `None` = cold.
    /// Warm entries never change the search result, only its hit rate
    /// and measurement time.
    pub warm_cache: Option<Arc<EvalCache>>,
}

impl RunSpec {
    pub fn new(workload: &str, target: Target, searcher: Searcher, budget: usize, seed: u64) -> RunSpec {
        RunSpec {
            workload: workload.to_string(),
            target,
            searcher,
            budget,
            seed,
            lambda: 0.5,
            ca_threshold: Some(2),
            search_threads: 1,
            warm_cache: None,
        }
    }

    fn config(&self) -> SearchConfig {
        SearchConfig {
            budget: self.budget,
            seed: self.seed,
            lambda: self.lambda,
            ca_threshold: self.ca_threshold,
            checkpoints: vec![50, 100, 250, 500, 750, 1000]
                .into_iter()
                .filter(|&c| c <= self.budget)
                .collect(),
            search_threads: self.search_threads,
            warm_cache: self.warm_cache.clone(),
            ..SearchConfig::default()
        }
    }
}

/// Dispatch one search according to `searcher` — the single home of the
/// searcher → baseline mapping, shared by [`run_one`], the e2e task
/// fan-out, and the warm-start driver. Every searcher, including the
/// evolutionary baseline, draws its budget/seed/checkpoints from `cfg`.
/// Also hands back the search's warmed evaluation cache
/// (`cfg.warm_cache` entries ∪ everything it measured; empty for the
/// cache-less evolutionary baseline).
fn dispatch_with_cache(
    searcher: &Searcher,
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> (SearchResult, EvalCache) {
    match searcher {
        Searcher::Single(m) => baselines::single_llm_with_cache(m, target, root, cfg, workload),
        Searcher::Coop { n, largest } => {
            baselines::litecoop_with_cache(*n, largest, target, root, cfg, workload)
        }
        Searcher::RandomRouting { n, largest } => {
            let mut cfg = cfg;
            cfg.routing = Routing::Random;
            baselines::litecoop_with_cache(*n, largest, target, root, cfg, workload)
        }
        Searcher::RoundRobinRouting { n, largest } => {
            let mut cfg = cfg;
            cfg.routing = Routing::RoundRobin;
            baselines::litecoop_with_cache(*n, largest, target, root, cfg, workload)
        }
        Searcher::Evolutionary => baselines::evolutionary_with_cache(target, root, cfg, workload),
    }
}

/// [`dispatch_with_cache`] without the warmed cache.
fn dispatch(
    searcher: &Searcher,
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    dispatch_with_cache(searcher, target, root, cfg, workload).0
}

/// Execute one run.
pub fn run_one(spec: &RunSpec) -> SearchResult {
    run_one_with_cache(spec).0
}

/// Execute one run and hand back its warmed evaluation cache (the
/// spec's warm entries ∪ everything this search measured) — the unit
/// the warm-start driver ([`crate::runtime::driver::run_specs_warm`])
/// merges and persists.
pub fn run_one_with_cache(spec: &RunSpec) -> (SearchResult, EvalCache) {
    let workload = workloads::resolve(&spec.workload)
        .unwrap_or_else(|e| panic!("unknown workload {}: {e}", spec.workload));
    let root = Schedule::initial(Arc::new(workload));
    dispatch_with_cache(&spec.searcher, spec.target, root, spec.config(), &spec.workload)
}

/// Execute a matrix of runs across `threads` OS threads. Results are
/// returned in spec order. Delegates to the parallel search driver
/// ([`crate::runtime::driver::run_specs`]), which guarantees the results
/// are byte-identical to running the specs serially.
pub fn run_many(specs: &[RunSpec], threads: usize) -> Vec<SearchResult> {
    crate::runtime::driver::run_specs(specs, threads)
}

/// [`run_many`] with a persistent eval-cache warm start: load
/// `cache_file` (if given), seed every search from it, save the merged
/// warmed cache back. See [`crate::runtime::driver::run_specs_cached`].
pub fn run_many_cached(
    specs: &[RunSpec],
    threads: usize,
    cache_file: Option<&str>,
) -> Vec<SearchResult> {
    crate::runtime::driver::run_specs_cached(specs, threads, cache_file)
}

/// Build the run matrix of a scenario sweep: `scenarios × targets`, one
/// spec per pair, each under an independent deterministic lane seed
/// ([`crate::runtime::driver::lane_seed`] over `base_seed`, lane =
/// position in the scenario-major cross product). The spec's workload
/// name is the scenario's canonical name, so everything downstream
/// (driver, reports, eval-cache keys) is scenario-aware for free.
pub fn sweep_specs(
    scenarios: &[ScenarioSpec],
    targets: &[Target],
    searcher: &Searcher,
    budget: usize,
    base_seed: u64,
    search_threads: usize,
) -> Vec<RunSpec> {
    let mut specs = Vec::with_capacity(scenarios.len() * targets.len());
    for sc in scenarios {
        for &target in targets {
            let lane = specs.len() as u64;
            let mut sp = RunSpec::new(
                &sc.name(),
                target,
                searcher.clone(),
                budget,
                crate::runtime::driver::lane_seed(base_seed, lane),
            );
            sp.search_threads = search_threads.max(1);
            specs.push(sp);
        }
    }
    specs
}

/// Aggregated e2e result (paper Table 3 / 16).
#[derive(Clone, Debug)]
pub struct E2eResult {
    pub label: String,
    pub speedup: f64,
    pub compile_time_s: f64,
    pub api_cost_usd: f64,
    pub n_samples: usize,
}

/// Tune every unique task of an e2e graph (budget split by FLOP share)
/// and combine into whole-model numbers, fanning tasks out across one
/// worker per available core. See [`run_e2e_threaded`].
pub fn run_e2e(
    graph: &E2eGraph,
    target: Target,
    searcher: &Searcher,
    total_budget: usize,
    seed: u64,
) -> E2eResult {
    run_e2e_threaded(graph, target, searcher, total_budget, seed, default_threads())
}

/// [`run_e2e`] with an explicit thread cap. Per-task searches fan out
/// through the parallel driver; each task keeps its own deterministic
/// seed, so the result is identical to tuning the tasks serially.
pub fn run_e2e_threaded(
    graph: &E2eGraph,
    target: Target,
    searcher: &Searcher,
    total_budget: usize,
    seed: u64,
    threads: usize,
) -> E2eResult {
    let jobs: Vec<_> = graph
        .tasks
        .iter()
        .enumerate()
        .map(|(ti, task)| {
            let searcher = searcher.clone();
            move || {
                let budget = ((total_budget as f64 * task.budget_frac).round() as usize).max(20);
                let root = Schedule::initial(Arc::new(task.workload.clone()));
                let cfg = SearchConfig {
                    budget,
                    seed: seed ^ ((ti as u64) << 8),
                    checkpoints: vec![budget],
                    ..SearchConfig::default()
                };
                dispatch(&searcher, target, root, cfg, &task.workload.name)
            }
        })
        .collect();
    let results = crate::runtime::driver::run_jobs(jobs, threads);

    let mut naive = 0.0;
    let mut tuned = 0.0;
    let mut time = 0.0;
    let mut cost = 0.0;
    let mut samples = 0usize;
    for (task, r) in graph.tasks.iter().zip(&results) {
        naive += r.baseline_latency_s * task.count as f64;
        tuned += r.best_latency_s * task.count as f64;
        time += r.compile_time_s;
        cost += r.api_cost_usd;
        samples += r.n_samples;
    }
    E2eResult {
        label: searcher.label(),
        speedup: naive / tuned,
        compile_time_s: time,
        api_cost_usd: cost,
        n_samples: samples,
    }
}

/// Default parallelism for experiment matrices.
pub fn default_threads() -> usize {
    crate::runtime::driver::default_threads()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_matrix_parallel_matches_serial() {
        let specs: Vec<RunSpec> = (0..3)
            .map(|seed| {
                RunSpec::new(
                    "gemm",
                    Target::Cpu,
                    Searcher::Coop {
                        n: 2,
                        largest: "gpt-5.2".into(),
                    },
                    40,
                    seed,
                )
            })
            .collect();
        let par = run_many(&specs, 3);
        let ser: Vec<_> = specs.iter().map(run_one).collect();
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.best_speedup, s.best_speedup);
        }
    }

    #[test]
    fn sweep_specs_cross_products_scenarios_and_targets() {
        let grid = crate::workloads::scenarios::ScenarioGrid::parse("gemm", "m=32,64").unwrap();
        let scenarios = grid.expand().unwrap();
        let searcher = Searcher::Coop {
            n: 2,
            largest: "gpt-5.2".into(),
        };
        let specs = sweep_specs(&scenarios, &[Target::Cpu, Target::Gpu], &searcher, 40, 7, 2);
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].workload, "gemm@m=32");
        assert_eq!(specs[0].target, Target::Cpu);
        assert_eq!(specs[1].target, Target::Gpu);
        assert_eq!(specs[2].workload, "gemm@m=64");
        assert!(specs.iter().all(|sp| sp.search_threads == 2));
        // independent deterministic lane seeds
        let seeds: Vec<u64> = specs.iter().map(|sp| sp.seed).collect();
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
        let again = sweep_specs(&scenarios, &[Target::Cpu, Target::Gpu], &searcher, 40, 7, 2);
        assert_eq!(seeds, again.iter().map(|sp| sp.seed).collect::<Vec<_>>());
        // the whole matrix actually runs (scenario names resolve)
        let results = run_many(&specs, 4);
        assert!(results.iter().all(|r| r.best_speedup >= 1.0));
    }

    #[test]
    fn e2e_graph_runs() {
        let graph = crate::workloads::llama_e2e::llama3_8b_graph();
        let r = run_e2e(
            &graph,
            Target::Cpu,
            &Searcher::Coop {
                n: 2,
                largest: "gpt-5.2".into(),
            },
            60,
            1,
        );
        assert!(r.speedup > 1.0, "{}", r.speedup);
        assert!(r.api_cost_usd > 0.0);
    }
}

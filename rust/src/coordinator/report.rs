//! Report assembly: aggregate [`SearchResult`]s into the paper's
//! table/figure shapes and emit markdown.

use crate::mcts::evalcache::CacheStats;
use crate::mcts::SearchResult;
use crate::stats;
use crate::util::table::Table;
use std::collections::BTreeMap;

/// Mean best-speedup over repetitions.
pub fn mean_speedup(runs: &[&SearchResult]) -> f64 {
    stats::mean(&runs.iter().map(|r| r.best_speedup).collect::<Vec<_>>())
}

pub fn mean_time(runs: &[&SearchResult]) -> f64 {
    stats::mean(&runs.iter().map(|r| r.compile_time_s).collect::<Vec<_>>())
}

pub fn mean_cost(runs: &[&SearchResult]) -> f64 {
    stats::mean(&runs.iter().map(|r| r.api_cost_usd).collect::<Vec<_>>())
}

/// Aggregate eval-cache counters over runs (see
/// [`crate::mcts::evalcache`]).
pub fn total_cache(runs: &[&SearchResult]) -> CacheStats {
    let mut total = CacheStats::default();
    for r in runs {
        total.merge(&r.eval_cache);
    }
    total
}

/// One-line eval-cache digest for a report footer.
pub fn cache_line(runs: &[&SearchResult]) -> String {
    let t = total_cache(runs);
    format!(
        "eval-cache: {} hits / {} misses ({:.1}% hit rate) across {} runs",
        t.hits,
        t.misses,
        t.hit_rate() * 100.0,
        runs.len()
    )
}

/// Total analyzer (Deny-lint) rejections over runs — transform
/// applications the legality analyzer refused (see [`crate::analysis`]).
pub fn total_lint_rejects(runs: &[&SearchResult]) -> u64 {
    runs.iter().map(|r| r.lint_rejects).sum()
}

/// One-line analyzer digest for a report footer.
pub fn lint_line(runs: &[&SearchResult]) -> String {
    format!(
        "analyzer: {} Deny-lint rejections across {} runs",
        total_lint_rejects(runs),
        runs.len()
    )
}

/// Aggregate fault-injection/recovery counters over runs (see
/// [`crate::llm::faults`]).
pub fn total_faults(runs: &[&SearchResult]) -> crate::llm::faults::FaultReport {
    let mut t = crate::llm::faults::FaultReport::default();
    for r in runs {
        let f = &r.faults;
        t.timeouts += f.timeouts;
        t.rate_limits += f.rate_limits;
        t.transients += f.transients;
        t.malformed += f.malformed;
        t.retries += f.retries;
        t.fallbacks += f.fallbacks;
        t.forced += f.forced;
        t.backoff_latency_s += f.backoff_latency_s;
        t.fault_latency_s += f.fault_latency_s;
        t.fault_cost_usd += f.fault_cost_usd;
    }
    t
}

/// One-line fault digest for a report footer.
pub fn fault_line(runs: &[&SearchResult]) -> String {
    format!(
        "faults: {} across {} runs",
        total_faults(runs).summary(),
        runs.len()
    )
}

/// Mean speedup at each curve checkpoint (runs must share checkpoints).
pub fn mean_curve(runs: &[&SearchResult]) -> Vec<(usize, f64)> {
    let mut acc: BTreeMap<usize, (f64, usize)> = BTreeMap::new();
    for r in runs {
        for &(s, v) in &r.curve {
            let e = acc.entry(s).or_insert((0.0, 0));
            e.0 += v;
            e.1 += 1;
        }
    }
    acc.into_iter()
        .map(|(s, (sum, n))| (s, sum / n as f64))
        .collect()
}

/// Average invocation rates (regular, CA) per model over runs.
pub fn mean_invocation_rates(runs: &[&SearchResult]) -> Vec<(String, f64, f64)> {
    let mut names: Vec<String> = Vec::new();
    for r in runs {
        for (n, _, _) in &r.call_counts {
            if !names.contains(n) {
                names.push(n.clone());
            }
        }
    }
    names
        .into_iter()
        .map(|name| {
            let mut reg = 0.0;
            let mut ca = 0.0;
            for r in runs {
                let (rr, cc) = r.invocation_rate(&name);
                reg += rr;
                ca += cc;
            }
            (name, reg / runs.len() as f64, ca / runs.len() as f64)
        })
        .collect()
}

/// Render a speedup-vs-samples figure as a markdown table (one row per
/// series, one column per checkpoint) — the textual form of Figure 2/3.
pub fn curve_table(
    title: &str,
    series: &[(String, Vec<(usize, f64)>)],
) -> Table {
    let checkpoints: Vec<usize> = series
        .first()
        .map(|(_, c)| c.iter().map(|&(s, _)| s).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["Config".into()];
    header.extend(checkpoints.iter().map(|c| c.to_string()));
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &hdr_refs);
    for (label, curve) in series {
        let mut row = vec![label.clone()];
        for &cp in &checkpoints {
            let v = curve
                .iter()
                .find(|&&(s, _)| s == cp)
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN);
            row.push(format!("{v:.2}"));
        }
        t.row(row);
    }
    t
}

/// Write a markdown report section to `reports/<id>.md` and echo it.
pub fn emit(id: &str, content: &str) -> std::io::Result<()> {
    std::fs::create_dir_all("reports")?;
    std::fs::write(format!("reports/{id}.md"), content)?;
    println!("{content}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn fake(speedup: f64, curve: Vec<(usize, f64)>) -> SearchResult {
        SearchResult {
            workload: "w".into(),
            best_speedup: speedup,
            best_latency_s: 1.0,
            baseline_latency_s: speedup,
            curve,
            compile_time_s: 100.0,
            api_cost_usd: 1.0,
            n_samples: 100,
            n_ca_events: 0,
            n_errors: 0,
            call_counts: vec![("m".into(), 10, 2)],
            eval_cache: CacheStats { hits: 3, misses: 7 },
            lint_rejects: 2,
            faults: crate::llm::faults::FaultReport {
                timeouts: 1,
                retries: 2,
                ..Default::default()
            },
            best_schedule: Schedule::initial(Arc::new(gemm::gemm(8, 8, 8))),
        }
    }

    #[test]
    fn aggregates() {
        let a = fake(2.0, vec![(50, 1.0), (100, 2.0)]);
        let b = fake(4.0, vec![(50, 3.0), (100, 4.0)]);
        let runs = vec![&a, &b];
        assert_eq!(mean_speedup(&runs), 3.0);
        assert_eq!(mean_curve(&runs), vec![(50, 2.0), (100, 3.0)]);
        let rates = mean_invocation_rates(&runs);
        assert_eq!(rates.len(), 1);
        assert!((rates[0].1 - 10.0 / 12.0).abs() < 1e-9);
        let cache = total_cache(&runs);
        assert_eq!(cache, CacheStats { hits: 6, misses: 14 });
        assert!(cache_line(&runs).contains("30.0% hit rate"));
        assert_eq!(total_lint_rejects(&runs), 4);
        assert!(lint_line(&runs).contains("4 Deny-lint rejections across 2 runs"));
        let faults = total_faults(&runs);
        assert_eq!((faults.timeouts, faults.retries), (2, 4));
        assert!(fault_line(&runs).contains("2 injected") && fault_line(&runs).contains("2 runs"));
    }

    #[test]
    fn curve_table_renders() {
        let t = curve_table(
            "Fig 2a",
            &[("LiteCoOp(8 LLMs)".into(), vec![(50, 7.5), (100, 10.6)])],
        );
        let md = t.to_markdown();
        assert!(md.contains("7.50"));
        assert!(md.contains("| 50"));
    }
}

//! Root-parallel distributed search: lane orchestration over one
//! scenario, and fleet sweeps over many.
//!
//! One fleet run fans a scenario out into N independent search lanes.
//! Each lane is a full engine on a distinct deterministic seed stream
//! ([`lane_seed`]), warm-started from the scenario's serve-registry tree
//! when one exists (resume → [`Mcts::reseed`] → budget extension), cold
//! otherwise, and each checkpoints its finished tree through the
//! treestore snapshot format. Lanes communicate **only** through those
//! snapshot files and the federated eval cache — the same contract a
//! multi-process fleet has — and are executed across worker threads by
//! [`run_jobs`] (one OS process here; the file-mediated protocol is what
//! keeps the merge semantics process-boundary-clean, including the
//! per-thread lint-reject accounting fixed at snapshot time).
//!
//! The lanes are then folded into one tree by
//! [`treemerge::merge_snapshot_files`] (keyed union; corrupt or missing
//! lane files degrade to warnings), re-validated with
//! [`Mcts::first_tree_deny`], persisted back to the serve registry so
//! the daemon absorbs the fleet's result on its next request, and every
//! lane's ground-truth evaluations are federated into the shared
//! persistent cache file ([`EvalCache::federate`]).
//!
//! Two invariants the CI merge smoke leans on:
//! * **Determinism**: a fleet's merged tree is a pure function of
//!   (scenario, config, seed set, lane count, warm-start state) — lanes
//!   are deterministic engines and the merge is canonical.
//! * **Monotonicity at equal total budget**: lanes warm-started from the
//!   registry tree begin at its incumbent, incumbents never regress, and
//!   the merge takes the best across lanes — so an N-lane fleet resumed
//!   on top of a prior run's tree reports a speedup ≥ that run's.
//!
//! Merged sample counters sum over lanes, so the shared warm-start
//! prefix is counted once per lane that inherited it — the standard
//! root-parallel accounting artifact; samples stay consistent with the
//! summed budgets, and *new* samples per fleet run still total exactly
//! the requested budget.

use super::serve::tree_file_name;
use crate::llm::registry::paper_config;
use crate::llm::ModelSet;
use crate::mcts::evalcache::EvalCache;
use crate::mcts::treemerge;
use crate::mcts::{Mcts, SearchConfig};
use crate::runtime::driver::{default_threads, lane_seed, run_jobs};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::workloads;
use std::sync::Arc;

/// Configuration of one fleet run (and the base config of a sweep).
#[derive(Clone, Debug)]
pub struct FleetOpts {
    /// Scenario name: a registry workload or `family@key=val,...` form.
    pub scenario: String,
    pub target: Target,
    /// Number of root-parallel lanes.
    pub lanes: usize,
    /// Total *new* sample budget, split across lanes (earlier lanes take
    /// the remainder), so fleets of different widths are comparable at
    /// equal total budget.
    pub total_budget: usize,
    pub n_llms: usize,
    pub largest: String,
    /// Base of the per-lane seed stream ([`lane_seed`]).
    pub base_seed: u64,
    /// Within-lane tree parallelism (threads of one engine).
    pub search_threads: usize,
    /// Lane fan-out: how many lanes run concurrently.
    pub threads: usize,
    /// Serve registry to warm-start lanes from and persist the merged
    /// tree into; `None` runs cold and keeps lane files in a temp dir.
    pub registry_dir: Option<String>,
    /// Persistent eval-cache file: loaded before the lanes, federated
    /// with every lane's ground truth after, saved back.
    pub cache_file: Option<String>,
    /// Keep per-lane snapshot files after the merge (debugging).
    pub keep_lane_files: bool,
    /// Chaos hook: these lanes panic on **every** attempt — contained by
    /// the supervisor, skipped after the retry, merge proceeds on the
    /// survivors. Exercised by the `chaos_smoke` CI gate.
    pub fail_lanes: Vec<usize>,
    /// Chaos hook: these lanes panic on their **first** attempt only —
    /// the supervisor's single retry (on a fresh seed stream) recovers
    /// them.
    pub flaky_lanes: Vec<usize>,
}

impl Default for FleetOpts {
    fn default() -> FleetOpts {
        FleetOpts {
            scenario: "gemm".to_string(),
            target: Target::Cpu,
            lanes: 4,
            total_budget: 240,
            n_llms: 4,
            largest: "gpt-5.2".to_string(),
            base_seed: 7,
            search_threads: 1,
            threads: default_threads(),
            registry_dir: None,
            cache_file: None,
            keep_lane_files: false,
            fail_lanes: Vec::new(),
            flaky_lanes: Vec::new(),
        }
    }
}

/// What one fleet run produced.
#[derive(Clone, Debug)]
pub struct FleetResult {
    pub scenario: String,
    /// Lanes dispatched.
    pub lanes_run: usize,
    /// Lanes whose snapshots survived into the merge.
    pub lanes_merged: usize,
    /// Per-lane incumbent speedups, lane order.
    pub lane_speedups: Vec<f64>,
    /// Merged incumbent speedup (= max of the surviving lanes').
    pub merged_speedup: f64,
    pub merged_samples: usize,
    pub merged_nodes: usize,
    /// Registry path the merged tree was persisted to, when a registry
    /// was configured.
    pub tree_path: Option<String>,
    /// `(path-or-lane, reason)` of lanes that failed to run or merge.
    pub skipped: Vec<(String, String)>,
    /// Lanes that failed both attempts and were excluded from the merge.
    pub lanes_failed: usize,
    /// Lanes recovered by the supervisor's single retry.
    pub lanes_retried: usize,
}

impl FleetResult {
    /// One-line fleet health digest for operators and smoke gates.
    pub fn health_summary(&self) -> String {
        format!(
            "fleet {}: {}/{} lanes merged ({} failed, {} recovered by retry), \
             merged speedup {:.3}x over {} samples",
            self.scenario,
            self.lanes_merged,
            self.lanes_run,
            self.lanes_failed,
            self.lanes_retried,
            self.merged_speedup,
            self.merged_samples,
        )
    }
}

/// One finished lane, as handed from a worker to the merge step.
struct LaneOut {
    path: String,
    speedup: f64,
    cache: EvalCache,
}

/// Split `total` into `lanes` near-equal parts, remainder to the front —
/// fleet widths stay comparable at equal total budget.
pub fn lane_budgets(total: usize, lanes: usize) -> Vec<usize> {
    let lanes = lanes.max(1);
    (0..lanes).map(|l| total / lanes + usize::from(l < total % lanes)).collect()
}

/// One lane attempt: build (or warm-start) the engine on `seed`, run it
/// to its budget, checkpoint the tree. Factored out of the job closure
/// so the supervisor can wrap it in panic containment and retry it on a
/// fresh seed stream.
#[allow(clippy::too_many_arguments)]
fn run_lane_attempt(
    workload: &Arc<crate::tir::Workload>,
    warm: &EvalCache,
    opts: &FleetOpts,
    lane_path: &str,
    registry_tree: Option<&str>,
    lane_budget: usize,
    l: usize,
    seed: u64,
    attempt: usize,
) -> Result<LaneOut, String> {
    if opts.fail_lanes.contains(&l) || (attempt == 0 && opts.flaky_lanes.contains(&l)) {
        panic!("chaos: injected failure in fleet lane {l} (attempt {attempt})");
    }
    let models = ModelSet::new(paper_config(opts.n_llms, &opts.largest));
    let sim = Simulator::new(opts.target);
    let root = Schedule::initial(Arc::clone(workload));
    let cfg = SearchConfig {
        budget: lane_budget,
        seed,
        search_threads: opts.search_threads,
        checkpoints: Vec::new(),
        ..SearchConfig::default()
    };
    // warm start: resume the scenario's registry tree onto this lane's
    // seed stream; cold otherwise
    let mut engine = match registry_tree
        .filter(|p| std::path::Path::new(p).exists())
        .and_then(|p| {
            Mcts::load_file(p, models.clone(), sim.clone(), root.clone())
                .map_err(|e| {
                    eprintln!("warning: fleet lane {l}: tree file {e}; starting cold")
                })
                .ok()
        }) {
        Some(mut resumed) => {
            resumed.reseed(seed);
            resumed.cfg.search_threads = opts.search_threads;
            resumed.eval.cache.absorb(warm.clone());
            resumed.extend_budget(lane_budget);
            resumed
        }
        None => Mcts::with_cache(cfg, models, sim, root, warm.clone()),
    };
    engine = if opts.search_threads > 1 {
        engine.run_parallel_until(opts.search_threads, usize::MAX)
    } else {
        engine.run_until(usize::MAX)
    };
    engine.save_file(lane_path)?;
    let speedup = engine.best_speedup();
    Ok(LaneOut { path: lane_path.to_string(), speedup, cache: engine.eval.cache })
}

/// Run one root-parallel fleet: N lanes, snapshot checkpoints, cache
/// federation, keyed-union merge, registry persistence. See the module
/// docs for the protocol.
pub fn run_fleet(opts: &FleetOpts) -> Result<FleetResult, String> {
    let lanes = opts.lanes.max(1);
    let workload = workloads::resolve(&opts.scenario)
        .map_err(|e| format!("fleet: unknown scenario {}: {e}", opts.scenario))?;
    let workload = Arc::new(workload);
    let warm = Arc::new(match &opts.cache_file {
        Some(path) => EvalCache::load_file_or_cold(path),
        None => EvalCache::default(),
    });

    // lane snapshots live next to the registry tree (or in a temp dir
    // for registry-less runs)
    let (lane_dir, temp_dir) = match &opts.registry_dir {
        Some(dir) => (dir.clone(), None),
        None => {
            let d = std::env::temp_dir()
                .join(format!("litecoop_fleet_{}", std::process::id()))
                .to_string_lossy()
                .into_owned();
            (d.clone(), Some(d))
        }
    };
    std::fs::create_dir_all(&lane_dir).map_err(|e| format!("fleet: lane dir {lane_dir}: {e}"))?;
    let tree_base = format!("{lane_dir}/{}", tree_file_name(&opts.scenario));

    let budgets = lane_budgets(opts.total_budget, lanes);
    let jobs: Vec<_> = (0..lanes)
        .map(|l| {
            let workload = Arc::clone(&workload);
            let warm = Arc::clone(&warm);
            let opts = opts.clone();
            let lane_path = format!("{tree_base}.lane{l}");
            let registry_tree = opts.registry_dir.as_ref().map(|_| tree_base.clone());
            let lane_budget = budgets[l];
            // the lane supervisor: contain a failed attempt (Err *or*
            // panic), retry exactly once on a fresh deterministic seed
            // stream, report the second failure for the merge to skip
            move || -> Result<(LaneOut, bool), String> {
                let mut last_err = String::new();
                for attempt in 0..2 {
                    let seed = if attempt == 0 {
                        lane_seed(opts.base_seed, l as u64)
                    } else {
                        lane_seed(opts.base_seed ^ 0xFA17, l as u64)
                    };
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_lane_attempt(
                            &workload,
                            &warm,
                            &opts,
                            &lane_path,
                            registry_tree.as_deref(),
                            lane_budget,
                            l,
                            seed,
                            attempt,
                        )
                    }))
                    .unwrap_or_else(|p| {
                        let what = p
                            .downcast_ref::<String>()
                            .map(String::as_str)
                            .or_else(|| p.downcast_ref::<&str>().copied())
                            .unwrap_or("panic");
                        Err(format!("lane execution panicked: {what}"))
                    });
                    match out {
                        Ok(lane) => return Ok((lane, attempt > 0)),
                        Err(e) => {
                            eprintln!(
                                "warning: fleet lane {l} attempt {attempt} (seed {seed}): {e}"
                            );
                            last_err = e;
                        }
                    }
                }
                Err(last_err)
            }
        })
        .collect();
    let outs = run_jobs(jobs, opts.threads.max(1).min(lanes));

    // federate every lane's ground truth into the shared persistent
    // cache (lane order; the union is order-independent)
    let mut fleet_cache = EvalCache::clone(&warm);
    let mut skipped: Vec<(String, String)> = Vec::new();
    let mut lane_speedups: Vec<f64> = Vec::new();
    let mut lane_paths: Vec<String> = Vec::new();
    let mut lanes_failed = 0usize;
    let mut lanes_retried = 0usize;
    for (l, out) in outs.into_iter().enumerate() {
        match out {
            Ok((lane, retried)) => {
                if retried {
                    lanes_retried += 1;
                }
                fleet_cache.federate(lane.cache);
                lane_speedups.push(lane.speedup);
                lane_paths.push(lane.path);
            }
            Err(e) => {
                eprintln!("warning: fleet lane {l}: {e}; skipping lane");
                lanes_failed += 1;
                skipped.push((format!("lane {l}"), e));
            }
        }
    }
    if lanes_failed > 0 || lanes_retried > 0 {
        eprintln!(
            "warning: fleet {}: {lanes_failed} of {lanes} lanes failed permanently, \
             {lanes_retried} recovered by retry; merging the survivors",
            opts.scenario
        );
    }
    if let Some(path) = &opts.cache_file {
        if let Err(e) = fleet_cache.save_file(path) {
            eprintln!("warning: fleet: failed to save eval cache: {e}");
        }
    }

    // keyed-union merge over the surviving lane snapshots, then the
    // trust-but-verify lint pass every from-disk tree gets
    let (merged, report) = treemerge::merge_snapshot_files(&lane_paths, || {
        (
            ModelSet::new(paper_config(opts.n_llms, &opts.largest)),
            Simulator::new(opts.target),
            Schedule::initial(Arc::clone(&workload)),
        )
    })?;
    if let Some((node, diag)) = merged.first_tree_deny() {
        return Err(format!(
            "fleet: merged tree failed the legality analyzer at node {node}: {diag}"
        ));
    }
    let tree_path = match &opts.registry_dir {
        Some(_) => {
            merged.save_file(&tree_base)?;
            Some(tree_base.clone())
        }
        None => None,
    };

    if !opts.keep_lane_files {
        for p in &lane_paths {
            let _ = std::fs::remove_file(p);
        }
        if let Some(d) = &temp_dir {
            let _ = std::fs::remove_dir(d);
        }
    }
    skipped.extend(report.skipped.iter().cloned());

    Ok(FleetResult {
        scenario: opts.scenario.clone(),
        lanes_run: lanes,
        lanes_merged: report.lanes_merged,
        lane_speedups,
        merged_speedup: report.best_speedup,
        merged_samples: merged.samples(),
        merged_nodes: report.n_nodes,
        tree_path,
        skipped,
        lanes_failed,
        lanes_retried,
    })
}

/// Shard a scenario list (e.g. an expanded
/// [`crate::workloads::scenarios::ScenarioGrid`]) across root-parallel
/// fleets, one scenario at a time, federating every fleet's ground
/// truth through the shared cache file: fleet k+1 warm-starts from the
/// cache fleet k saved. Lane fan-out happens inside each fleet.
pub fn run_lanes(base: &FleetOpts, scenarios: &[String]) -> Result<Vec<FleetResult>, String> {
    let mut results = Vec::with_capacity(scenarios.len());
    for scenario in scenarios {
        let mut opts = base.clone();
        opts.scenario = scenario.clone();
        results.push(run_fleet(&opts)?);
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("litecoop_fleet_{tag}_{}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn quick_opts(lanes: usize, budget: usize) -> FleetOpts {
        FleetOpts {
            lanes,
            total_budget: budget,
            n_llms: 2,
            threads: 2,
            ..FleetOpts::default()
        }
    }

    #[test]
    fn lane_budgets_partition_the_total() {
        assert_eq!(lane_budgets(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(lane_budgets(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(lane_budgets(3, 4), vec![1, 1, 1, 0]);
        assert_eq!(lane_budgets(5, 1), vec![5]);
        let total: usize = lane_budgets(97, 6).iter().sum();
        assert_eq!(total, 97);
    }

    #[test]
    fn fleet_merges_all_lanes_and_beats_no_lane() {
        let r = run_fleet(&quick_opts(3, 36)).expect("fleet");
        assert_eq!(r.lanes_run, 3);
        assert_eq!(r.lanes_merged, 3);
        assert_eq!(r.lane_speedups.len(), 3);
        assert!(r.skipped.is_empty(), "{:?}", r.skipped);
        let best_lane = r.lane_speedups.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(r.merged_speedup.to_bits(), best_lane.to_bits());
        assert_eq!(r.merged_samples, 36);
        assert!(r.tree_path.is_none());
    }

    #[test]
    fn fleet_is_deterministic_per_seed_set() {
        let a = run_fleet(&quick_opts(2, 24)).expect("fleet a");
        let b = run_fleet(&quick_opts(2, 24)).expect("fleet b");
        assert_eq!(a.merged_speedup.to_bits(), b.merged_speedup.to_bits());
        assert_eq!(a.merged_nodes, b.merged_nodes);
        assert_eq!(a.lane_speedups.len(), b.lane_speedups.len());
        for (x, y) in a.lane_speedups.iter().zip(&b.lane_speedups) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn flaky_lane_is_recovered_by_one_retry() {
        let opts = FleetOpts {
            flaky_lanes: vec![1],
            ..quick_opts(2, 24)
        };
        let r = run_fleet(&opts).expect("fleet");
        assert_eq!(r.lanes_merged, 2, "{:?}", r.skipped);
        assert_eq!(r.lanes_retried, 1);
        assert_eq!(r.lanes_failed, 0);
        assert!(r.skipped.is_empty(), "{:?}", r.skipped);
        assert_eq!(r.lane_speedups.len(), 2);
        assert!(r.health_summary().contains("1 recovered by retry"), "{}", r.health_summary());
    }

    #[test]
    fn permanently_failed_lane_is_skipped_and_survivors_merge() {
        let opts = FleetOpts {
            fail_lanes: vec![1],
            ..quick_opts(3, 36)
        };
        let r = run_fleet(&opts).expect("fleet must survive a dead lane");
        assert_eq!(r.lanes_run, 3);
        assert_eq!(r.lanes_merged, 2);
        assert_eq!(r.lanes_failed, 1);
        assert_eq!(r.skipped.len(), 1);
        assert!(r.skipped[0].1.contains("panicked"), "{:?}", r.skipped);
        let best_survivor = r.lane_speedups.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(r.merged_speedup.to_bits(), best_survivor.to_bits());
        assert!(r.health_summary().contains("2/3 lanes merged"), "{}", r.health_summary());
    }

    #[test]
    fn supervised_merge_matches_healthy_lanes_only_merge() {
        // a fleet with one lane forced dead must merge to bit-identical
        // state as a healthy fleet's merge over the same surviving lanes
        let dir_f = tmp_dir("chaosmerge_f");
        let dir_h = tmp_dir("chaosmerge_h");
        for d in [&dir_f, &dir_h] {
            let _ = std::fs::remove_dir_all(d);
        }
        let mut faulted = quick_opts(3, 36);
        faulted.fail_lanes = vec![2];
        faulted.registry_dir = Some(dir_f.clone());
        faulted.keep_lane_files = true;
        let rf = run_fleet(&faulted).expect("faulted fleet");
        assert_eq!(rf.lanes_merged, 2);
        let mut healthy = quick_opts(3, 36);
        healthy.registry_dir = Some(dir_h.clone());
        healthy.keep_lane_files = true;
        let rh = run_fleet(&healthy).expect("healthy fleet");
        assert_eq!(rh.lanes_merged, 3);
        // manually merge only the healthy fleet's lanes 0 and 1 (the
        // faulted fleet's survivors) and compare canonical snapshots
        let base_h = format!("{dir_h}/{}", tree_file_name("gemm"));
        let survivors = vec![format!("{base_h}.lane0"), format!("{base_h}.lane1")];
        let (manual, _) = treemerge::merge_snapshot_files(&survivors, || {
            (
                ModelSet::new(paper_config(2, "gpt-5.2")),
                Simulator::new(Target::Cpu),
                Schedule::initial(Arc::new(workloads::by_name("gemm").unwrap())),
            )
        })
        .expect("manual merge");
        let persisted = std::fs::read_to_string(rf.tree_path.as_ref().unwrap()).unwrap();
        assert_eq!(
            persisted.trim_end(),
            format!("{}", manual.snapshot()),
            "supervised merge diverged from the healthy-lanes-only merge"
        );
        for d in [&dir_f, &dir_h] {
            let _ = std::fs::remove_dir_all(d);
        }
    }

    #[test]
    fn registry_warm_start_is_monotone_at_equal_budget() {
        let dir = tmp_dir("warm");
        let _ = std::fs::remove_dir_all(&dir);
        let mut first = quick_opts(1, 24);
        first.registry_dir = Some(dir.clone());
        let r1 = run_fleet(&first).expect("fleet 1");
        assert!(r1.tree_path.is_some());
        let mut second = quick_opts(4, 24);
        second.registry_dir = Some(dir.clone());
        let r2 = run_fleet(&second).expect("fleet 2");
        assert!(
            r2.merged_speedup >= r1.merged_speedup,
            "4-lane warm fleet {} regressed below 1-lane {}",
            r2.merged_speedup,
            r1.merged_speedup
        );
        // every lane inherited the prior tree's samples, plus its share
        assert!(r2.merged_samples > r1.merged_samples);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

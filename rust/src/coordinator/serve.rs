//! Resident serve loop: a line-oriented daemon that answers repeated
//! tuning requests against a per-scenario registry of persisted MCTS
//! trees.
//!
//! Protocol: one scenario name per stdin line (a registry workload name
//! or a scenario-grammar name like `gemm@m=512`; see
//! [`crate::workloads::scenarios`]). For each request the daemon
//! resumes the scenario's persisted tree (or starts cold on the first
//! request), runs `--budget-per-request` more search samples on it,
//! persists the tree back, and prints the incumbent schedule and
//! speedup. A tree served once stays **resident** — later requests for
//! the same scenario continue in memory without a reload — up to
//! `--max-trees` scenarios; beyond that the least-recently-used tree is
//! persisted and dropped.
//!
//! Degradation contract: a request must never take the daemon down. An
//! unresolvable scenario name reports an error line and the loop
//! continues; a corrupt tree file falls back to a cold tree with a
//! stderr warning ([`Mcts::resume_file_or_cold`]).
//!
//! The `expect_warm_on_repeat` self-check (CI smoke) turns the warm-
//! start contract into a hard failure: any repeated request must resume
//! a tree (not start cold), report nonzero eval-cache hits, and report
//! a speedup no worse than its previous segment's — speedups are
//! monotone under continued search because the incumbent latency never
//! increases.

use crate::llm::registry::paper_config;
use crate::llm::ModelSet;
use crate::mcts::{Mcts, SearchConfig};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::util::fnv::{fnv_str, FNV_OFFSET};
use crate::workloads;
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::Arc;

/// Serve-daemon configuration (one per `litecoop serve` invocation).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Directory holding one persisted tree file per scenario.
    pub registry_dir: String,
    /// Resident-tree cap: beyond this many distinct scenarios, the
    /// least-recently-used tree is persisted and dropped.
    pub max_trees: usize,
    /// Search samples added per request.
    pub budget_per_request: usize,
    /// Model-pool size for cold trees (resumed trees keep the roster
    /// they were persisted with — it must match this configuration).
    pub n_llms: usize,
    /// Largest model of the pool.
    pub largest: String,
    pub target: Target,
    /// In-search tree parallelism per request.
    pub search_threads: usize,
    /// Seed for cold trees.
    pub seed: u64,
    /// CI self-check: fail hard if a repeated request does not resume a
    /// warm tree with cache hits and a monotone speedup.
    pub expect_warm_on_repeat: bool,
    /// Per-request deadline in **simulated** seconds (LLM latency +
    /// measurement time, [`Mcts::simulated_time_s`]): the incremental
    /// budget is run in chunks and trimmed once the request's simulated
    /// time crosses the deadline. Simulated time is deterministic, so
    /// trimming is too. `None` = no deadline.
    pub deadline_s: Option<f64>,
    /// Chaos hook: requests for these scenarios panic inside the serve
    /// path, exercising the degraded-mode response (contained by the
    /// loop, answered from the persisted incumbent).
    pub chaos_panic_scenarios: Vec<String>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            registry_dir: "trees".to_string(),
            max_trees: 8,
            budget_per_request: 60,
            n_llms: 4,
            largest: "gpt-5.2".to_string(),
            target: Target::Cpu,
            search_threads: 1,
            seed: 7,
            expect_warm_on_repeat: false,
            deadline_s: None,
            chaos_panic_scenarios: Vec::new(),
        }
    }
}

/// What the serve loop did, for the caller's exit report.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    pub requests: usize,
    /// Requests answered by continuing an existing tree (resident or
    /// loaded from the registry) rather than starting cold.
    pub resumed: usize,
    pub evictions: usize,
    pub errors: usize,
    /// Requests whose search blew up and were answered degraded (the
    /// persisted incumbent, `degraded=` marker) instead of erroring.
    pub degraded: usize,
    /// Requests whose incremental budget was trimmed by the deadline.
    pub trimmed: usize,
}

/// Scenario names contain characters that don't belong in filenames
/// (`@`, `=`, `,`, `.`); the registry file name is the sanitized name
/// plus a short hash of the exact name, so distinct scenarios can never
/// collide on a shared sanitized form.
pub fn tree_file_name(scenario: &str) -> String {
    let safe: String = scenario
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect();
    format!("{safe}-{:08x}.tree.json", fnv_str(FNV_OFFSET, scenario) & 0xFFFF_FFFF)
}

/// The per-scenario tree registry: persisted files under `dir`, plus an
/// LRU-bounded resident set so repeated requests skip the load.
pub struct TreeRegistry {
    dir: String,
    max_trees: usize,
    /// LRU order: least-recently-used first. Small (≤ max_trees), so a
    /// linear scan beats a hash map + separate order list.
    resident: Vec<(String, Mcts)>,
    pub evictions: usize,
}

impl TreeRegistry {
    pub fn new(dir: &str, max_trees: usize) -> Result<TreeRegistry, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("registry dir {dir}: {e}"))?;
        // startup hygiene: reclaim temp files stranded by a daemon that
        // died mid-save (atomic saves rename within the same call, so any
        // surviving *.tmp.<pid> is an orphan by definition)
        crate::util::fsx::sweep_orphan_tmp_dir(dir);
        Ok(TreeRegistry {
            dir: dir.to_string(),
            max_trees: max_trees.max(1),
            resident: Vec::new(),
            evictions: 0,
        })
    }

    /// Registry path of a scenario's persisted tree.
    pub fn tree_path(&self, scenario: &str) -> String {
        format!("{}/{}", self.dir, tree_file_name(scenario))
    }

    /// Remove and return the resident tree for `scenario`, if any.
    pub fn take(&mut self, scenario: &str) -> Option<Mcts> {
        let i = self.resident.iter().position(|(n, _)| n == scenario)?;
        Some(self.resident.remove(i).1)
    }

    /// Make `scenario`'s tree resident (most recently used). If the cap
    /// is now exceeded, the least-recently-used tree is persisted to its
    /// registry file and dropped — eviction never loses search state.
    pub fn put(&mut self, scenario: &str, engine: Mcts) -> Result<(), String> {
        self.resident.push((scenario.to_string(), engine));
        while self.resident.len() > self.max_trees {
            let (name, tree) = self.resident.remove(0);
            tree.save_file(&self.tree_path(&name))?;
            self.evictions += 1;
        }
        Ok(())
    }

    /// Persist every resident tree (shutdown path).
    pub fn flush(&mut self) -> Result<(), String> {
        for (name, tree) in &self.resident {
            tree.save_file(&self.tree_path(name))?;
        }
        Ok(())
    }
}

/// One answered request, for the status line.
struct ServeReply {
    resumed: bool,
    samples: usize,
    speedup: f64,
    hits: u64,
    /// Budget cut short by the per-request deadline.
    trimmed: bool,
}

/// Answer one request: resume (resident → registry file → cold, in that
/// order), search `budget_per_request` more samples (trimmed by the
/// deadline, if any), persist, park the tree resident.
fn serve_one(
    registry: &mut TreeRegistry,
    opts: &ServeOpts,
    scenario: &str,
) -> Result<ServeReply, String> {
    if opts.chaos_panic_scenarios.iter().any(|s| s == scenario) {
        panic!("chaos: injected serve failure for {scenario}");
    }
    let (mut engine, resumed) = match registry.take(scenario) {
        Some(engine) => (engine, true),
        None => {
            let workload = workloads::resolve(scenario)
                .map_err(|e| format!("unknown scenario {scenario}: {e}"))?;
            let root = Schedule::initial(Arc::new(workload));
            let models = ModelSet::new(paper_config(opts.n_llms, &opts.largest));
            let sim = Simulator::new(opts.target);
            let cfg = SearchConfig {
                budget: 0, // grown per request below
                seed: opts.seed,
                search_threads: opts.search_threads,
                checkpoints: Vec::new(),
                ..SearchConfig::default()
            };
            Mcts::resume_file_or_cold(&registry.tree_path(scenario), cfg, models, sim, root)
        }
    };
    engine.extend_budget(opts.budget_per_request);
    let goal = engine.samples().saturating_add(opts.budget_per_request);
    let run_to = |engine: Mcts, to: usize| {
        if opts.search_threads > 1 {
            engine.run_parallel_until(opts.search_threads, to)
        } else {
            engine.run_until(to)
        }
    };
    let mut trimmed = false;
    match opts.deadline_s {
        None => engine = run_to(engine, goal),
        Some(deadline) => {
            // chunked stepping: check the request's simulated-time spend
            // between chunks, trim the remaining budget once it crosses
            // the deadline (never mid-chunk, so the tree stays valid at a
            // between-samples point)
            let start = engine.simulated_time_s();
            let chunk = (opts.budget_per_request / 8).max(1);
            while engine.samples() < goal {
                if engine.simulated_time_s() - start >= deadline {
                    trimmed = true;
                    break;
                }
                let next = engine.samples().saturating_add(chunk).min(goal);
                engine = run_to(engine, next);
            }
        }
    }
    let samples = engine.samples();
    let speedup = engine.best_speedup();
    let hits = engine.eval_cache_stats().hits;
    engine.save_file(&registry.tree_path(scenario))?;
    registry.put(scenario, engine)?;
    Ok(ServeReply { resumed, samples, speedup, hits, trimmed })
}

/// Read the persisted incumbent's speedup straight off a snapshot file —
/// the degraded-mode answer when the live engine blew up (no full
/// resume: the file may be the only healthy state left).
fn persisted_speedup(path: &str) -> Option<f64> {
    let v = crate::util::Json::parse_file(path).ok()?;
    let best = crate::util::json::json_bits_f64(&v, "best_latency").ok()?;
    let base = crate::util::json::json_bits_f64(&v, "baseline_latency").ok()?;
    (best > 0.0).then(|| base / best)
}

/// The daemon loop: read scenario names off `input` until EOF, answer
/// each, write one status line per request to `out`. Factored over
/// generic reader/writer so tests drive it with in-memory buffers.
pub fn serve(
    opts: &ServeOpts,
    input: impl BufRead,
    mut out: impl Write,
) -> Result<ServeSummary, String> {
    let mut registry = TreeRegistry::new(&opts.registry_dir, opts.max_trees)?;
    let mut summary = ServeSummary::default();
    // per-scenario speedup of the previous segment, for the self-check
    let mut last_speedup: HashMap<String, f64> = HashMap::new();
    for line in input.lines() {
        let line = line.map_err(|e| format!("serve: stdin: {e}"))?;
        let scenario = line.trim();
        if scenario.is_empty() || scenario.starts_with('#') {
            continue;
        }
        summary.requests += 1;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_one(&mut registry, opts, scenario)
        }));
        match outcome {
            Ok(Ok(r)) => {
                let ServeReply { resumed, samples, speedup, hits, trimmed } = r;
                if resumed {
                    summary.resumed += 1;
                }
                if trimmed {
                    summary.trimmed += 1;
                }
                writeln!(
                    out,
                    "serve {scenario}: tree={} samples={samples} speedup={speedup:.3}x \
                     cache_hits={hits}{}",
                    if resumed { "resumed" } else { "cold" },
                    if trimmed { " deadline=trimmed" } else { "" },
                )
                .map_err(|e| format!("serve: stdout: {e}"))?;
                if opts.expect_warm_on_repeat {
                    if let Some(&prev) = last_speedup.get(scenario) {
                        if !resumed {
                            return Err(format!(
                                "serve self-check: repeated request for {scenario} started cold"
                            ));
                        }
                        if hits == 0 {
                            return Err(format!(
                                "serve self-check: repeated request for {scenario} reported zero \
                                 eval-cache hits"
                            ));
                        }
                        if speedup < prev {
                            return Err(format!(
                                "serve self-check: speedup regressed for {scenario}: \
                                 {speedup:.4} < {prev:.4}"
                            ));
                        }
                    }
                }
                last_speedup.insert(scenario.to_string(), speedup);
            }
            Ok(Err(e)) => {
                summary.errors += 1;
                writeln!(out, "serve {scenario}: error: {e}")
                    .map_err(|e| format!("serve: stdout: {e}"))?;
            }
            Err(_) => {
                // degraded mode: the request's engine blew up mid-search.
                // The engine (taken out of the resident set before the
                // search) is gone, but the registry file persisted by the
                // previous request still holds a valid incumbent — answer
                // from it instead of erroring.
                summary.degraded += 1;
                match persisted_speedup(&registry.tree_path(scenario)) {
                    Some(speedup) => writeln!(
                        out,
                        "serve {scenario}: degraded=engine-panic speedup={speedup:.3}x \
                         (persisted incumbent)"
                    ),
                    None => writeln!(
                        out,
                        "serve {scenario}: degraded=engine-panic speedup=unknown \
                         (no persisted incumbent)"
                    ),
                }
                .map_err(|e| format!("serve: stdout: {e}"))?;
            }
        }
    }
    registry.flush()?;
    summary.evictions = registry.evictions;
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn tmp_dir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!(
            "litecoop_serve_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d.to_string_lossy().into_owned()
    }

    fn quick_opts(dir: &str) -> ServeOpts {
        ServeOpts {
            registry_dir: dir.to_string(),
            max_trees: 2,
            budget_per_request: 24,
            n_llms: 2,
            seed: 11,
            ..ServeOpts::default()
        }
    }

    #[test]
    fn repeated_requests_resume_and_improve() {
        let dir = tmp_dir("repeat");
        let opts = ServeOpts {
            expect_warm_on_repeat: true, // the CI smoke contract, enforced in-test
            ..quick_opts(&dir)
        };
        let input = Cursor::new("gemm\n\n# comment line\ngemm\n");
        let mut out = Vec::new();
        let summary = serve(&opts, input, &mut out).expect("serve loop");
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.resumed, 1);
        assert_eq!(summary.errors, 0);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("tree=cold"), "{}", lines[0]);
        assert!(lines[1].contains("tree=resumed"), "{}", lines[1]);
        assert!(lines[1].contains("samples=48"), "{}", lines[1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn second_process_loads_from_registry_file() {
        let dir = tmp_dir("reload");
        let opts = quick_opts(&dir);
        let mut out = Vec::new();
        serve(&opts, Cursor::new("gemm\n"), &mut out).expect("first daemon");
        // a fresh registry (≅ a fresh daemon process) must resume the
        // persisted tree, not start cold
        let mut out2 = Vec::new();
        let summary = serve(&opts, Cursor::new("gemm\n"), &mut out2).expect("second daemon");
        assert_eq!(summary.resumed, 1);
        let text = String::from_utf8(out2).unwrap();
        assert!(text.contains("tree=resumed"), "{text}");
        assert!(text.contains("samples=48"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_eviction_persists_before_dropping() {
        let dir = tmp_dir("evict");
        let opts = quick_opts(&dir); // max_trees = 2
        let input = Cursor::new("gemm\ngemm@m=128\ngemm@m=256\ngemm\n");
        let mut out = Vec::new();
        let summary = serve(&opts, input, &mut out).expect("serve loop");
        // the third distinct scenario evicts "gemm"; the fourth request
        // reloads it from the registry file it was persisted to
        assert!(summary.evictions >= 1, "{summary:?}");
        assert_eq!(summary.resumed, 1);
        let text = String::from_utf8(out).unwrap();
        assert!(text.lines().last().unwrap().contains("tree=resumed"), "{text}");
        for scenario in ["gemm", "gemm@m=128", "gemm@m=256"] {
            let path = format!("{dir}/{}", tree_file_name(scenario));
            assert!(std::path::Path::new(&path).exists(), "missing {path}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unresolvable_scenario_does_not_kill_the_loop() {
        let dir = tmp_dir("badname");
        let opts = quick_opts(&dir);
        let input = Cursor::new("no_such_workload@x=1\ngemm\n");
        let mut out = Vec::new();
        let summary = serve(&opts, input, &mut out).expect("serve loop");
        assert_eq!(summary.errors, 1);
        assert_eq!(summary.requests, 2);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("error:"), "{text}");
        assert!(text.contains("tree=cold"), "{text}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deadline_trims_incremental_budget_deterministically() {
        let run = |tag: &str| {
            let dir = tmp_dir(tag);
            let opts = ServeOpts {
                // tiny simulated-time allowance: the first chunk always
                // exceeds it, so the request trims well short of the
                // 24-sample budget
                deadline_s: Some(1e-9),
                ..quick_opts(&dir)
            };
            let mut out = Vec::new();
            let summary = serve(&opts, Cursor::new("gemm\n"), &mut out).expect("serve loop");
            let _ = std::fs::remove_dir_all(&dir);
            (summary, String::from_utf8(out).unwrap())
        };
        let (summary, text) = run("deadline_a");
        assert_eq!(summary.trimmed, 1, "{summary:?}");
        assert_eq!(summary.errors, 0);
        assert!(text.contains("deadline=trimmed"), "{text}");
        // trimmed short of the full budget, but the chunk that did run
        // is persisted and reported
        let samples: usize = text
            .split("samples=")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        assert!(samples > 0 && samples < 24, "samples={samples}");
        // simulated time is deterministic, so trimming is too
        let (_, text_b) = run("deadline_b");
        assert_eq!(text, text_b);
    }

    #[test]
    fn chaos_panic_is_contained_and_answered_degraded() {
        let dir = tmp_dir("degraded");
        let opts = quick_opts(&dir);
        // a healthy first request persists an incumbent to the registry
        serve(&opts, Cursor::new("gemm\n"), &mut Vec::new()).expect("healthy serve");
        let chaos = ServeOpts {
            chaos_panic_scenarios: vec!["gemm".to_string()],
            ..opts
        };
        let mut out = Vec::new();
        let summary =
            serve(&chaos, Cursor::new("gemm\ngemm\n"), &mut out).expect("daemon must survive");
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.degraded, 2, "{summary:?}");
        assert_eq!(summary.errors, 0);
        let text = String::from_utf8(out).unwrap();
        for line in text.lines() {
            assert!(line.contains("degraded=engine-panic"), "{line}");
            assert!(line.contains("persisted incumbent"), "{line}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_startup_sweeps_orphaned_tmp_files() {
        let dir = tmp_dir("sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let orphan = format!("{dir}/x.tree.json.tmp.4242");
        let keeper = format!("{dir}/x.tree.json");
        std::fs::write(&orphan, "half-written").unwrap();
        std::fs::write(&keeper, "{}").unwrap();
        TreeRegistry::new(&dir, 2).expect("registry");
        assert!(!std::path::Path::new(&orphan).exists(), "orphan survived startup");
        assert!(std::path::Path::new(&keeper).exists(), "final file must be untouched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tree_file_names_are_sanitized_and_collision_free() {
        let a = tree_file_name("gemm@m=512,n=64");
        assert!(a.ends_with(".tree.json"));
        assert!(!a.contains('@') && !a.contains('=') && !a.contains(','));
        // same sanitized form, different scenarios -> different hashes
        assert_ne!(tree_file_name("gemm@m=1,n=2"), tree_file_name("gemm@m=1.n.2"));
    }
}

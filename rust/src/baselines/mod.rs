//! Baseline searchers the paper compares against.
//!
//! * [`single_llm`] — single-model MCTS (the paper's GPT-5.2 / gpt-5-mini
//!   baselines, i.e. Reasoning-Compiler-style search with one LLM).
//! * [`random_routing`] / [`round_robin_routing`] — the Appendix-G
//!   ablations: same 8-model pool, routing replaced by a static policy.
//! * [`evolutionary`] — an LLM-free MetaSchedule-default stand-in
//!   (evolutionary search with the same cost model) used for sanity
//!   context; no paper table depends on it, but it pins the "no-LLM"
//!   floor.

use crate::costmodel::CostModel;
use crate::llm::registry::{by_name, paper_config};
use crate::llm::ModelSet;
use crate::mcts::evalcache::EvalCache;
use crate::mcts::{Mcts, Routing, SearchConfig, SearchResult};
use crate::schedule::transforms::{apply_sequence, TransformKind};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::util::Rng;

/// Single-LLM MCTS baseline (course alteration is meaningless with one
/// model and is disabled). Honors `cfg.search_threads`: 1 runs the serial
/// engine, >1 the tree-parallel engine ([`Mcts::run_parallel`]).
pub fn single_llm(
    model_name: &str,
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    single_llm_with_cache(model_name, target, root, cfg, workload).0
}

/// [`single_llm`], also handing back the warmed evaluation cache
/// (`cfg.warm_cache` entries ∪ everything this search measured) for
/// persistence across searches or processes.
pub fn single_llm_with_cache(
    model_name: &str,
    target: Target,
    root: Schedule,
    mut cfg: SearchConfig,
    workload: &str,
) -> (SearchResult, EvalCache) {
    let spec = by_name(model_name).unwrap_or_else(|| panic!("unknown model {model_name}"));
    cfg.ca_threshold = None;
    let threads = cfg.search_threads;
    let models = ModelSet::new(vec![spec]);
    Mcts::new(cfg, models, Simulator::new(target), root).run_parallel_with_cache(workload, threads)
}

/// LiteCoOp with the paper's n-model configuration. Honors
/// `cfg.search_threads` like [`single_llm`].
pub fn litecoop(
    n_llms: usize,
    largest: &str,
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    litecoop_with_cache(n_llms, largest, target, root, cfg, workload).0
}

/// [`litecoop`], also handing back the warmed evaluation cache (see
/// [`single_llm_with_cache`]).
pub fn litecoop_with_cache(
    n_llms: usize,
    largest: &str,
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> (SearchResult, EvalCache) {
    let threads = cfg.search_threads;
    let models = ModelSet::new(paper_config(n_llms, largest));
    Mcts::new(cfg, models, Simulator::new(target), root).run_parallel_with_cache(workload, threads)
}

/// Appendix-G ablation: same pool, random next-model routing.
pub fn random_routing(
    n_llms: usize,
    largest: &str,
    target: Target,
    root: Schedule,
    mut cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    cfg.routing = Routing::Random;
    litecoop(n_llms, largest, target, root, cfg, workload)
}

/// Appendix-G ablation: same pool, round-robin next-model routing.
pub fn round_robin_routing(
    n_llms: usize,
    largest: &str,
    target: Target,
    root: Schedule,
    mut cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    cfg.routing = Routing::RoundRobin;
    litecoop(n_llms, largest, target, root, cfg, workload)
}

/// Evolutionary-search baseline (MetaSchedule-default stand-in): mutate a
/// population of schedules, cost-model-rank, measure the elite. Budget,
/// seed, and curve checkpoints come from `cfg` like every other searcher;
/// `cfg.search_threads` is ignored (no tree to parallelize).
pub fn evolutionary(
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> SearchResult {
    let budget = cfg.budget;
    let seed = cfg.seed;
    let checkpoints = cfg.checkpoints;
    let lint_rejects_at_start = crate::analysis::lint_rejects();
    let sim = Simulator::new(target);
    let mut cost = CostModel::new(target, seed);
    let mut rng = Rng::new(seed ^ 0xEE0);
    let gpu = target.is_gpu();
    let vocab = TransformKind::vocabulary(gpu);
    let baseline = cost.measure(&sim, &root);

    let pop_size = 16;
    let mut population: Vec<Schedule> = vec![root.clone(); pop_size];
    let mut best_latency = baseline;
    let mut best_schedule = root.clone();
    let mut samples = 0usize;
    let mut curve = Vec::new();
    let mut measure_time = 0.0;

    while samples < budget {
        // mutate: each member gets 1-3 random transforms
        let mut cands: Vec<Schedule> = Vec::with_capacity(pop_size);
        for p in &population {
            let seq: Vec<_> = (0..1 + rng.below(3)).map(|_| *rng.choice(&vocab)).collect();
            match apply_sequence(p, &seq, &mut rng, gpu) {
                Ok(s) => cands.push(s),
                Err(_) => cands.push(p.clone()),
            }
            samples += 1;
            if samples >= budget {
                break;
            }
        }
        // rank by predicted score, measure the top quarter
        let mut scored: Vec<(f64, usize)> = cands
            .iter()
            .enumerate()
            .map(|(i, s)| (cost.score(s), i))
            .collect();
        scored.sort_by(|a, b| b.0.total_cmp(&a.0));
        for &(_, i) in scored.iter().take(pop_size / 4) {
            let lat = cost.measure(&sim, &cands[i]);
            measure_time += 1.5;
            if lat < best_latency {
                best_latency = lat;
                best_schedule = cands[i].clone();
            }
        }
        // next generation: elite + mutated elite
        population = scored
            .iter()
            .take(pop_size / 2)
            .map(|&(_, i)| cands[i].clone())
            .collect();
        while population.len() < pop_size {
            population.push(best_schedule.clone());
        }
        for &cp in &checkpoints {
            if samples >= cp && !curve.iter().any(|&(s, _)| s == cp) {
                curve.push((cp, baseline / best_latency));
            }
        }
    }
    crate::mcts::fill_missing_checkpoints(&mut curve, &checkpoints, baseline / best_latency);
    SearchResult {
        workload: workload.to_string(),
        best_speedup: baseline / best_latency,
        best_latency_s: best_latency,
        baseline_latency_s: baseline,
        curve,
        compile_time_s: measure_time,
        api_cost_usd: 0.0,
        n_samples: samples,
        n_ca_events: 0,
        n_errors: 0,
        call_counts: vec![],
        eval_cache: crate::mcts::evalcache::CacheStats::default(),
        lint_rejects: crate::analysis::lint_rejects().saturating_sub(lint_rejects_at_start),
        // the LLM-free baseline makes no model calls, so nothing can fault
        faults: crate::llm::faults::FaultReport::default(),
        best_schedule,
    }
}

/// [`evolutionary`] behind the cache-returning searcher surface: the
/// evolutionary baseline never consults the evaluation cache (its cost
/// model measures directly), so the returned cache is empty — it
/// contributes no reusable entries to a sweep's cache file, and any
/// `cfg.warm_cache` is ignored.
pub fn evolutionary_with_cache(
    target: Target,
    root: Schedule,
    cfg: SearchConfig,
    workload: &str,
) -> (SearchResult, EvalCache) {
    (evolutionary(target, root, cfg, workload), EvalCache::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn root() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(512, 512, 512)))
    }

    fn cfg(budget: usize, seed: u64) -> SearchConfig {
        SearchConfig {
            budget,
            seed,
            ..SearchConfig::default()
        }
    }

    #[test]
    fn single_llm_runs_and_improves() {
        let r = single_llm("gpt-5.2", Target::Cpu, root(), cfg(60, 1), "gemm");
        assert!(r.best_speedup > 1.2, "{}", r.best_speedup);
        assert_eq!(r.n_ca_events, 0);
    }

    #[test]
    fn small_single_model_weaker_than_large_on_average() {
        // averaged over seeds, gpt-5-mini alone should not beat gpt-5.2 alone
        let mut big = 0.0;
        let mut small = 0.0;
        for seed in 0..4 {
            big += single_llm("gpt-5.2", Target::Cpu, root(), cfg(80, seed), "g").best_speedup;
            small += single_llm("gpt-5-mini", Target::Cpu, root(), cfg(80, seed), "g").best_speedup;
        }
        assert!(
            big * 1.05 > small,
            "large {big} should be at least comparable to small {small}"
        );
    }

    #[test]
    fn evolutionary_baseline_improves() {
        let r = evolutionary(Target::Cpu, root(), cfg(200, 3), "gemm");
        assert!(r.best_speedup > 1.2, "{}", r.best_speedup);
        assert_eq!(r.api_cost_usd, 0.0);
    }

    #[test]
    fn routing_ablations_spread_calls_evenly() {
        let r = round_robin_routing(8, "gpt-5.2", Target::Cpu, root(), cfg(120, 4), "gemm");
        let counts: Vec<usize> = r.call_counts.iter().map(|(_, a, _)| *a).collect();
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().filter(|&&c| c > 0).min().unwrap_or(&1) as f64;
        assert!(max / min < 4.0, "round-robin spread too uneven: {counts:?}");
    }
}

//! Small CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `prog [subcommand] [--flag] [--key value]... [positional]...`
//! Both `--key value` and `--key=value` are accepted.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // value-taking if the next token isn't another flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("fig2 --budget 500 --target=gpu out.md --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.usize_or("budget", 0), 500);
        assert_eq!(a.str_or("target", ""), "gpu");
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["out.md"]);
    }

    #[test]
    fn bare_flag_at_end() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.subcommand, None);
        assert_eq!(a.f64_or("lambda", 0.5), 0.5);
    }

    #[test]
    fn negative_number_values() {
        // a negative value is not a flag
        let a = parse("x --offset -3");
        assert_eq!(a.str_or("offset", ""), "-3");
    }
}

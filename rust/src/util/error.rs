//! Minimal error type — the `anyhow` substitute for this offline build
//! (DESIGN.md §Substitutions).
//!
//! A single string-carrying error is all the crate needs: errors here are
//! terminal diagnostics for a CLI / experiment harness, never matched on.
//! The [`err!`](crate::err) macro builds one with `format!` syntax, and
//! [`Context`] adds `anyhow::Context`-style annotation to any
//! `Result<_, E: Display>`.

use std::fmt;

/// A boxed-string error.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error::new(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error::new(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::new(e.to_string())
    }
}

/// `anyhow::Context`-style annotation: prefix an error with what was being
/// attempted when it occurred.
pub trait Context<T> {
    fn context(self, what: &str) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context(self, what: &str) -> Result<T, Error> {
        self.map_err(|e| Error::new(format!("{what}: {e}")))
    }
}

/// Build an [`Error`] with `format!` syntax (the `anyhow!` substitute).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::new(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message() {
        let e = err!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn context_prefixes() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
    }

    #[test]
    fn converts_from_string() {
        let e: Error = "boom".into();
        assert_eq!(e.to_string(), "boom");
        let e: Error = String::from("boom2").into();
        assert_eq!(e.to_string(), "boom2");
    }
}

//! Markdown / aligned-text table rendering for experiment reports.

/// A simple column-aligned table builder. Emits GitHub-flavored markdown.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push(' ');
                s.push_str(&format!("{:width$}", cells[i], width = widths[i]));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio like the paper: `1.95` (two decimals).
pub fn ratio(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a pair of GPU/CPU values like the paper: `1.85/1.48`.
pub fn pair(gpu: f64, cpu: f64) -> String {
    format!("{gpu:.2}/{cpu:.2}")
}

/// Format a percentage like the paper: `23.1`.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Benchmark", "Speedup"]);
        t.row(vec!["llama3-attn".into(), "30.1".into()]);
        t.row(vec!["moe".into(), "10.9".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| Benchmark   | Speedup |"));
        assert!(md.contains("| llama3-attn | 30.1    |"));
        assert!(md.starts_with("**Demo**"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ratio(1.9512), "1.95");
        assert_eq!(pair(1.85, 1.48), "1.85/1.48");
        assert_eq!(pct(0.231), "23.1");
    }
}

//! Filesystem hygiene helpers shared by the persistence layers.
//!
//! Every atomic save in this codebase (tree snapshots, eval-cache files)
//! writes to a pid-suffixed sibling — `<final>.tmp.<pid>` — then renames
//! over the target. A crash between the write and the rename strands the
//! temp file forever: the pid is gone, no writer will ever come back for
//! it, and a directory that serves long-lived daemons slowly fills with
//! dead bytes. [`sweep_orphan_tmp`] and [`sweep_orphan_tmp_dir`] reclaim
//! them on startup/load, warning on stderr once per file so operators see
//! the evidence of the crash that produced it.
//!
//! Only filenames matching the exact convention — a `.tmp.` infix whose
//! suffix is all decimal digits — are touched; anything else in the
//! directory is left alone.

use std::path::Path;

/// True iff `name` looks like one of our atomic-save temp files:
/// `<stem>.tmp.<digits>`.
fn is_tmp_name(name: &str) -> bool {
    match name.rfind(".tmp.") {
        Some(i) => {
            let suffix = &name[i + ".tmp.".len()..];
            !suffix.is_empty() && suffix.bytes().all(|b| b.is_ascii_digit())
        }
        None => false,
    }
}

/// Remove orphaned `<final_path>.tmp.<pid>` siblings left behind by a
/// writer that crashed between write and rename. Returns the number of
/// files removed; each removal is announced with a stderr warning. I/O
/// errors (unreadable directory, racing unlink) are swallowed — hygiene
/// must never take the caller down.
pub fn sweep_orphan_tmp(final_path: &str) -> usize {
    let p = Path::new(final_path);
    let dir = p.parent().filter(|d| !d.as_os_str().is_empty());
    let stem = match p.file_name().and_then(|n| n.to_str()) {
        Some(s) => s,
        None => return 0,
    };
    let entries = match std::fs::read_dir(dir.unwrap_or(Path::new("."))) {
        Ok(e) => e,
        Err(_) => return 0,
    };
    let mut removed = 0;
    let mut names: Vec<String> = entries
        .flatten()
        .filter_map(|e| e.file_name().to_str().map(String::from))
        .filter(|n| n.starts_with(stem) && is_tmp_name(n) && n[stem.len()..].starts_with(".tmp."))
        .collect();
    names.sort();
    for name in names {
        let path = dir.map_or_else(|| Path::new(&name).to_path_buf(), |d| d.join(&name));
        if std::fs::remove_file(&path).is_ok() {
            eprintln!(
                "warning: removed orphaned checkpoint temp file {} (writer died mid-save)",
                path.display()
            );
            removed += 1;
        }
    }
    removed
}

/// [`sweep_orphan_tmp`] over a whole directory: every `*.tmp.<digits>`
/// file is an orphan by definition (live writers rename within the same
/// call that created them). Used by registry startup, where the set of
/// final paths isn't known until requests arrive.
pub fn sweep_orphan_tmp_dir(dir: &str) -> usize {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return 0,
    };
    let mut removed = 0;
    let mut names: Vec<String> = entries
        .flatten()
        .filter_map(|e| e.file_name().to_str().map(String::from))
        .filter(|n| is_tmp_name(n))
        .collect();
    names.sort();
    for name in names {
        let path = Path::new(dir).join(&name);
        if std::fs::remove_file(&path).is_ok() {
            eprintln!(
                "warning: removed orphaned checkpoint temp file {} (writer died mid-save)",
                path.display()
            );
            removed += 1;
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("fsx_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d.to_str().unwrap().to_string()
    }

    #[test]
    fn tmp_name_convention() {
        assert!(is_tmp_name("tree.json.tmp.1234"));
        assert!(is_tmp_name("cache.tmp.7"));
        assert!(!is_tmp_name("tree.json"));
        assert!(!is_tmp_name("tree.json.tmp."));
        assert!(!is_tmp_name("tree.json.tmp.12a4"));
        assert!(!is_tmp_name("tmp.1234.json"));
    }

    #[test]
    fn sweeps_only_matching_siblings() {
        let d = tdir("sib");
        let fin = format!("{d}/tree.json");
        std::fs::write(&fin, "{}").unwrap();
        std::fs::write(format!("{d}/tree.json.tmp.999"), "junk").unwrap();
        std::fs::write(format!("{d}/tree.json.tmp.abc"), "keep").unwrap();
        std::fs::write(format!("{d}/other.json.tmp.999"), "keep").unwrap();
        assert_eq!(sweep_orphan_tmp(&fin), 1);
        assert!(std::path::Path::new(&fin).exists());
        assert!(!std::path::Path::new(&format!("{d}/tree.json.tmp.999")).exists());
        assert!(std::path::Path::new(&format!("{d}/tree.json.tmp.abc")).exists());
        assert!(std::path::Path::new(&format!("{d}/other.json.tmp.999")).exists());
        assert_eq!(sweep_orphan_tmp(&fin), 0, "second sweep finds nothing");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn dir_sweep_reclaims_all_orphans() {
        let d = tdir("dir");
        std::fs::write(format!("{d}/a.json"), "{}").unwrap();
        std::fs::write(format!("{d}/a.json.tmp.11"), "x").unwrap();
        std::fs::write(format!("{d}/b.json.tmp.22"), "y").unwrap();
        std::fs::write(format!("{d}/notes.txt"), "z").unwrap();
        assert_eq!(sweep_orphan_tmp_dir(&d), 2);
        assert!(std::path::Path::new(&format!("{d}/a.json")).exists());
        assert!(std::path::Path::new(&format!("{d}/notes.txt")).exists());
        assert_eq!(sweep_orphan_tmp_dir(&d), 0);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn missing_dir_is_harmless() {
        assert_eq!(sweep_orphan_tmp_dir("/nonexistent/definitely/not/here"), 0);
        assert_eq!(sweep_orphan_tmp("/nonexistent/definitely/not/here/t.json"), 0);
    }
}

//! Minimal JSON: a value type, a writer, and a recursive-descent parser.
//!
//! Used for the artifacts manifest (read) and experiment reports (write).
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for our machine-generated inputs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — programmer error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Read and parse a JSON file, prefixing errors with the path (the
    /// common shape for "cache file X: bad entry key" diagnostics).
    pub fn parse_file(path: &str) -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }

    pub fn from_f64(x: f64) -> Json {
        Json::Num(x)
    }
}

/// Encode an `f64` **exactly** as the decimal string of its IEEE-754 bit
/// pattern. `Json::Num` is lossy for engine state: the writer's integer
/// fast path collapses `-0.0` to `0`, and JSON has no NaN/±inf at all.
/// Persistence code (tree snapshots, cost-model state) uses this form
/// wherever bit-for-bit round-tripping is load-bearing.
pub fn f64_to_bits_json(x: f64) -> Json {
    Json::Str(format!("{}", x.to_bits()))
}

/// Decode a bits-string produced by [`f64_to_bits_json`].
pub fn f64_from_bits_json(v: &Json) -> Result<f64, String> {
    let s = v
        .as_str()
        .ok_or_else(|| "expected f64 bits string".to_string())?;
    let bits: u64 = s
        .parse()
        .map_err(|_| format!("bad f64 bits string {s:?}"))?;
    Ok(f64::from_bits(bits))
}

/// Fetch object field `key` as a non-negative integer. Persistence
/// loaders use these accessors so every missing/mistyped field becomes a
/// named `Err` (degrading to a cold start) instead of a panic.
pub fn json_usize(v: &Json, key: &str) -> Result<usize, String> {
    let n = v
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing field {key:?}"))?;
    if n.fract() != 0.0 || !(0.0..=9e15).contains(&n) {
        return Err(format!("field {key:?}: bad integer {n}"));
    }
    Ok(n as usize)
}

/// Fetch object field `key` as a `u64` stored in decimal-string form
/// (full 64-bit range; `Json::Num` only holds 53 exact bits).
pub fn json_u64_str(v: &Json, key: &str) -> Result<u64, String> {
    let s = v
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| format!("missing field {key:?}"))?;
    s.parse()
        .map_err(|_| format!("field {key:?}: bad u64 string {s:?}"))
}

/// Fetch object field `key` as an exact f64 bits-string
/// (see [`f64_to_bits_json`]).
pub fn json_bits_f64(v: &Json, key: &str) -> Result<f64, String> {
    f64_from_bits_json(
        v.get(key)
            .ok_or_else(|| format!("missing field {key:?}"))?,
    )
    .map_err(|e| format!("field {key:?}: {e}"))
}

/// Encode a `u64` slice as an array of decimal strings (full 64-bit
/// range — RNG stream positions, trace hashes).
pub fn u64_str_arr_json(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(x.to_string())).collect())
}

/// Fetch object field `key` as an array of decimal-string `u64`s
/// (see [`u64_str_arr_json`]).
pub fn json_u64_str_arr(v: &Json, key: &str) -> Result<Vec<u64>, String> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("missing array {key:?}"))?;
    arr.iter()
        .map(|x| {
            let s = x
                .as_str()
                .ok_or_else(|| format!("array {key:?}: non-string"))?;
            s.parse()
                .map_err(|_| format!("array {key:?}: bad u64 string {s:?}"))
        })
        .collect()
}

/// Fetch object field `key` as an array of non-negative `u32` indices.
pub fn json_u32_arr(v: &Json, key: &str) -> Result<Vec<u32>, String> {
    let arr = v
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("missing array {key:?}"))?;
    arr.iter()
        .map(|x| {
            let n = x.as_f64().ok_or_else(|| format!("array {key:?}: non-number"))?;
            if n.fract() != 0.0 || !(0.0..=u32::MAX as f64).contains(&n) {
                return Err(format!("array {key:?}: bad index {n}"));
            }
            Ok(n as u32)
        })
        .collect()
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_val(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..n {
                out.push_str("  ");
            }
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                    if pretty {
                        out.push(' ');
                    }
                }
                write_val(item, out, indent, false);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_val(val, out, indent + 1, pretty);
            }
            pad(out, indent);
            out.push('}');
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_val(self, &mut s, 0, true);
        write!(f, "{s}")
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad utf8")?,
                                16,
                            )
                            .map_err(|_| "bad hex")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk =
                        std::str::from_utf8(&rest[..ch_len.min(rest.len())]).map_err(|_| "utf8")?;
                    s.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", "llama".into())
            .set("speedup", 30.1.into())
            .set("ok", true.into())
            .set("arr", Json::Arr(vec![1.0.into(), 2.0.into()]));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"llama4_mlp": {"hlo": "llama4_mlp.hlo.txt",
            "args": [{"shape": [128, 256], "dtype": "float32"}]}}"#;
        let j = Json::parse(text).unwrap();
        let entry = j.get("llama4_mlp").unwrap();
        assert_eq!(entry.get("hlo").unwrap().as_str(), Some("llama4_mlp.hlo.txt"));
        let shape = entry.get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_f64(), Some(128.0));
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{unquoted: 1}").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn numbers() {
        let j = Json::parse("[-1.5e3, 0, 42]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[2].as_f64(), Some(42.0));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éx""#).unwrap();
        assert_eq!(j.as_str(), Some("éx"));
    }

    #[test]
    fn f64_bits_roundtrip_is_exact() {
        for x in [
            0.0,
            -0.0,
            1.5,
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            -3.141592653589793,
        ] {
            let j = f64_to_bits_json(x);
            let text = j.to_string();
            let back = f64_from_bits_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(x.to_bits(), back.to_bits(), "{x}");
        }
        assert!(f64_from_bits_json(&Json::Num(1.0)).is_err());
        assert!(f64_from_bits_json(&Json::Str("xyz".into())).is_err());
    }

    #[test]
    fn parse_file_reports_path_in_errors() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("litecoop_json_parse_file_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(&path, "{\"a\": 1}\n").unwrap();
        assert_eq!(
            Json::parse_file(&path).unwrap().get("a").unwrap().as_f64(),
            Some(1.0)
        );
        std::fs::write(&path, "{oops").unwrap();
        let err = Json::parse_file(&path).unwrap_err();
        assert!(err.contains(&path), "{err}");
        std::fs::remove_file(&path).unwrap();
        let err = Json::parse_file(&path).unwrap_err();
        assert!(err.contains(&path), "{err}");
    }
}

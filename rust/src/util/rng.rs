//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component in the system (LLM proposal noise, MCTS
//! rollouts, tile-size sampling, Monte-Carlo Dunnett adjustment) draws from
//! this generator so experiments are exactly reproducible from a seed.

/// xoshiro256** with SplitMix64 seeding. Not cryptographic; fast, with
/// 256-bit state and full 2^256-1 period — the same generator family the
/// `rand` crate uses for small-state simulation work.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// One SplitMix64 step: advance `state` by the golden-gamma increment and
/// return a scrambled output. The crate's single source of truth for this
/// scramble (seeding here, lane derivation in the parallel driver).
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-thread / per-component
    /// determinism regardless of interleaving).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw 256-bit stream position, for search-state persistence
    /// (tree snapshots). `Rng::from_state(r.state())` continues the
    /// stream exactly where `r` stands.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact stream position captured by
    /// [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free for our purposes (bias < 2^-53 * n)
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.f64() * (hi - lo + 1) as f64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniformly pick a reference from a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are ~0.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 1e-12 {
            return self.below(weights.len());
        }
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Softmax-sample an index from scores at the given temperature.
    /// Lower temperature -> greedier.
    pub fn softmax_sample(&mut self, scores: &[f64], temperature: f64) -> usize {
        let t = temperature.max(1e-6);
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = scores.iter().map(|s| ((s - m) / t).exp()).collect();
        self.weighted(&ws)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(6);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > 4000, "{counts:?}");
    }

    #[test]
    fn softmax_greedy_at_low_temperature() {
        let mut r = Rng::new(8);
        let scores = [0.1, 0.9, 0.3];
        let picks = (0..200)
            .filter(|_| r.softmax_sample(&scores, 0.01) == 1)
            .count();
        assert!(picks > 195);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(11);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}

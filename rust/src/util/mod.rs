//! Self-implemented utility substrates.
//!
//! This build environment is fully offline and the crate is
//! dependency-free. Everything a project of this shape would normally pull
//! from crates.io — a PRNG (`rand`), JSON (`serde_json`), config parsing
//! (`toml`), CLI parsing (`clap`), error plumbing (`anyhow`) — is
//! implemented here from scratch, tested, and treated as a first-class
//! substrate (DESIGN.md §Substitutions). Real PJRT execution (the `xla`
//! crate) is gated behind the optional `pjrt` cargo feature; see
//! [`crate::runtime`].

pub mod error;
pub mod fnv;
pub mod fsx;
pub mod rng;
pub mod json;
pub mod tomlmini;
pub mod cli;
pub mod table;

pub use error::Error;
pub use rng::Rng;
pub use json::Json;

//! Self-implemented utility substrates.
//!
//! This build environment is fully offline: the only third-party crates
//! available are the vendored closure of the `xla` crate. Everything a
//! project of this shape would normally pull from crates.io — a PRNG
//! (`rand`), JSON (`serde_json`), config parsing (`toml`), CLI parsing
//! (`clap`) — is implemented here from scratch, tested, and treated as a
//! first-class substrate (DESIGN.md §Substitutions).

pub mod rng;
pub mod json;
pub mod tomlmini;
pub mod cli;
pub mod table;

pub use rng::Rng;
pub use json::Json;

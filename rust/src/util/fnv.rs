//! FNV-1a hashing primitives — the one hash the incremental-key machinery
//! speaks everywhere: trace running hashes ([`crate::schedule::trace`]),
//! evaluation-cache keys ([`crate::mcts::evalcache::trace_key`]), the
//! per-block / per-workload structural fingerprints, and the block-level
//! simulation memo ([`crate::sim::blockcache`]). Living in `util` keeps
//! the dependency direction clean: `tir` and `sim` fold fingerprints
//! without reaching up into the schedule layer.
//!
//! All folds are deterministic across runs, platforms, and processes (no
//! randomized hasher state), which is what lets fingerprint-derived keys
//! be compared against values produced on other threads or persisted to
//! disk.

/// FNV-1a offset basis — also the running hash of an empty trace and the
/// seed state for every structural fingerprint.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold a string into an FNV-1a state, with a field separator so
/// ("ab","c") and ("a","bc") hash differently.
pub fn fnv_str(mut h: u64, s: &str) -> u64 {
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h ^= 0x1f;
    h.wrapping_mul(FNV_PRIME)
}

/// Fold a u64 into an FNV-1a state byte by byte.
pub fn fnv_u64(mut h: u64, x: u64) -> u64 {
    for i in 0..8 {
        h ^= (x >> (8 * i)) & 0xff;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Fold an i64 (two's-complement bits) into an FNV-1a state.
pub fn fnv_i64(h: u64, x: i64) -> u64 {
    fnv_u64(h, x as u64)
}

/// Fold an f64's exact bit pattern into an FNV-1a state (fingerprints
/// must distinguish values that simulate differently, bit for bit).
pub fn fnv_f64(h: u64, x: f64) -> u64 {
    fnv_u64(h, x.to_bits())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_are_deterministic_and_separated() {
        assert_eq!(fnv_str(FNV_OFFSET, "ab"), fnv_str(FNV_OFFSET, "ab"));
        // field separation: ("ab","c") != ("a","bc")
        assert_ne!(
            fnv_str(fnv_str(FNV_OFFSET, "ab"), "c"),
            fnv_str(fnv_str(FNV_OFFSET, "a"), "bc")
        );
        assert_ne!(fnv_u64(FNV_OFFSET, 1), fnv_u64(FNV_OFFSET, 2));
        assert_eq!(fnv_i64(FNV_OFFSET, -1), fnv_u64(FNV_OFFSET, u64::MAX));
        assert_eq!(fnv_f64(FNV_OFFSET, 1.5), fnv_u64(FNV_OFFSET, 1.5f64.to_bits()));
        // -0.0 and 0.0 have different bit patterns and must hash apart
        assert_ne!(fnv_f64(FNV_OFFSET, 0.0), fnv_f64(FNV_OFFSET, -0.0));
    }
}

//! Minimal TOML-subset parser for experiment config files.
//!
//! Supports: `[section]` and `[section.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and flat arrays, plus `#` comments.
//! This covers the entire configuration grammar the coordinator uses; it is
//! not a general TOML implementation (no multi-line strings, no inline
//! tables, no datetime).

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path -> value, where keys inside `[section]`
/// become `section.key`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                let end = line
                    .find(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = line[1..end].trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn str_list(&self, path: &str) -> Vec<String> {
        self.get(path)
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default()
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            return Err(format!("unterminated string: {s}"));
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].replace("\\\"", "\"")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(format!("unterminated array: {s}"));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split on commas not inside quotes (arrays are flat; no nesting needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
lambda = 0.5

[search]
budget = 1000
branching = 2
exploration = 1.4142  # sqrt(2)
course_alteration = true

[llms]
models = ["gpt-5.2", "gpt-5-mini", "qwen3-8b"]
largest = "gpt-5.2"
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.i64_or("seed", 0), 42);
        assert_eq!(doc.f64_or("lambda", 0.0), 0.5);
        assert_eq!(doc.i64_or("search.budget", 0), 1000);
        assert!(doc.bool_or("search.course_alteration", false));
        assert_eq!(doc.str_or("llms.largest", ""), "gpt-5.2");
        assert_eq!(
            doc.str_list("llms.models"),
            vec!["gpt-5.2", "gpt-5-mini", "qwen3-8b"]
        );
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "x"), "x");
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse(r##"k = "a#b" # trailing"##).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("just words").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("[unclosed").is_err());
    }

    #[test]
    fn float_and_int_coercion() {
        let doc = TomlDoc::parse("a = 3").unwrap();
        assert_eq!(doc.f64_or("a", 0.0), 3.0);
    }
}

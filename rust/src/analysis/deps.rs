//! Dependence and race analysis: footprint-based classification of the
//! *materialized* loop nest.
//!
//! The footprint argument: a loop may run its iterations concurrently
//! (Parallel / BlockIdx / ThreadIdx) or in lockstep lanes (Vectorized)
//! only if distinct iterations touch distinct output elements — i.e.
//! the loop's axis appears in **every** write access of the block. An
//! axis missing from a write (a reduction axis in its natural encoding,
//! or a mislabeled spatial axis) makes concurrent iterations store to
//! the same element: a write-write race. `DecomposeReduction` splits
//! the init out of the update loop and switches the accumulation to a
//! legalized pattern, which is the one sanctioned escape hatch.
//!
//! These lints read the materialized [`LoopNest`], not the raw
//! annotation counters: the materializer already refuses to hand
//! parallel-ish kinds to `AxisKind::Reduction` axes, so an annotation
//! *window* covering a reduction position is merely degenerate
//! ([`AnnotationOnReductionPosition`], Warn) — the Deny arm fires only
//! when a genuinely racy loop would be emitted.

use super::{Diagnostic, Lint, LintCtx, Severity};
use crate::schedule::LoopKind;
use crate::tir::AxisKind;

fn concurrent(kind: LoopKind) -> bool {
    matches!(
        kind,
        LoopKind::Parallel | LoopKind::BlockIdx | LoopKind::ThreadIdx | LoopKind::Vectorized
    )
}

/// Deny: a concurrent/vector loop whose axis does not cover every write
/// of its block (write-write race) without a preceding
/// `DecomposeReduction`.
pub struct RaceOnReductionAxis;

impl Lint for RaceOnReductionAxis {
    fn code(&self) -> &'static str {
        "race-on-reduction-axis"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let Some(nest) = ctx.nest(b) else { continue };
            if ctx.block(b).decomposed {
                continue;
            }
            let blk = &w.blocks[b];
            for l in &nest.loops {
                if !concurrent(l.kind) {
                    continue;
                }
                let racy = blk.axes[l.axis].kind == AxisKind::Reduction
                    || blk.writes.iter().any(|wr| !wr.uses_axis(l.axis));
                if racy {
                    sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Deny,
                        block: b,
                        axis: Some(l.axis),
                        message: format!(
                            "{}: {:?} loop on axis {} does not cover every write — \
                             concurrent iterations store to the same element \
                             (write-write race); DecomposeReduction must precede it",
                            blk.name, l.kind, blk.axes[l.axis].name
                        ),
                    });
                }
            }
        }
    }
}

/// Deny: `compute_at` set on a block no other block consumes — there is
/// no loop nest to fuse into, so the dependence edge the fusion claims
/// does not exist.
pub struct FusionWithoutConsumer;

impl Lint for FusionWithoutConsumer {
    fn code(&self) -> &'static str {
        "fusion-without-consumer"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            if ctx.block(b).compute_at.is_some() && ctx.consumers[b].is_empty() {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Deny,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: compute_at set but no block consumes its output — \
                         nothing to fuse into",
                        w.blocks[b].name
                    ),
                });
            }
        }
    }
}

/// Deny: `compute_at` deeper than the consumer's loop nest. Hoisting
/// the producer to a depth that does not exist means its write would be
/// re-executed under loops that never iterate it consistently — the
/// consumer reads values the producer has not written at that point.
pub struct FusionDepthOutOfRange;

impl Lint for FusionDepthOutOfRange {
    fn code(&self) -> &'static str {
        "fusion-depth-out-of-range"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let Some(d) = ctx.block(b).compute_at else { continue };
            let Some(&c) = ctx.consumers[b].first() else { continue };
            let n = ctx.block(c).n_loops();
            if d >= n {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Deny,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: fused at depth {d} but consumer {} has only {n} loops",
                        w.blocks[b].name, w.blocks[c].name
                    ),
                });
            }
        }
    }
}

/// Warn: a parallel/thread window or vectorize position lands on a
/// reduction axis. The materializer silently neutralizes it (the loop
/// stays serial), so the annotation is dead weight — usually a sign
/// the proposal wanted a reorder or a `DecomposeReduction` first.
pub struct AnnotationOnReductionPosition;

impl Lint for AnnotationOnReductionPosition {
    fn code(&self) -> &'static str {
        "annotation-on-reduction-position"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            if ctx.nest(b).is_none() {
                continue; // structurally corrupt; structural lints own it
            }
            let bs = ctx.block(b);
            let blk = &w.blocks[b];
            let n = bs.order.len();
            for (pos, &(axis, _)) in bs.order.iter().enumerate() {
                if blk.axes[axis].kind != AxisKind::Reduction {
                    continue;
                }
                let which = if pos < bs.parallel {
                    "parallel"
                } else if ctx.gpu && pos < bs.parallel + bs.thread_tiles {
                    "thread-bind"
                } else if bs.vectorize && pos + 1 == n {
                    "vectorize"
                } else {
                    continue;
                };
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Warn,
                    block: b,
                    axis: Some(axis),
                    message: format!(
                        "{}: {which} annotation at position {pos} lands on reduction \
                         axis {} and is ignored (loop stays serial)",
                        blk.name, blk.axes[axis].name
                    ),
                });
            }
        }
    }
}

/// Warn: the vectorized loop's axis is not stride-1 in every write —
/// lanes scatter instead of storing contiguously, so the vector
/// annotation buys little and may pessimize.
pub struct NonContiguousVectorization;

impl Lint for NonContiguousVectorization {
    fn code(&self) -> &'static str {
        "non-contiguous-vectorization"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let Some(nest) = ctx.nest(b) else { continue };
            let blk = &w.blocks[b];
            for l in &nest.loops {
                if l.kind != LoopKind::Vectorized {
                    continue;
                }
                if !blk.writes.iter().all(|wr| wr.axis_is_contiguous(l.axis)) {
                    sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Warn,
                        block: b,
                        axis: Some(l.axis),
                        message: format!(
                            "{}: vectorized axis {} is not stride-1 in every write — \
                             lanes scatter (strided stores)",
                            blk.name, blk.axes[l.axis].name
                        ),
                    });
                }
            }
        }
    }
}

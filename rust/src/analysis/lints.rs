//! Structural and degenerate-annotation lints.
//!
//! The structural checks are the historical `Workload::validate` /
//! `BlockSched::validate` logic re-homed into the lint framework (those
//! methods now delegate here — see [`super::workload_error`] and
//! [`super::block_structure_error`] — so legality has one source of
//! truth). Message texts are kept byte-identical to the historical
//! errors so delegating callers observe no change.
//!
//! The degenerate checks flag legal-but-useless annotations: they are
//! Warn-severity because ordinary transform sequences can reach them
//! (the search is allowed to *try* a pointless parallelization; the
//! simulator prices it), but the `lint_audit` table surfaces how often.

use super::{Diagnostic, Lint, LintCtx, Severity};
use crate::schedule::BlockSched;
use crate::tir::{BlockDef, Workload};

// ---------------------------------------------------------------------------
// workload scope (Deny)
// ---------------------------------------------------------------------------

/// Deny: access arity disagrees with its buffer's rank (or the buffer
/// index is out of range).
pub struct AccessRankMismatch;

impl Lint for AccessRankMismatch {
    fn code(&self) -> &'static str {
        "access-rank-mismatch"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_workload(&self, w: &Workload, sink: &mut dyn FnMut(Diagnostic)) {
        for (bi, blk) in w.blocks.iter().enumerate() {
            for acc in blk.reads.iter().chain(blk.writes.iter()) {
                match w.buffers.get(acc.buffer) {
                    None => sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Deny,
                        block: bi,
                        axis: None,
                        message: format!("block {}: buffer idx out of range", blk.name),
                    }),
                    Some(buf) if acc.dim_axes.len() != buf.shape.len() => sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Deny,
                        block: bi,
                        axis: None,
                        message: format!(
                            "block {}: access rank {} != buffer {} rank {}",
                            blk.name,
                            acc.dim_axes.len(),
                            buf.name,
                            buf.shape.len()
                        ),
                    }),
                    _ => {}
                }
            }
        }
    }
}

/// Deny: an access indexes a block axis that does not exist.
pub struct AxisIndexOutOfRange;

impl Lint for AxisIndexOutOfRange {
    fn code(&self) -> &'static str {
        "axis-index-out-of-range"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_workload(&self, w: &Workload, sink: &mut dyn FnMut(Diagnostic)) {
        for (bi, blk) in w.blocks.iter().enumerate() {
            for acc in blk.reads.iter().chain(blk.writes.iter()) {
                for dims in &acc.dim_axes {
                    for &ax in dims {
                        if ax >= blk.axes.len() {
                            sink(Diagnostic {
                                code: self.code(),
                                severity: Severity::Deny,
                                block: bi,
                                axis: None,
                                message: format!("block {}: axis idx {} oob", blk.name, ax),
                            });
                        }
                    }
                }
            }
        }
    }
}

/// Deny: a block that writes nothing computes nothing observable.
pub struct BlockWithoutWrites;

impl Lint for BlockWithoutWrites {
    fn code(&self) -> &'static str {
        "block-without-writes"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_workload(&self, w: &Workload, sink: &mut dyn FnMut(Diagnostic)) {
        for (bi, blk) in w.blocks.iter().enumerate() {
            if blk.writes.is_empty() {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Deny,
                    block: bi,
                    axis: None,
                    message: format!("block {}: no writes", blk.name),
                });
            }
        }
    }
}

/// Deny: a producer edge that is not earlier in topo order (cycles and
/// forward references both land here).
pub struct ProducerOrderViolation;

impl Lint for ProducerOrderViolation {
    fn code(&self) -> &'static str {
        "producer-order-violation"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_workload(&self, w: &Workload, sink: &mut dyn FnMut(Diagnostic)) {
        for (bi, blk) in w.blocks.iter().enumerate() {
            for &p in &blk.producers {
                if p >= bi {
                    sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Deny,
                        block: bi,
                        axis: None,
                        message: format!(
                            "block {}: producer {} not earlier in topo order",
                            blk.name, p
                        ),
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// schedule scope, structural (Deny)
// ---------------------------------------------------------------------------
// The four check_* functions below are shared between the Lint impls
// (full sweeps) and `super::block_structure_error` (the validate()
// delegation path, which runs them in the historical order).

pub(crate) fn check_tile_arity(
    bs: &BlockSched,
    blk: &BlockDef,
    block: usize,
    sink: &mut dyn FnMut(Diagnostic),
) {
    if bs.tiles.len() != blk.axes.len() {
        sink(Diagnostic {
            code: TileArityMismatch.code(),
            severity: Severity::Deny,
            block,
            axis: None,
            message: format!("{}: tiles len mismatch", blk.name),
        });
    }
}

pub(crate) fn check_tile_products(
    bs: &BlockSched,
    blk: &BlockDef,
    block: usize,
    sink: &mut dyn FnMut(Diagnostic),
) {
    for (ai, (t, ax)) in bs.tiles.iter().zip(&blk.axes).enumerate() {
        let prod: i64 = t.iter().product();
        if prod != ax.extent {
            sink(Diagnostic {
                code: TileProductMismatch.code(),
                severity: Severity::Deny,
                block,
                axis: Some(ai),
                message: format!(
                    "{}: axis {ai} factors {:?} product {} != extent {}",
                    blk.name, t, prod, ax.extent
                ),
            });
        }
        if t.iter().any(|&f| f < 1) {
            sink(Diagnostic {
                code: TileProductMismatch.code(),
                severity: Severity::Deny,
                block,
                axis: Some(ai),
                message: format!("{}: axis {ai} non-positive factor", blk.name),
            });
        }
    }
}

pub(crate) fn check_loop_order(
    bs: &BlockSched,
    blk: &BlockDef,
    block: usize,
    sink: &mut dyn FnMut(Diagnostic),
) {
    let want: usize = bs.tiles.iter().map(Vec::len).sum();
    if bs.order.len() != want {
        sink(Diagnostic {
            code: LoopOrderInvalid.code(),
            severity: Severity::Deny,
            block,
            axis: None,
            message: format!("{}: order len {} != {}", blk.name, bs.order.len(), want),
        });
    }
    let mut seen = std::collections::BTreeSet::new();
    for &(a, l) in &bs.order {
        if a >= bs.tiles.len() || l >= bs.tiles[a].len() {
            sink(Diagnostic {
                code: LoopOrderInvalid.code(),
                severity: Severity::Deny,
                block,
                axis: None,
                message: format!("{}: order entry ({a},{l}) oob", blk.name),
            });
            continue;
        }
        if !seen.insert((a, l)) {
            sink(Diagnostic {
                code: LoopOrderInvalid.code(),
                severity: Severity::Deny,
                block,
                axis: None,
                message: format!("{}: duplicate order entry ({a},{l})", blk.name),
            });
        }
    }
}

pub(crate) fn check_cache_read_arity(
    bs: &BlockSched,
    blk: &BlockDef,
    block: usize,
    sink: &mut dyn FnMut(Diagnostic),
) {
    if bs.cache_reads.len() != blk.reads.len() {
        sink(Diagnostic {
            code: CacheReadArityMismatch.code(),
            severity: Severity::Deny,
            block,
            axis: None,
            message: format!("{}: cache_reads len mismatch", blk.name),
        });
    }
}

macro_rules! structural_lint {
    ($name:ident, $code:literal, $check:ident, $doc:literal) => {
        #[doc = $doc]
        pub struct $name;

        impl Lint for $name {
            fn code(&self) -> &'static str {
                $code
            }
            fn severity(&self) -> Severity {
                Severity::Deny
            }
            fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
                let w = &ctx.sched.workload;
                for b in 0..w.blocks.len() {
                    $check(ctx.block(b), &w.blocks[b], b, sink);
                }
            }
        }
    };
}

structural_lint!(
    TileArityMismatch,
    "tile-arity-mismatch",
    check_tile_arity,
    "Deny: `tiles` does not cover exactly the block's axes."
);
structural_lint!(
    TileProductMismatch,
    "tile-product-mismatch",
    check_tile_products,
    "Deny: an axis's tile factors don't multiply back to its extent \
     (or a factor is non-positive) — iterations are dropped or invented."
);
structural_lint!(
    LoopOrderInvalid,
    "loop-order-invalid",
    check_loop_order,
    "Deny: `order` is not a permutation of every (axis, level) tile."
);
structural_lint!(
    CacheReadArityMismatch,
    "cache-read-arity-mismatch",
    check_cache_read_arity,
    "Deny: `cache_reads` does not pair 1:1 with the block's reads."
);

// ---------------------------------------------------------------------------
// schedule scope, target + degenerate
// ---------------------------------------------------------------------------

/// Deny: thread bindings on a CPU target. `ThreadBind` is GPU-only;
/// this lint is the single rejection point (the transform itself no
/// longer special-cases the target).
pub struct GpuOnlyTransformOnCpu;

impl Lint for GpuOnlyTransformOnCpu {
    fn code(&self) -> &'static str {
        "gpu-only-transform-on-cpu"
    }
    fn severity(&self) -> Severity {
        Severity::Deny
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        if ctx.gpu {
            return;
        }
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let tt = ctx.block(b).thread_tiles;
            if tt > 0 {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Deny,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: {tt} thread-bound loop(s) on a CPU target — ThreadBind \
                         is GPU-only",
                        w.blocks[b].name
                    ),
                });
            }
        }
    }
}

/// Warn: a parallel annotation that materializes total extent 1 — the
/// fork overhead is paid for zero concurrency.
pub struct ParallelExtentOne;

impl Lint for ParallelExtentOne {
    fn code(&self) -> &'static str {
        "parallel-extent-one"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let Some(nest) = ctx.nest(b) else { continue };
            if ctx.block(b).parallel > 0 && nest.parallel_extent() == 1 {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Warn,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: parallel annotation materializes extent 1 (no useful \
                         parallelism)",
                        w.blocks[b].name
                    ),
                });
            }
        }
    }
}

/// Unrolled-body size above which we flag code blowup.
pub const UNROLL_PRODUCT_LIMIT: i64 = 4096;

/// Warn: the unrolled loop body exceeds [`UNROLL_PRODUCT_LIMIT`]
/// iterations — instruction-cache blowup territory.
pub struct UnrollProductBlowup;

impl Lint for UnrollProductBlowup {
    fn code(&self) -> &'static str {
        "unroll-product-blowup"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let Some(nest) = ctx.nest(b) else { continue };
            let prod = nest.unrolled_product();
            if ctx.block(b).unroll > 0 && prod > UNROLL_PRODUCT_LIMIT {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Warn,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: unrolled body covers {prod} iterations \
                         (> {UNROLL_PRODUCT_LIMIT}) — code-size blowup",
                        w.blocks[b].name
                    ),
                });
            }
        }
    }
}

/// Warn: `cache_write` on a block with no reduction axis — there is no
/// accumulation to keep in registers, so the staging copy is dead.
pub struct DeadCacheWrite;

impl Lint for DeadCacheWrite {
    fn code(&self) -> &'static str {
        "dead-cache-write"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            if ctx.block(b).cache_write && !w.blocks[b].has_reduction() {
                sink(Diagnostic {
                    code: self.code(),
                    severity: Severity::Warn,
                    block: b,
                    axis: None,
                    message: format!(
                        "{}: cache_write on a block with no reduction axis — the \
                         accumulator stage is dead",
                        w.blocks[b].name
                    ),
                });
            }
        }
    }
}

/// Warn: a `cache_reads` stage on a fully broadcast (scalar) read — the
/// access touches no loop axis, so staging it buys nothing.
pub struct DeadCacheRead;

impl Lint for DeadCacheRead {
    fn code(&self) -> &'static str {
        "dead-cache-read"
    }
    fn severity(&self) -> Severity {
        Severity::Warn
    }
    fn check_schedule(&self, ctx: &LintCtx, sink: &mut dyn FnMut(Diagnostic)) {
        let w = &ctx.sched.workload;
        for b in 0..w.blocks.len() {
            let bs = ctx.block(b);
            let blk = &w.blocks[b];
            for (r, cr) in bs.cache_reads.iter().enumerate() {
                if cr.is_none() {
                    continue;
                }
                let Some(acc) = blk.reads.get(r) else { continue };
                if acc.dim_axes.iter().all(Vec::is_empty) {
                    sink(Diagnostic {
                        code: self.code(),
                        severity: Severity::Warn,
                        block: b,
                        axis: None,
                        message: format!(
                            "{}: cache_read stages read {r}, a fully broadcast \
                             (scalar) access — staging is dead",
                            blk.name
                        ),
                    });
                }
            }
        }
    }
}

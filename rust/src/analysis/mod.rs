//! Static legality analysis over `tir` + `schedule`.
//!
//! COLT's premise is that small LLMs propose transformations cheaply —
//! but a proposal is only useful if it is *legal*: `Parallel` /
//! `ThreadBind` / `Vectorize` over an axis that does not cover every
//! write is a write-write race the analytic simulator would happily
//! score, and `ComputeLocation` fusion can silently break
//! producer→consumer dependences. This module is the classical-compiler
//! soundness gate in front of the search: a [`Lint`] registry computes
//! per-axis read/write footprints from each block's `Access` patterns
//! and statically classifies every annotation of a schedule, emitting
//! structured [`Diagnostic`]s with stable codes.
//!
//! Two severities:
//!
//! * [`Severity::Deny`] — the schedule is **illegal** (race, broken
//!   dependence, malformed structure). [`crate::schedule::transforms::apply`]
//!   rejects Deny-level results as structural no-fits, so the MCTS never
//!   inserts an illegal node; rejections are counted per search
//!   ([`lint_rejects`] → `SearchResult::lint_rejects`).
//! * [`Severity::Warn`] — legal but degenerate (parallel extent 1,
//!   unroll blowup, dead cache stage, strided vector lanes). Warns are
//!   reachable by ordinary transform sequences and feed the
//!   `experiments lint_audit` diagnostic table; they never reject.
//!
//! The pre-existing `Workload::validate` / `BlockSched::validate` /
//! `Schedule::validate` checks are folded in here ([`workload_error`],
//! [`block_structure_error`]) so there is one source of truth for
//! legality. The invariant CI enforces (`lint_audit`, the proptest
//! `prop_reachable_schedules_lint_clean`): **every schedule reachable
//! from the transform vocabulary lints clean of Deny diagnostics** —
//! the prerequisite for a long-lived `serve` daemon that must reject
//! illegal schedules before they reach evaluation or a persisted tree.

pub mod deps;
pub mod lints;

use crate::schedule::{BlockSched, LoopNest, Schedule};
use crate::tir::Workload;
use std::cell::Cell;
use std::fmt;

/// How bad a diagnostic is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Degenerate but legal; never rejects a schedule.
    Warn,
    /// Illegal; `transforms::apply` rejects the schedule.
    Deny,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warn => write!(f, "warn"),
            Severity::Deny => write!(f, "deny"),
        }
    }
}

/// One structured finding: a stable machine-readable code, severity,
/// location (block index, optionally the axis), and a human message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Stable kebab-case code, e.g. `race-on-reduction-axis`.
    pub code: &'static str,
    pub severity: Severity,
    /// Index into `Workload::blocks` the finding anchors to.
    pub block: usize,
    /// Axis index within the block, when the lint is axis-scoped.
    pub axis: Option<usize>,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}] {}", self.severity, self.code, self.message)
    }
}

/// Shared context handed to schedule-scope checks: the schedule, the
/// target flavor, a bounds-checked consumer map, and the materialized
/// loop nest of every block whose state is sound enough to materialize.
pub struct LintCtx<'a> {
    pub sched: &'a Schedule,
    pub gpu: bool,
    /// `consumers[b]` = blocks consuming `b`'s output (producer edges
    /// out of range are skipped rather than trusted).
    pub consumers: Vec<Vec<usize>>,
    nests: Vec<Option<LoopNest>>,
}

impl<'a> LintCtx<'a> {
    pub fn new(sched: &'a Schedule, gpu: bool) -> LintCtx<'a> {
        let w = &sched.workload;
        let nb = w.blocks.len();
        let mut consumers = vec![Vec::new(); nb];
        for (bi, blk) in w.blocks.iter().enumerate() {
            for &p in &blk.producers {
                if p < nb {
                    consumers[p].push(bi);
                }
            }
        }
        let nests = (0..nb)
            .map(|b| materializable(sched, b).then(|| sched.loop_nest(b, gpu)))
            .collect();
        LintCtx {
            sched,
            gpu,
            consumers,
            nests,
        }
    }

    /// The block's schedule state.
    pub fn block(&self, b: usize) -> &BlockSched {
        &self.sched.blocks[b]
    }

    /// The materialized nest of `block`, or `None` when the block's
    /// schedule state is too corrupt to materialize (the structural
    /// lints report that corruption; nest-based lints skip the block).
    pub fn nest(&self, block: usize) -> Option<&LoopNest> {
        self.nests.get(block).and_then(|n| n.as_ref())
    }
}

/// True when `loop_nest(b)` can run without out-of-bounds indexing —
/// the structural preconditions the materializer assumes.
fn materializable(s: &Schedule, b: usize) -> bool {
    let bs = &s.blocks[b];
    let blk = &s.workload.blocks[b];
    if bs.tiles.len() != blk.axes.len() {
        return false;
    }
    if bs.order.is_empty() && bs.vectorize {
        return false;
    }
    bs.order.iter().all(|&(a, l)| a < bs.tiles.len() && l < bs.tiles[a].len())
}

/// One legality check. Implementations are stateless unit structs; each
/// owns one stable diagnostic code and overrides whichever scope it
/// inspects (workload structure vs. scheduled program).
pub trait Lint: Sync {
    /// Stable machine-readable code (the identity of this lint).
    fn code(&self) -> &'static str;
    fn severity(&self) -> Severity;
    /// Workload-scope checks (IR structure; target-independent).
    fn check_workload(&self, _w: &Workload, _sink: &mut dyn FnMut(Diagnostic)) {}
    /// Schedule-scope checks (annotations, tiling, fusion, races).
    fn check_schedule(&self, _ctx: &LintCtx, _sink: &mut dyn FnMut(Diagnostic)) {}
}

/// Every registered lint, workload-scope first, Deny before Warn.
/// `first_deny` scans in this order, so earlier entries win ties.
pub static REGISTRY: [&dyn Lint; 18] = [
    // workload scope (Deny)
    &lints::AccessRankMismatch,
    &lints::AxisIndexOutOfRange,
    &lints::BlockWithoutWrites,
    &lints::ProducerOrderViolation,
    // schedule scope, structural (Deny)
    &lints::TileArityMismatch,
    &lints::TileProductMismatch,
    &lints::LoopOrderInvalid,
    &lints::CacheReadArityMismatch,
    // schedule scope, dependence/race (Deny)
    &deps::RaceOnReductionAxis,
    &deps::FusionWithoutConsumer,
    &deps::FusionDepthOutOfRange,
    &lints::GpuOnlyTransformOnCpu,
    // schedule scope, degenerate (Warn)
    &deps::AnnotationOnReductionPosition,
    &deps::NonContiguousVectorization,
    &lints::ParallelExtentOne,
    &lints::UnrollProductBlowup,
    &lints::DeadCacheWrite,
    &lints::DeadCacheRead,
];

/// Run every lint (workload scope + schedule scope) over a scheduled
/// program and collect all diagnostics.
pub fn analyze(sched: &Schedule, gpu: bool) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut sink = |d: Diagnostic| out.push(d);
    for lint in REGISTRY {
        lint.check_workload(&sched.workload, &mut sink);
    }
    let ctx = LintCtx::new(sched, gpu);
    for lint in REGISTRY {
        lint.check_schedule(&ctx, &mut sink);
    }
    out
}

/// Run only the workload-scope lints (no schedule needed).
pub fn analyze_workload(w: &Workload) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut sink = |d: Diagnostic| out.push(d);
    for lint in REGISTRY {
        lint.check_workload(w, &mut sink);
    }
    out
}

/// First Deny diagnostic over the *schedule-scope* lints, or `None` if
/// the schedule is legal. This is the hot-path gate
/// [`crate::schedule::transforms::apply`] runs on every applied
/// transform; workload-scope lints are skipped because the workload is
/// immutable under transforms (it is validated once at construction).
pub fn first_deny(sched: &Schedule, gpu: bool) -> Option<Diagnostic> {
    let ctx = LintCtx::new(sched, gpu);
    let mut hit: Option<Diagnostic> = None;
    for lint in REGISTRY {
        if lint.severity() != Severity::Deny {
            continue;
        }
        let mut sink = |d: Diagnostic| {
            if hit.is_none() {
                hit = Some(d);
            }
        };
        lint.check_schedule(&ctx, &mut sink);
        if hit.is_some() {
            return hit;
        }
    }
    None
}

/// First Deny over the workload-scope lints — the analyzer-backed body
/// of [`crate::tir::Workload::validate`].
pub fn workload_error(w: &Workload) -> Option<Diagnostic> {
    let mut hit: Option<Diagnostic> = None;
    for lint in REGISTRY {
        if lint.severity() != Severity::Deny {
            continue;
        }
        let mut sink = |d: Diagnostic| {
            if hit.is_none() {
                hit = Some(d);
            }
        };
        lint.check_workload(w, &mut sink);
        if hit.is_some() {
            return hit;
        }
    }
    None
}

/// First structural diagnostic for one block's schedule state — the
/// analyzer-backed body of [`crate::schedule::BlockSched::validate`].
/// Checks run in the historical validate order (tile arity → tile
/// products → loop order → cache-read arity) with the historical
/// message texts, so delegating callers see identical errors.
pub fn block_structure_error(
    bs: &BlockSched,
    blk: &crate::tir::BlockDef,
    block: usize,
) -> Option<Diagnostic> {
    let mut hit: Option<Diagnostic> = None;
    {
        let mut sink = |d: Diagnostic| {
            if hit.is_none() {
                hit = Some(d);
            }
        };
        lints::check_tile_arity(bs, blk, block, &mut sink);
        if hit.is_none() {
            lints::check_tile_products(bs, blk, block, &mut sink);
        }
        if hit.is_none() {
            lints::check_loop_order(bs, blk, block, &mut sink);
        }
        if hit.is_none() {
            lints::check_cache_read_arity(bs, blk, block, &mut sink);
        }
    }
    hit
}

/// Number of Deny-severity diagnostics in a report.
pub fn deny_count(diags: &[Diagnostic]) -> usize {
    diags.iter().filter(|d| d.severity == Severity::Deny).count()
}

thread_local! {
    static LINT_REJECTS: Cell<u64> = const { Cell::new(0) };
}

/// Monotonic per-thread count of transform applications rejected with a
/// Deny diagnostic by [`crate::schedule::transforms::apply`]. Search
/// engines snapshot it at start and report the delta in
/// `SearchResult::lint_rejects`; all `apply` calls of one search happen
/// on its coordinator thread, so the delta is deterministic.
pub fn lint_rejects() -> u64 {
    LINT_REJECTS.with(Cell::get)
}

/// Bump the per-thread Deny-rejection counter (called by `apply`).
pub(crate) fn note_lint_reject() {
    LINT_REJECTS.with(|c| c.set(c.get() + 1));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::tir::{Access, Axis, BlockDef, BodyKind, Buffer, DType, Workload};
    use std::collections::BTreeSet;
    use std::sync::Arc;

    /// C[i,j] += A[i,k] * B[k,j] over 64^3.
    fn matmul() -> Workload {
        let buffers = vec![
            Buffer::new("A", &[64, 64], DType::F32),
            Buffer::new("B", &[64, 64], DType::F32),
            Buffer::new("C", &[64, 64], DType::F32),
        ];
        let blocks = vec![BlockDef {
            name: "matmul".into(),
            axes: vec![
                Axis::spatial("i", 64),
                Axis::spatial("j", 64),
                Axis::reduction("k", 64),
            ],
            reads: vec![
                Access::new(0, vec![vec![0], vec![2]]),
                Access::new(1, vec![vec![2], vec![1]]),
            ],
            writes: vec![Access::new(2, vec![vec![0], vec![1]])],
            body: BodyKind::Mac,
            flops_per_point: 2.0,
            producers: vec![],
        }];
        Workload::new("matmul".into(), buffers, blocks)
    }

    /// copy X→T then elementwise T→Y (a producer→consumer pair).
    fn two_block() -> Workload {
        let buffers = vec![
            Buffer::new("X", &[32, 32], DType::F32),
            Buffer::new("T", &[32, 32], DType::F32),
            Buffer::new("Y", &[32, 32], DType::F32),
        ];
        let blocks = vec![
            BlockDef {
                name: "stage".into(),
                axes: vec![Axis::spatial("i", 32), Axis::spatial("j", 32)],
                reads: vec![Access::new(0, vec![vec![0], vec![1]])],
                writes: vec![Access::new(1, vec![vec![0], vec![1]])],
                body: BodyKind::Copy,
                flops_per_point: 0.0,
                producers: vec![],
            },
            BlockDef {
                name: "consume".into(),
                axes: vec![Axis::spatial("i", 32), Axis::spatial("j", 32)],
                reads: vec![Access::new(1, vec![vec![0], vec![1]])],
                writes: vec![Access::new(2, vec![vec![0], vec![1]])],
                body: BodyKind::Elementwise,
                flops_per_point: 1.0,
                producers: vec![0],
            },
        ];
        Workload::new("two_block".into(), buffers, blocks)
    }

    fn sched_of(w: Workload) -> Schedule {
        Schedule::initial(Arc::new(w))
    }

    fn codes(diags: &[Diagnostic]) -> BTreeSet<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn initial_schedules_lint_clean() {
        let mut ws = crate::workloads::paper_benchmarks();
        ws.push(crate::workloads::gemm::gemm(256, 256, 256));
        for w in ws {
            let name = w.name.clone();
            let s = sched_of(w);
            for gpu in [false, true] {
                let diags = analyze(&s, gpu);
                assert!(deny_count(&diags) == 0, "{name} (gpu={gpu}): {diags:?}");
            }
        }
    }

    #[test]
    fn registry_codes_unique() {
        let codes: BTreeSet<&str> = REGISTRY.iter().map(|l| l.code()).collect();
        assert_eq!(codes.len(), REGISTRY.len(), "duplicate lint code");
    }

    /// Guard against dead lints: deliberately corrupted schedules and
    /// workloads must trigger **every** registered lint code.
    #[test]
    fn every_lint_code_fires() {
        let mut fired: BTreeSet<&'static str> = BTreeSet::new();
        let mut run = |s: &Schedule, gpu: bool| {
            for d in analyze(s, gpu) {
                fired.insert(d.code);
            }
        };

        // race-on-reduction-axis: mislabel k spatial so the parallel
        // window materializes a Parallel loop over an axis C's write
        // never covers — the canonical write-write race.
        let mut w = matmul();
        w.blocks[0].axes[2].kind = crate::tir::AxisKind::Spatial;
        let mut s = sched_of(w);
        s.block_mut(0).parallel = 3;
        run(&s, false);

        // annotation-on-reduction-position + parallel-extent-one:
        // reduction axis reordered into the parallel window (the
        // materializer neutralizes it, leaving extent-1 parallelism).
        let mut s = sched_of(matmul());
        s.block_mut(0).order = vec![(2, 0), (0, 0), (1, 0)];
        s.block_mut(0).parallel = 1;
        run(&s, false);

        // non-contiguous-vectorization: innermost spatial axis i is not
        // stride-1 in C's write.
        let mut s = sched_of(matmul());
        s.block_mut(0).order = vec![(1, 0), (2, 0), (0, 0)];
        s.block_mut(0).vectorize = true;
        run(&s, false);

        // gpu-only-transform-on-cpu
        let mut s = sched_of(matmul());
        s.block_mut(0).thread_tiles = 1;
        run(&s, false);

        // unroll-product-blowup: 64^3 unrolled body
        let mut s = sched_of(matmul());
        s.block_mut(0).unroll = 3;
        run(&s, false);

        // dead-cache-write: accumulator stage on a reduction-free block
        let mut s = sched_of(two_block());
        s.block_mut(0).cache_write = true;
        run(&s, false);

        // dead-cache-read: staging a fully broadcast (scalar) read
        let mut w = two_block();
        w.blocks[0].reads[0].dim_axes = vec![vec![], vec![]];
        let mut s = sched_of(w);
        s.block_mut(0).cache_reads[0] = Some(0);
        run(&s, false);

        // fusion-without-consumer: terminal block claims a fusion site
        let mut s = sched_of(two_block());
        s.block_mut(1).compute_at = Some(0);
        run(&s, false);

        // fusion-depth-out-of-range
        let mut s = sched_of(two_block());
        s.block_mut(0).compute_at = Some(99);
        run(&s, false);

        // tile-arity-mismatch
        let mut s = sched_of(matmul());
        s.block_mut(0).tiles.push(vec![1]);
        run(&s, false);

        // tile-product-mismatch
        let mut s = sched_of(matmul());
        s.block_mut(0).tiles[0] = vec![3];
        run(&s, false);

        // loop-order-invalid (duplicate entry)
        let mut s = sched_of(matmul());
        s.block_mut(0).order.push((0, 0));
        run(&s, false);

        // cache-read-arity-mismatch
        let mut s = sched_of(matmul());
        s.block_mut(0).cache_reads.push(None);
        run(&s, false);

        // workload scope: rank mismatch, axis oob, no writes, producer order
        let mut w = matmul();
        w.blocks[0].reads[0].dim_axes.push(vec![0]);
        run(&sched_of(w), false);
        let mut w = matmul();
        w.blocks[0].reads[0].dim_axes[0] = vec![9];
        run(&sched_of(w), false);
        let mut w = matmul();
        w.blocks[0].writes.clear();
        run(&sched_of(w), false);
        let mut w = two_block();
        w.blocks[0].producers = vec![0];
        run(&sched_of(w), false);

        let registered: BTreeSet<&'static str> = REGISTRY.iter().map(|l| l.code()).collect();
        let missing: Vec<&&str> = registered.difference(&fired).collect();
        assert!(
            missing.is_empty(),
            "dead lints (never fired by the corruption suite): {missing:?}"
        );
        let unknown: Vec<&&str> = fired.difference(&registered).collect();
        assert!(unknown.is_empty(), "diagnostics with unregistered codes: {unknown:?}");
    }

    #[test]
    fn first_deny_matches_analyze() {
        let mut s = sched_of(matmul());
        s.block_mut(0).thread_tiles = 1;
        let d = first_deny(&s, false).expect("deny expected");
        assert_eq!(d.code, "gpu-only-transform-on-cpu");
        let all = analyze(&s, false);
        assert!(codes(&all).contains("gpu-only-transform-on-cpu"));
        // clean schedule → no deny
        assert!(first_deny(&sched_of(matmul()), false).is_none());
    }

    #[test]
    fn decompose_legalizes_race() {
        let mut w = matmul();
        w.blocks[0].axes[2].kind = crate::tir::AxisKind::Spatial;
        let mut s = sched_of(w);
        s.block_mut(0).parallel = 3;
        assert_eq!(first_deny(&s, false).unwrap().code, "race-on-reduction-axis");
        s.block_mut(0).decomposed = true;
        assert!(first_deny(&s, false).is_none());
    }

    #[test]
    fn warns_never_reject() {
        let mut s = sched_of(matmul());
        s.block_mut(0).unroll = 3; // blowup warn
        assert!(first_deny(&s, false).is_none());
        let diags = analyze(&s, false);
        assert!(diags.iter().any(|d| d.code == "unroll-product-blowup"));
        assert_eq!(deny_count(&diags), 0);
    }

    #[test]
    fn reject_counter_is_monotonic_per_thread() {
        let before = lint_rejects();
        note_lint_reject();
        note_lint_reject();
        assert_eq!(lint_rejects(), before + 2);
    }

    #[test]
    fn diagnostic_display_is_structured() {
        let mut s = sched_of(matmul());
        s.block_mut(0).thread_tiles = 1;
        let d = first_deny(&s, false).unwrap();
        let line = d.to_string();
        assert!(line.starts_with("deny[gpu-only-transform-on-cpu]"), "{line}");
    }
}

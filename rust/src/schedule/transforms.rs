//! The transformation vocabulary exposed to LLMs (the paper's "Available
//! Transformations" list) and the parameter-sampling machinery behind it.
//!
//! LLM proposals name transforms (`"TileSize"`, `"Parallel"`, ...); the
//! engine samples concrete parameters (which axis, which factors, what
//! depth) exactly like MetaSchedule's `sample_perfect_tile` — the sampled
//! decisions are recorded in the trace and shown back to the models in
//! later prompt context.

use super::{Schedule, trace::TraceStep};
use crate::tir::AxisKind;
use crate::util::Rng;

/// All transformation kinds. `ThreadBind` is GPU-only (on CPU the
/// analyzer's `gpu-only-transform-on-cpu` lint denies the result).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransformKind {
    TileSize,
    Reorder,
    Parallel,
    Vectorize,
    Unroll,
    CacheWrite,
    CacheRead,
    ComputeLocation,
    DecomposeReduction,
    ThreadBind,
}

impl TransformKind {
    pub const ALL: [TransformKind; 10] = [
        TransformKind::TileSize,
        TransformKind::Reorder,
        TransformKind::Parallel,
        TransformKind::Vectorize,
        TransformKind::Unroll,
        TransformKind::CacheWrite,
        TransformKind::CacheRead,
        TransformKind::ComputeLocation,
        TransformKind::DecomposeReduction,
        TransformKind::ThreadBind,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TransformKind::TileSize => "TileSize",
            TransformKind::Reorder => "Reorder",
            TransformKind::Parallel => "Parallel",
            TransformKind::Vectorize => "Vectorize",
            TransformKind::Unroll => "Unroll",
            TransformKind::CacheWrite => "CacheWrite",
            TransformKind::CacheRead => "CacheRead",
            TransformKind::ComputeLocation => "ComputeLocation",
            TransformKind::DecomposeReduction => "DecomposeReduction",
            TransformKind::ThreadBind => "ThreadBind",
        }
    }

    /// Parse an LLM-proposed transform name. `None` = invalid (counts as
    /// a model error per the paper's prompt stats).
    pub fn from_name(s: &str) -> Option<TransformKind> {
        Self::ALL.iter().copied().find(|t| t.name() == s)
    }

    /// The vocabulary valid for a target (ThreadBind is GPU-only).
    pub fn vocabulary(gpu: bool) -> Vec<TransformKind> {
        Self::ALL
            .iter()
            .copied()
            .filter(|t| gpu || *t != TransformKind::ThreadBind)
            .collect()
    }
}

/// All divisors of n, ascending.
pub fn divisors(n: i64) -> Vec<i64> {
    let mut ds = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            ds.push(i);
            if i != n / i {
                ds.push(n / i);
            }
        }
        i += 1;
    }
    ds.sort_unstable();
    ds
}

/// MetaSchedule-style `sample_perfect_tile`: split `extent` into `parts`
/// factors whose product is exactly `extent`.
pub fn sample_perfect_tile(rng: &mut Rng, extent: i64, parts: usize) -> Vec<i64> {
    let mut remaining = extent;
    let mut out = Vec::with_capacity(parts);
    for i in 0..parts {
        if i == parts - 1 {
            out.push(remaining);
            break;
        }
        let ds = divisors(remaining);
        let f = *rng.choice(&ds);
        out.push(f);
        remaining /= f;
    }
    out
}

/// Pick a block to transform: weighted by FLOPs so the dominant block gets
/// most of the attention (as MetaSchedule's task scheduler does).
fn pick_block(s: &Schedule, rng: &mut Rng) -> usize {
    let weights: Vec<f64> = s.workload.blocks.iter().map(|b| b.flops().max(1.0)).collect();
    rng.weighted(&weights)
}

/// Apply one named transform with sampled parameters. Returns the new
/// schedule (with the step appended to its trace) or an explanation of why
/// the transform is inapplicable (not an LLM error — a structural no-fit).
///
/// Every successful application is gated on the static legality
/// analyzer: a result carrying any Deny-level diagnostic (write-write
/// race, broken fusion dependence, GPU-only state on CPU, malformed
/// structure) is rejected as a structural no-fit — the search never
/// sees an illegal schedule. Rejections bump the per-thread counter
/// behind [`crate::analysis::lint_rejects`].
pub fn apply(s: &Schedule, kind: TransformKind, rng: &mut Rng, gpu: bool) -> Result<Schedule, String> {
    // Cloning is cheap: blocks are copy-on-write (only the block the
    // transform touches is deep-cloned, via Schedule::block_mut) and the
    // trace is a persistent list extended in O(1).
    let mut out = s.clone();
    let step = apply_in_place(&mut out, kind, rng, gpu)?;
    out.trace.push_step(step);
    if let Some(d) = crate::analysis::first_deny(&out, gpu) {
        crate::analysis::note_lint_reject();
        return Err(format!("{}: {}", d.code, d.message));
    }
    Ok(out)
}

/// After a retile changed `consumer`'s loop count, re-clamp the fusion
/// depth of every producer fused into it: `compute_at` is a depth into
/// the *consumer's* nest, which per-block `clamp_annotations` cannot
/// see. Without this, tiling a consumer below a producer's fusion depth
/// leaves a dangling `fusion-depth-out-of-range` state.
fn clamp_fused_producers(s: &mut Schedule, consumer: usize) {
    let wl = s.workload.clone();
    if wl.blocks[consumer].producers.is_empty() {
        return;
    }
    let n = s.blocks[consumer].n_loops();
    let mut cons: Option<Vec<Vec<usize>>> = None;
    for &p in &wl.blocks[consumer].producers {
        let Some(d) = s.blocks[p].compute_at else { continue };
        if d < n {
            continue;
        }
        // a producer's fusion target is its *first* consumer; only clamp
        // producers actually fused into this block
        let cons = cons.get_or_insert_with(|| wl.consumers());
        if cons[p].first() != Some(&consumer) {
            continue;
        }
        s.block_mut(p).compute_at = if n == 0 { None } else { Some(n - 1) };
    }
}

fn apply_in_place(
    s: &mut Schedule,
    kind: TransformKind,
    rng: &mut Rng,
    gpu: bool,
) -> Result<TraceStep, String> {
    let wl = s.workload.clone();
    match kind {
        TransformKind::TileSize => {
            let b = pick_block(s, rng);
            let blk = &wl.blocks[b];
            let ax = rng.below(blk.axes.len());
            let extent = blk.axes[ax].extent;
            if extent < 2 {
                return Err("axis too small to tile".into());
            }
            let parts = 2 + rng.below(3); // 2..=4 tile levels
            let factors = sample_perfect_tile(rng, extent, parts);
            s.block_mut(b).retile(ax, factors.clone());
            clamp_fused_producers(s, b);
            Ok(TraceStep::new(
                "sample_perfect_tile",
                &blk.name,
                format!("loop={}, decision={:?}", blk.axes[ax].name, factors),
            ))
        }
        TransformKind::Reorder => {
            let b = pick_block(s, rng);
            let blk = &wl.blocks[b];
            // applicability through the read path — block_mut would pay a
            // CoW block clone even on an immediate Err
            if s.blocks[b].order.len() < 3 {
                return Err("too few loops to reorder".into());
            }
            let bs = s.block_mut(b);
            // Good-practice shuffle: keep level-0 loops outermost-ish,
            // permute the rest. Sample: sort by level with random
            // tie-breaking among same-level loops.
            let mut keyed: Vec<(usize, u64, (usize, usize))> = bs
                .order
                .iter()
                .map(|&(a, l)| (l, rng.next_u64(), (a, l)))
                .collect();
            keyed.sort_by_key(|&(l, r, _)| (l, r));
            bs.order = keyed.into_iter().map(|(_, _, al)| al).collect();
            bs.clamp_annotations();
            let detail = format!(
                "order={:?}",
                bs.order
                    .iter()
                    .map(|&(a, l)| format!("{}_{}", blk.axes[a].name, l))
                    .collect::<Vec<_>>()
            );
            Ok(TraceStep::new("reorder", &blk.name, detail))
        }
        TransformKind::Parallel => {
            let b = pick_block(s, rng);
            let blk = &wl.blocks[b];
            // bring up to `np` spatial loops to the front and parallelize;
            // find them through the read path so an inapplicable attempt
            // doesn't pay the CoW block clone
            let spatial_positions: Vec<usize> = s.blocks[b]
                .order
                .iter()
                .enumerate()
                .filter(|(_, &(a, _))| blk.axes[a].kind == AxisKind::Spatial)
                .map(|(i, _)| i)
                .collect();
            if spatial_positions.is_empty() {
                return Err("no spatial loops".into());
            }
            let bs = s.block_mut(b);
            let np = 1 + rng.below(spatial_positions.len().min(3));
            // stable partition: selected spatial loops first
            let chosen: Vec<(usize, usize)> = spatial_positions
                .iter()
                .take(np)
                .map(|&i| bs.order[i])
                .collect();
            bs.order.retain(|e| !chosen.contains(e));
            let mut new_order = chosen.clone();
            new_order.extend(bs.order.iter().copied());
            bs.order = new_order;
            bs.parallel = np;
            bs.clamp_annotations();
            Ok(TraceStep::new("parallel", &blk.name, format!("num_loops={np}")))
        }
        TransformKind::Vectorize => {
            let b = pick_block(s, rng);
            let blk = &wl.blocks[b];
            // choose a spatial axis that is contiguous in the write
            let write = &blk.writes[0];
            let cand: Vec<usize> = (0..blk.axes.len())
                .filter(|&a| blk.axes[a].kind == AxisKind::Spatial && write.axis_is_contiguous(a))
                .collect();
            let ax = *cand.first().ok_or("no contiguous spatial axis")?;
            let bs = s.block_mut(b);
            // make sure the axis has an inner factor in {4..64} and move it last
            let lanes_opts = [4i64, 8, 16, 32, 64];
            let extent = blk.axes[ax].extent;
            let lanes = *lanes_opts
                .iter()
                .filter(|&&l| extent % l == 0)
                .max_by_key(|&&l| l.min(16)) // prefer 8/16
                .ok_or("extent not divisible by any vector width")?;
            // retile axis: keep existing outer structure, ensure innermost = lanes
            let mut outer: Vec<i64> = bs.tiles[ax].clone();
            let prod: i64 = outer.iter().product();
            debug_assert_eq!(prod, extent);
            // squash to two levels: [extent/lanes, lanes]
            outer = vec![extent / lanes, lanes];
            bs.retile(ax, outer);
            // move (ax, 1) to the end of the order
            bs.order.retain(|&e| e != (ax, 1));
            bs.order.push((ax, 1));
            bs.vectorize = true;
            bs.clamp_annotations();
            clamp_fused_producers(s, b);
            Ok(TraceStep::new(
                "vectorize",
                &blk.name,
                format!("loop={}_1, lanes={lanes}", blk.axes[ax].name),
            ))
        }
        TransformKind::Unroll => {
            let b = pick_block(s, rng);
            let bs = s.block_mut(b);
            let depth = 1 + rng.below(3);
            bs.unroll = depth;
            bs.clamp_annotations();
            Ok(TraceStep::new("unroll", &wl.blocks[b].name, format!("depth={depth}")))
        }
        TransformKind::CacheWrite => {
            let cands: Vec<usize> = (0..wl.blocks.len())
                .filter(|&b| wl.blocks[b].has_reduction() && !s.blocks[b].cache_write)
                .collect();
            let &b = cands.first().ok_or("no reduction block without cache_write")?;
            s.block_mut(b).cache_write = true;
            Ok(TraceStep::new(
                "cache_write",
                &wl.blocks[b].name,
                format!("storage_scope=\"{}\"", if gpu { "local" } else { "global" }),
            ))
        }
        TransformKind::CacheRead => {
            let b = pick_block(s, rng);
            let blk = &wl.blocks[b];
            if blk.reads.is_empty() {
                return Err("no reads".into());
            }
            let r = rng.below(blk.reads.len());
            let bs = s.block_mut(b);
            let depth = 1 + rng.below(bs.n_loops().max(2) - 1);
            bs.cache_reads[r] = Some(depth);
            Ok(TraceStep::new(
                "cache_read",
                &blk.name,
                format!(
                    "read_buffer={}, storage_scope=\"{}\", at_depth={depth}",
                    wl.buffers[blk.reads[r].buffer].name,
                    if gpu { "shared" } else { "local" }
                ),
            ))
        }
        TransformKind::ComputeLocation => {
            // pick a block that has a consumer; move where it's computed
            let cons = wl.consumers();
            let cands: Vec<usize> = (0..wl.blocks.len())
                .filter(|&b| !cons[b].is_empty())
                .collect();
            if cands.is_empty() {
                return Err("no fusable producer".into());
            }
            let b = *rng.choice(&cands);
            let consumer = cons[b][0];
            let max_depth = s.blocks[consumer].n_loops();
            let choice = rng.below(max_depth + 1);
            let bs = s.block_mut(b);
            let detail;
            if choice == 0 {
                bs.compute_at = None;
                detail = "at=root".to_string();
            } else {
                bs.compute_at = Some(choice - 1);
                detail = format!(
                    "consumer=\"{}\", at_depth={}",
                    wl.blocks[consumer].name,
                    choice - 1
                );
            }
            Ok(TraceStep::new("compute_at", &wl.blocks[b].name, detail))
        }
        TransformKind::DecomposeReduction => {
            let cands: Vec<usize> = (0..wl.blocks.len())
                .filter(|&b| wl.blocks[b].has_reduction() && !s.blocks[b].decomposed)
                .collect();
            let &b = cands.first().ok_or("no undecomposed reduction")?;
            s.block_mut(b).decomposed = true;
            Ok(TraceStep::new("decompose_reduction", &wl.blocks[b].name, String::new()))
        }
        TransformKind::ThreadBind => {
            // No inline target check: on CPU the resulting thread-bound
            // state is rejected by the analyzer's
            // `gpu-only-transform-on-cpu` lint in `apply` — the single
            // rejection point, which also covers thread-bound schedules
            // arriving from warm caches or persisted traces.
            let b = pick_block(s, rng);
            let bs = s.block_mut(b);
            if bs.parallel == 0 {
                // need blockIdx loops first; promote one spatial loop
                bs.parallel = 1;
            }
            let nt = 1 + rng.below(2);
            bs.thread_tiles = nt.min(bs.n_loops().saturating_sub(bs.parallel));
            bs.clamp_annotations();
            Ok(TraceStep::new(
                "bind",
                &wl.blocks[b].name,
                format!("thread_loops={}", bs.thread_tiles),
            ))
        }
    }
}

/// Apply a whole proposal (sequence of transform names) to a schedule.
/// Inapplicable steps are skipped; at least one must apply or this errors.
pub fn apply_sequence(
    s: &Schedule,
    kinds: &[TransformKind],
    rng: &mut Rng,
    gpu: bool,
) -> Result<Schedule, String> {
    let mut cur = s.clone();
    let mut applied = 0;
    for &k in kinds {
        match apply(&cur, k, rng, gpu) {
            Ok(next) => {
                cur = next;
                applied += 1;
            }
            Err(_) => continue,
        }
    }
    if applied == 0 {
        Err("no transform in the sequence was applicable".into())
    } else {
        Ok(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{attention, gemm};
    use std::sync::Arc;

    fn sched() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)))
    }

    #[test]
    fn names_roundtrip() {
        for t in TransformKind::ALL {
            assert_eq!(TransformKind::from_name(t.name()), Some(t));
        }
        assert_eq!(TransformKind::from_name("Fission"), None);
    }

    #[test]
    fn vocabulary_excludes_threadbind_on_cpu() {
        assert!(!TransformKind::vocabulary(false).contains(&TransformKind::ThreadBind));
        assert!(TransformKind::vocabulary(true).contains(&TransformKind::ThreadBind));
    }

    #[test]
    fn perfect_tile_products() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let f = sample_perfect_tile(&mut rng, 384, 4);
            assert_eq!(f.iter().product::<i64>(), 384);
            assert_eq!(f.len(), 4);
        }
    }

    #[test]
    fn every_transform_keeps_schedule_valid() {
        let mut rng = Rng::new(2);
        for gpu in [false, true] {
            let base = Schedule::initial(Arc::new(attention::small_attention(128, 4, 32, true)));
            for kind in TransformKind::vocabulary(gpu) {
                let mut cur = base.clone();
                for _ in 0..5 {
                    if let Ok(next) = apply(&cur, kind, &mut rng, gpu) {
                        next.validate()
                            .unwrap_or_else(|e| panic!("{kind:?} broke: {e}"));
                        cur = next;
                    }
                }
            }
        }
    }

    #[test]
    fn random_transform_storm_stays_valid() {
        let mut rng = Rng::new(3);
        let mut s = sched();
        let vocab = TransformKind::vocabulary(true);
        for _ in 0..300 {
            let k = *rng.choice(&vocab);
            if let Ok(next) = apply(&s, k, &mut rng, true) {
                s = next;
            }
        }
        s.validate().unwrap();
        assert!(s.trace.len() > 50);
    }

    #[test]
    fn trace_records_decisions() {
        let mut rng = Rng::new(4);
        let s = apply(&sched(), TransformKind::TileSize, &mut rng, false).unwrap();
        assert_eq!(s.trace.len(), 1);
        assert!(s.trace.steps()[0].detail.contains("decision="));
    }

    #[test]
    fn apply_shares_unmutated_blocks_with_parent() {
        // CoW: applying one transform to a multi-block workload deep-clones
        // at most the mutated block; every other block stays shared.
        let mut rng = Rng::new(8);
        let base = Schedule::initial(Arc::new(attention::small_attention(128, 4, 32, true)));
        let next = apply(&base, TransformKind::Unroll, &mut rng, false).unwrap();
        let shared = base
            .blocks
            .iter()
            .zip(&next.blocks)
            .filter(|&(a, b)| Arc::ptr_eq(a, b))
            .count();
        assert!(
            shared >= base.blocks.len() - 1,
            "only {shared}/{} blocks shared after one transform",
            base.blocks.len()
        );
        assert_eq!(next.trace.len(), 1);
        assert_eq!(base.trace.len(), 0, "parent trace untouched");
    }

    #[test]
    fn threadbind_rejected_on_cpu() {
        let mut rng = Rng::new(5);
        assert!(apply(&sched(), TransformKind::ThreadBind, &mut rng, false).is_err());
    }

    /// Regression (single rejection point): ThreadBind-on-CPU is no
    /// longer special-cased inside the transform — the rejection comes
    /// from the analyzer's Deny lint, carries its stable code, and
    /// bumps the per-thread lint-reject counter.
    #[test]
    fn threadbind_on_cpu_rejected_by_lint_not_transform() {
        let mut rng = Rng::new(5);
        let before = crate::analysis::lint_rejects();
        let err = apply(&sched(), TransformKind::ThreadBind, &mut rng, false).unwrap_err();
        assert!(
            err.contains("gpu-only-transform-on-cpu"),
            "expected the lint code in the rejection, got: {err}"
        );
        assert_eq!(crate::analysis::lint_rejects(), before + 1);
        // ...while on GPU the same transform is legal
        let mut rng = Rng::new(5);
        assert!(apply(&sched(), TransformKind::ThreadBind, &mut rng, true).is_ok());
    }

    /// Tiling a consumer below a producer's fusion depth must re-clamp
    /// the producer's `compute_at` (the dangling depth would otherwise
    /// be a `fusion-depth-out-of-range` Deny on a reachable state).
    #[test]
    fn retile_clamps_fused_producer_depths() {
        let mut rng = Rng::new(11);
        let base = Schedule::initial(Arc::new(attention::small_attention(128, 4, 32, true)));
        // drive fusion + tiling storms; every surviving state must lint clean
        let mut s = base.clone();
        let vocab = [
            TransformKind::TileSize,
            TransformKind::Vectorize,
            TransformKind::ComputeLocation,
        ];
        let mut fused_seen = false;
        for _ in 0..400 {
            let k = *rng.choice(&vocab);
            if let Ok(next) = apply(&s, k, &mut rng, false) {
                s = next;
            }
            fused_seen |= s.blocks.iter().any(|b| b.compute_at.is_some());
            assert!(
                crate::analysis::first_deny(&s, false).is_none(),
                "reachable state carries a Deny diagnostic"
            );
        }
        assert!(fused_seen, "storm never exercised ComputeLocation fusion");
    }

    #[test]
    fn apply_sequence_partial_ok() {
        let mut rng = Rng::new(6);
        let out = apply_sequence(
            &sched(),
            &[TransformKind::ThreadBind, TransformKind::TileSize],
            &mut rng,
            false,
        )
        .unwrap();
        assert_eq!(out.trace.len(), 1); // ThreadBind skipped on CPU
    }

    #[test]
    fn vectorize_sets_lanes() {
        let mut rng = Rng::new(7);
        let s = apply(&sched(), TransformKind::Vectorize, &mut rng, false).unwrap();
        let nest = s.loop_nest(0, false);
        assert!(nest.vector_lanes() >= 4);
    }
}

//! Transformation trace: the replayable history attached to every
//! schedule, rendered into LLM prompt context exactly like the paper's
//! `sch.sample_perfect_tile(loop=j, decision=[1, 64, 1, 64])` lines.
//!
//! # Representation: a persistent cons list
//!
//! A [`Trace`] is a singly linked list of [`TraceStep`]s stored
//! newest-first behind [`Arc`]s, so the search hot loop pays O(1) for the
//! two operations it performs constantly:
//!
//! * **clone** — copying a trace copies one `Option<Arc<..>>`; every
//!   child schedule structurally shares its parent's entire prefix
//!   (exactly the shape of the shared MCTS tree, where thousands of
//!   nodes extend common transformation prefixes);
//! * **push** — appending allocates one node and extends the cached
//!   running FNV-1a hash by the new step's three strings, so
//!   [`Trace::running_hash`] is always available without iterating.
//!
//! The running hash is what makes the evaluation cache's
//! [`trace_key`](crate::mcts::evalcache::trace_key) O(1) in trace depth:
//! it folds in the precomputed hash instead of re-hashing three strings
//! per step per lookup. Transform and block names are interned as
//! `Arc<str>` (they come from tiny fixed vocabularies), so a step costs
//! two refcount bumps plus its unique decision string.

use std::cell::RefCell;
use std::collections::HashSet;
use std::fmt;
use std::sync::Arc;

// The FNV-1a primitives historically lived here; they now sit in
// `util::fnv` so the lower layers (`tir` structural fingerprints, the
// `sim` block memo) can fold hashes without depending on the schedule
// layer. Re-exported under the old paths for existing callers.
pub use crate::util::fnv::{fnv_str, fnv_u64, FNV_OFFSET, FNV_PRIME};

/// Intern a name into a shared `Arc<str>`. Transform and block names come
/// from tiny fixed vocabularies, so each distinct string is allocated once
/// per thread and every trace step after that is a refcount bump.
pub fn intern(s: &str) -> Arc<str> {
    thread_local! {
        static POOL: RefCell<HashSet<Arc<str>>> = RefCell::new(HashSet::new());
    }
    POOL.with(|p| {
        let mut m = p.borrow_mut();
        // Arc<str>: Borrow<str>, so the set is queryable by &str — each
        // distinct name is allocated exactly once per thread
        if let Some(a) = m.get(s) {
            return a.clone();
        }
        let a: Arc<str> = Arc::from(s);
        m.insert(a.clone());
        a
    })
}

/// One applied transformation with its sampled decisions.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceStep {
    /// Canonical transform name (the names exposed to LLMs), interned.
    pub name: Arc<str>,
    /// Target block name, interned.
    pub block: Arc<str>,
    /// Rendered decision string, e.g. `loop=j, decision=[2, 32, 2, 32]`.
    pub detail: String,
}

impl TraceStep {
    pub fn new(name: &str, block: &str, detail: String) -> TraceStep {
        TraceStep {
            name: intern(name),
            block: intern(block),
            detail,
        }
    }
}

impl fmt::Display for TraceStep {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sch.{}(block=\"{}\", {})", self.name, self.block, self.detail)
    }
}

/// One cons cell: the newest step plus the shared prefix, carrying the
/// cached length and running hash of everything up to and including it.
#[derive(Debug)]
struct TraceNode {
    step: TraceStep,
    prev: Option<Arc<TraceNode>>,
    len: usize,
    hash: u64,
}

/// The full history of a schedule (ordered oldest → newest), stored as a
/// persistent newest-first cons list. See the module docs for why.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    head: Option<Arc<TraceNode>>,
}

impl Trace {
    /// Append a step (interning the name and block). O(1).
    pub fn push(&mut self, name: &str, block: &str, detail: String) {
        self.push_step(TraceStep::new(name, block, detail));
    }

    /// Append an already-built step. O(1): one node allocation plus
    /// folding the step's three strings into the cached running hash.
    pub fn push_step(&mut self, step: TraceStep) {
        let (prev_len, prev_hash) = match &self.head {
            Some(n) => (n.len, n.hash),
            None => (0, FNV_OFFSET),
        };
        let mut h = fnv_str(prev_hash, &step.name);
        h = fnv_str(h, &step.block);
        h = fnv_str(h, &step.detail);
        self.head = Some(Arc::new(TraceNode {
            step,
            prev: self.head.take(),
            len: prev_len + 1,
            hash: h,
        }));
    }

    /// Number of steps. O(1) (cached in the head node).
    pub fn len(&self) -> usize {
        self.head.as_ref().map_or(0, |n| n.len)
    }

    pub fn is_empty(&self) -> bool {
        self.head.is_none()
    }

    /// The cached running FNV-1a hash over every step's (name, block,
    /// detail), in order; [`FNV_OFFSET`] for an empty trace. O(1) — this
    /// is the value [`trace_key`](crate::mcts::evalcache::trace_key)
    /// builds on. Stable across clones (clones share the same nodes) and
    /// equal for traces built step-by-step from equal strings.
    pub fn running_hash(&self) -> u64 {
        self.head.as_ref().map_or(FNV_OFFSET, |n| n.hash)
    }

    /// Iterate steps newest → oldest (the list's native order).
    pub fn iter_rev(&self) -> impl Iterator<Item = &TraceStep> {
        std::iter::successors(self.head.as_deref(), |n| n.prev.as_deref()).map(|n| &n.step)
    }

    /// Owned steps in application order (oldest → newest). O(len) — for
    /// tests and offline inspection, not the hot loop.
    pub fn steps(&self) -> Vec<TraceStep> {
        let mut v: Vec<TraceStep> = self.iter_rev().cloned().collect();
        v.reverse();
        v
    }

    /// Render the last `n` steps (prompt context shows a bounded history).
    pub fn render_tail(&self, n: usize) -> String {
        let mut lines: Vec<String> = self.iter_rev().take(n).map(|s| s.to_string()).collect();
        lines.reverse();
        lines.join("\n")
    }
}

impl PartialEq for Trace {
    fn eq(&self, other: &Self) -> bool {
        if self.len() != other.len() {
            return false;
        }
        let mut a = self.head.as_deref();
        let mut b = other.head.as_deref();
        while let (Some(x), Some(y)) = (a, b) {
            if std::ptr::eq(x, y) {
                // shared suffix of the walk = shared prefix of the trace
                return true;
            }
            if x.step != y.step {
                return false;
            }
            a = x.prev.as_deref();
            b = y.prev.as_deref();
        }
        true
    }
}

impl Drop for Trace {
    /// Iterative teardown of uniquely-owned chain segments so dropping a
    /// deep trace never recurses (the derived drop would unwind one stack
    /// frame per step).
    fn drop(&mut self) {
        let mut cur = self.head.take();
        while let Some(node) = cur {
            // into_inner (not try_unwrap) so that when two threads race to
            // drop a shared suffix, exactly one of them receives the node
            // and keeps tearing down iteratively — the other sees None and
            // stops with nothing left to drop recursively.
            match Arc::into_inner(node) {
                Some(mut n) => cur = n.prev.take(),
                // the rest of the chain is shared — its owner tears it down
                None => break,
            }
        }
    }
}

impl fmt::Display for Trace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.render_tail(usize::MAX))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_like_tvm() {
        let mut t = Trace::default();
        t.push("sample_perfect_tile", "matmul", "loop=j, decision=[1, 64, 1, 64]".into());
        t.push("vectorize", "matmul", "loop=j_3".into());
        let s = t.to_string();
        assert!(s.contains("sch.sample_perfect_tile(block=\"matmul\", loop=j, decision=[1, 64, 1, 64])"));
        assert!(s.lines().count() == 2);
    }

    #[test]
    fn tail_rendering() {
        let mut t = Trace::default();
        for i in 0..10 {
            t.push("unroll", "b", format!("depth={i}"));
        }
        let tail = t.render_tail(3);
        assert_eq!(tail.lines().count(), 3);
        assert!(tail.contains("depth=9"));
        assert!(!tail.contains("depth=6"));
    }

    #[test]
    fn display_matches_full_tail_and_order() {
        let mut t = Trace::default();
        t.push("parallel", "b", "num_loops=2".into());
        t.push("unroll", "b", "depth=1".into());
        assert_eq!(t.to_string(), t.render_tail(usize::MAX));
        // oldest step renders first
        let first = t.to_string().lines().next().unwrap().to_string();
        assert!(first.contains("parallel"), "{first}");
        assert_eq!(t.steps()[0].detail, "num_loops=2");
        assert_eq!(t.steps()[1].detail, "depth=1");
    }

    #[test]
    fn hash_stable_across_clones_and_rebuilds() {
        let mut a = Trace::default();
        a.push("unroll", "b", "depth=1".into());
        a.push("vectorize", "b", "lanes=8".into());
        let cloned = a.clone();
        assert_eq!(a.running_hash(), cloned.running_hash());
        // a trace rebuilt from the same strings hashes identically even
        // though it shares no nodes
        let mut rebuilt = Trace::default();
        rebuilt.push("unroll", "b", "depth=1".into());
        rebuilt.push("vectorize", "b", "lanes=8".into());
        assert_eq!(a.running_hash(), rebuilt.running_hash());
        assert_eq!(a, rebuilt);
    }

    #[test]
    fn divergent_prefixes_hash_differently() {
        let mut base = Trace::default();
        base.push("unroll", "b", "depth=1".into());
        let mut x = base.clone();
        let mut y = base.clone();
        x.push("vectorize", "b", "lanes=8".into());
        y.push("vectorize", "b", "lanes=16".into());
        assert_ne!(x.running_hash(), y.running_hash());
        assert_ne!(x, y);
        // field boundaries matter: ("ab","c") != ("a","bc")
        let mut p = Trace::default();
        p.push("ab", "c", "d".into());
        let mut q = Trace::default();
        q.push("a", "bc", "d".into());
        assert_ne!(p.running_hash(), q.running_hash());
        // empty trace hashes to the offset basis
        assert_eq!(Trace::default().running_hash(), FNV_OFFSET);
    }

    #[test]
    fn clone_is_persistent() {
        let mut a = Trace::default();
        a.push("unroll", "b", "depth=1".into());
        let snapshot = a.clone();
        a.push("parallel", "b", "num_loops=2".into());
        // the clone still sees only its own prefix
        assert_eq!(snapshot.len(), 1);
        assert_eq!(a.len(), 2);
        assert_ne!(snapshot.running_hash(), a.running_hash());
        // equality walks shared structure (prefix nodes are the same Arcs)
        assert_eq!(snapshot, {
            let mut t = Trace::default();
            t.push("unroll", "b", "depth=1".into());
            t
        });
    }

    #[test]
    fn interning_dedups_names() {
        let a = TraceStep::new("unroll", "matmul", "d=1".into());
        let b = TraceStep::new("unroll", "matmul", "d=2".into());
        assert!(Arc::ptr_eq(&a.name, &b.name));
        assert!(Arc::ptr_eq(&a.block, &b.block));
    }

    #[test]
    fn deep_trace_drops_without_overflow() {
        let mut t = Trace::default();
        for i in 0..50_000 {
            t.push("unroll", "b", format!("depth={i}"));
        }
        assert_eq!(t.len(), 50_000);
        drop(t); // must not recurse 50k frames
    }
}

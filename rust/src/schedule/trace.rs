//! Transformation trace: the replayable history attached to every
//! schedule, rendered into LLM prompt context exactly like the paper's
//! `sch.sample_perfect_tile(loop=j, decision=[1, 64, 1, 64])` lines.

use std::fmt;

/// One applied transformation with its sampled decisions.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceStep {
    /// Canonical transform name (the names exposed to LLMs).
    pub name: String,
    /// Target block name.
    pub block: String,
    /// Rendered decision string, e.g. `loop=j, decision=[2, 32, 2, 32]`.
    pub detail: String,
}

impl fmt::Display for TraceStep {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sch.{}(block=\"{}\", {})", self.name, self.block, self.detail)
    }
}

/// The full history of a schedule (ordered).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    pub steps: Vec<TraceStep>,
}

impl Trace {
    pub fn push(&mut self, name: &str, block: &str, detail: String) {
        self.steps.push(TraceStep {
            name: name.to_string(),
            block: block.to_string(),
            detail,
        });
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Render the last `n` steps (prompt context shows a bounded history).
    pub fn render_tail(&self, n: usize) -> String {
        let start = self.steps.len().saturating_sub(n);
        self.steps[start..]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    }
}

impl fmt::Display for Trace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.render_tail(usize::MAX))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_like_tvm() {
        let mut t = Trace::default();
        t.push("sample_perfect_tile", "matmul", "loop=j, decision=[1, 64, 1, 64]".into());
        t.push("vectorize", "matmul", "loop=j_3".into());
        let s = t.to_string();
        assert!(s.contains("sch.sample_perfect_tile(block=\"matmul\", loop=j, decision=[1, 64, 1, 64])"));
        assert!(s.lines().count() == 2);
    }

    #[test]
    fn tail_rendering() {
        let mut t = Trace::default();
        for i in 0..10 {
            t.push("unroll", "b", format!("depth={i}"));
        }
        let tail = t.render_tail(3);
        assert_eq!(tail.lines().count(), 3);
        assert!(tail.contains("depth=9"));
        assert!(!tail.contains("depth=6"));
    }
}

//! Schedule representation: the MetaSchedule-primitive stand-in.
//!
//! A [`Schedule`] pairs a [`Workload`](crate::tir::Workload) with one
//! [`BlockSched`] per block. Transformations ([`transforms`]) are
//! semantic-preserving structural rewrites recorded in a replayable
//! [`trace`]. The materialized loop nest ([`LoopNest`]) is what the
//! simulator evaluates and the printer renders into prompt context.
//!
//! Schedules are **copy-on-write**: per-block state sits behind `Arc`s,
//! so cloning a schedule copies pointers and applying a transform clones
//! only the block it mutates (via [`Schedule::block_mut`]). Together with
//! the persistent [`trace`] this makes the search's pervasive
//! clone-then-extend pattern O(1) + O(one block) instead of O(program).

pub mod transforms;
pub mod trace;
pub mod printer;

use crate::tir::{AxisKind, Workload};
use crate::util::fnv::{fnv_i64, fnv_u64, FNV_OFFSET};
use std::sync::{Arc, OnceLock};

/// Annotation on one materialized loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopKind {
    Serial,
    Parallel,
    Vectorized,
    Unrolled,
    /// GPU blockIdx binding (maps from `parallel` on the GPU target).
    BlockIdx,
    /// GPU threadIdx binding.
    ThreadIdx,
}

/// Per-block schedule state.
///
/// Carries a lazily cached structural fingerprint
/// ([`BlockSched::fingerprint`]) — the per-block half of the schedule
/// fingerprint and the identity the block-level simulation memo
/// ([`crate::sim::blockcache`]) keys on. The cache is invalidated by
/// [`Schedule::block_mut`] (the only sanctioned mutation path for blocks
/// held by a schedule); equality ignores it.
#[derive(Clone, Debug)]
pub struct BlockSched {
    /// Per original axis: tile factors, outermost -> innermost.
    /// Invariant: product == axis extent; len >= 1.
    pub tiles: Vec<Vec<i64>>,
    /// Loop order as (axis, level) pairs; a permutation of every tile
    /// level of every axis.
    pub order: Vec<(usize, usize)>,
    /// Number of outermost loops fused and parallelized (CPU) or bound to
    /// blockIdx (GPU).
    pub parallel: usize,
    /// Number of loops after the parallel ones bound to threadIdx (GPU
    /// targets only; ignored by the CPU model).
    pub thread_tiles: usize,
    /// Innermost loop is vectorized.
    pub vectorize: bool,
    /// Number of innermost (non-vector) loops annotated unroll.
    pub unroll: usize,
    /// Output accumulated in a register/local tile then written back.
    pub cache_write: bool,
    /// Per read access: Some(depth) = staged into fast scope at that loop
    /// depth (CPU: L1-resident pack buffer; GPU: shared memory).
    pub cache_reads: Vec<Option<usize>>,
    /// None = root (standalone); Some(d) = fused into the consumer's loop
    /// nest at depth d (ComputeLocation).
    pub compute_at: Option<usize>,
    /// Reduction init split out of the update loop.
    pub decomposed: bool,
    /// Lazily cached structural fingerprint over every field above;
    /// cleared by [`Schedule::block_mut`] before mutation. Cloning copies
    /// the cache (a clone is structurally identical); equality ignores it.
    fp: OnceLock<u64>,
}

/// Structural equality only — the lazily cached fingerprint is derived
/// state and must never make two structurally equal blocks compare
/// unequal (one may simply not have been fingerprinted yet).
impl PartialEq for BlockSched {
    fn eq(&self, other: &Self) -> bool {
        self.tiles == other.tiles
            && self.order == other.order
            && self.parallel == other.parallel
            && self.thread_tiles == other.thread_tiles
            && self.vectorize == other.vectorize
            && self.unroll == other.unroll
            && self.cache_write == other.cache_write
            && self.cache_reads == other.cache_reads
            && self.compute_at == other.compute_at
            && self.decomposed == other.decomposed
    }
}

impl BlockSched {
    /// Default (unoptimized) schedule for a block: one tile level per
    /// axis, original order, all-serial.
    pub fn default_for(workload: &Workload, block: usize) -> BlockSched {
        let blk = &workload.blocks[block];
        BlockSched {
            tiles: blk.axes.iter().map(|a| vec![a.extent]).collect(),
            order: (0..blk.axes.len()).map(|i| (i, 0)).collect(),
            parallel: 0,
            thread_tiles: 0,
            vectorize: false,
            unroll: 0,
            cache_write: false,
            cache_reads: vec![None; blk.reads.len()],
            compute_at: None,
            decomposed: false,
            fp: OnceLock::new(),
        }
    }

    /// Deterministic structural fingerprint of this block's schedule
    /// state (every field the simulator's per-block model can observe:
    /// tiles, order, annotation counts, caching flags, fusion depth).
    /// FNV-1a folded — stable across runs, threads, and processes — and
    /// computed at most once per instance ([`Schedule::block_mut`] clears
    /// the cache before handing out mutable access). The schedule-level
    /// [`Schedule::fingerprint`] is a fold of these, and the block-level
    /// simulation memo ([`crate::sim::blockcache`]) keys on them.
    pub fn fingerprint(&self) -> u64 {
        *self.fp.get_or_init(|| {
            let mut h = FNV_OFFSET;
            h = fnv_u64(h, self.tiles.len() as u64);
            for t in &self.tiles {
                h = fnv_u64(h, t.len() as u64);
                for &f in t {
                    h = fnv_i64(h, f);
                }
            }
            for &(a, l) in &self.order {
                h = fnv_u64(h, a as u64);
                h = fnv_u64(h, l as u64);
            }
            h = fnv_u64(h, self.parallel as u64);
            h = fnv_u64(h, self.thread_tiles as u64);
            h = fnv_u64(h, u64::from(self.vectorize));
            h = fnv_u64(h, self.unroll as u64);
            h = fnv_u64(h, u64::from(self.cache_write));
            h = fnv_u64(h, self.cache_reads.len() as u64);
            for cr in &self.cache_reads {
                // Some(d) and None must never collide for any depth d
                h = fnv_u64(h, cr.map_or(u64::MAX, |d| d as u64));
            }
            h = fnv_u64(h, self.compute_at.map_or(u64::MAX, |d| d as u64));
            h = fnv_u64(h, u64::from(self.decomposed));
            h
        })
    }

    /// Number of materialized loops.
    pub fn n_loops(&self) -> usize {
        self.order.len()
    }

    /// Extent of the (axis, level) tile.
    pub fn tile_extent(&self, axis: usize, level: usize) -> i64 {
        self.tiles[axis][level]
    }

    /// Re-derive a canonical order after re-tiling an axis: existing
    /// positions of that axis's levels are replaced in place (old levels
    /// beyond the new count dropped, new levels appended innermost).
    pub fn retile(&mut self, axis: usize, factors: Vec<i64>) {
        let new_n = factors.len();
        self.tiles[axis] = factors;
        // Keep the first min(old,new) occurrences, renumbered; drop extras.
        let mut seen = 0usize;
        self.order.retain(|&(a, _)| {
            if a == axis {
                seen += 1;
                seen <= new_n
            } else {
                true
            }
        });
        // renumber kept levels in appearance order
        let mut level = 0;
        for slot in self.order.iter_mut() {
            if slot.0 == axis {
                slot.1 = level;
                level += 1;
            }
        }
        // append any missing levels innermost
        while level < new_n {
            self.order.push((axis, level));
            level += 1;
        }
        self.clamp_annotations();
    }

    /// Keep annotation counts within the loop count.
    pub fn clamp_annotations(&mut self) {
        let n = self.n_loops();
        self.parallel = self.parallel.min(n);
        self.thread_tiles = self.thread_tiles.min(n - self.parallel);
        self.unroll = self.unroll.min(n.saturating_sub(self.parallel + self.thread_tiles));
        for cr in self.cache_reads.iter_mut().flatten() {
            *cr = (*cr).min(n.saturating_sub(1));
        }
    }

    /// Structural sanity: order is a permutation of all tile levels.
    /// Delegates to the static analyzer's structural lints
    /// ([`crate::analysis::block_structure_error`]) so legality has one
    /// source of truth; checks run in the historical order with the
    /// historical message texts.
    pub fn validate(&self, workload: &Workload, block: usize) -> Result<(), String> {
        match crate::analysis::block_structure_error(self, &workload.blocks[block], block) {
            Some(d) => Err(d.message),
            None => Ok(()),
        }
    }
}

/// One materialized loop of a scheduled block.
#[derive(Clone, Debug)]
pub struct LoopInfo {
    pub axis: usize,
    pub level: usize,
    pub extent: i64,
    pub kind: LoopKind,
    pub is_reduction: bool,
}

/// The fully materialized loop nest of one block under its schedule.
#[derive(Clone, Debug)]
pub struct LoopNest {
    pub loops: Vec<LoopInfo>,
}

impl LoopNest {
    pub fn parallel_extent(&self) -> i64 {
        self.loops
            .iter()
            .filter(|l| matches!(l.kind, LoopKind::Parallel | LoopKind::BlockIdx))
            .map(|l| l.extent)
            .product()
    }

    pub fn thread_extent(&self) -> i64 {
        self.loops
            .iter()
            .filter(|l| l.kind == LoopKind::ThreadIdx)
            .map(|l| l.extent)
            .product()
    }

    pub fn vector_lanes(&self) -> i64 {
        self.loops
            .iter()
            .rev()
            .find(|l| l.kind == LoopKind::Vectorized)
            .map(|l| l.extent)
            .unwrap_or(0)
    }

    pub fn unrolled_product(&self) -> i64 {
        self.loops
            .iter()
            .filter(|l| l.kind == LoopKind::Unrolled)
            .map(|l| l.extent)
            .product()
    }
}

/// A scheduled program: the MCTS search state's "program" component.
///
/// Cloning is cheap (copy-on-write): `blocks` holds `Arc`s, the trace is
/// a persistent list, and the structural fingerprint is lazily cached.
/// All mutation of block state must go through [`Schedule::block_mut`],
/// which clones only the target block and invalidates the fingerprint.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub workload: Arc<Workload>,
    /// Per-block schedule state, shared with ancestor schedules until
    /// mutated. Read through plain indexing (`&s.blocks[b]` auto-derefs);
    /// write ONLY through [`Schedule::block_mut`] — writing through the
    /// `Arc` directly (e.g. `Arc::make_mut`) would leave the cached
    /// fingerprint stale and corrupt evaluation-cache keys, which is why
    /// this field is crate-private.
    pub(crate) blocks: Vec<Arc<BlockSched>>,
    pub trace: trace::Trace,
    /// Lazily computed structural fingerprint; reset on mutation.
    fp: OnceLock<u64>,
}

impl Schedule {
    /// The unoptimized program p1.
    pub fn initial(workload: Arc<Workload>) -> Schedule {
        let blocks = (0..workload.blocks.len())
            .map(|b| Arc::new(BlockSched::default_for(&workload, b)))
            .collect();
        Schedule {
            workload,
            blocks,
            trace: trace::Trace::default(),
            fp: OnceLock::new(),
        }
    }

    /// Mutable access to one block's schedule state. Copy-on-write: if the
    /// block is shared with another schedule (the common case — every
    /// child shares its parent's unchanged blocks), only that block is
    /// cloned. Also invalidates both cached structural fingerprints: the
    /// schedule-level one and the target block's own (the caller is about
    /// to mutate it — an `Arc::make_mut` that found the block unshared
    /// would otherwise keep the stale cache, corrupting the block-memo
    /// keys derived from it).
    pub fn block_mut(&mut self, block: usize) -> &mut BlockSched {
        self.fp = OnceLock::new();
        let bs = Arc::make_mut(&mut self.blocks[block]);
        bs.fp = OnceLock::new();
        bs
    }

    /// Materialize the loop nest of `block` for this target.
    pub fn loop_nest(&self, block: usize, gpu: bool) -> LoopNest {
        let bs = &self.blocks[block];
        let blk = &self.workload.blocks[block];
        let n = bs.n_loops();
        let mut loops = Vec::with_capacity(n);
        let vec_pos = if bs.vectorize && n > 0 { Some(n - 1) } else { None };
        let unroll_end = n - usize::from(bs.vectorize); // exclusive
        let unroll_start = unroll_end.saturating_sub(bs.unroll);
        for (pos, &(axis, level)) in bs.order.iter().enumerate() {
            let is_red = blk.axes[axis].kind == AxisKind::Reduction;
            let mut kind = LoopKind::Serial;
            if pos < bs.parallel && !is_red {
                kind = if gpu { LoopKind::BlockIdx } else { LoopKind::Parallel };
            } else if gpu && pos < bs.parallel + bs.thread_tiles && !is_red {
                kind = LoopKind::ThreadIdx;
            } else if Some(pos) == vec_pos && !is_red {
                kind = LoopKind::Vectorized;
            } else if pos >= unroll_start && pos < unroll_end {
                kind = LoopKind::Unrolled;
            }
            loops.push(LoopInfo {
                axis,
                level,
                extent: bs.tiles[axis][level],
                kind,
                is_reduction: is_red,
            });
        }
        LoopNest { loops }
    }

    /// Structural validation over every block.
    pub fn validate(&self) -> Result<(), String> {
        for b in 0..self.blocks.len() {
            self.blocks[b].validate(&self.workload, b)?;
        }
        Ok(())
    }

    /// A cheap structural fingerprint (used for dedup in search). A fold
    /// of the per-block fingerprints ([`BlockSched::fingerprint`]), so a
    /// schedule that shares N-1 of its N blocks with an already
    /// fingerprinted parent hashes only the one block that changed.
    /// Lazily computed once per schedule instance and cached — repeated
    /// evaluation-cache lookups on the same schedule pay O(1); the cache
    /// is invalidated by [`Schedule::block_mut`] and carried across
    /// clones (clones are structurally identical by construction).
    pub fn fingerprint(&self) -> u64 {
        *self.fp.get_or_init(|| {
            let mut h = FNV_OFFSET;
            for bs in &self.blocks {
                h = fnv_u64(h, bs.fingerprint());
            }
            h
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gemm;

    fn sched() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(64, 64, 64)))
    }

    #[test]
    fn initial_schedule_validates() {
        let s = sched();
        s.validate().unwrap();
        assert_eq!(s.blocks[0].n_loops(), 3);
    }

    #[test]
    fn retile_keeps_permutation() {
        let mut s = sched();
        s.block_mut(0).retile(0, vec![4, 4, 4]);
        s.validate().unwrap();
        assert_eq!(s.blocks[0].n_loops(), 5);
        s.block_mut(0).retile(0, vec![64]);
        s.validate().unwrap();
        assert_eq!(s.blocks[0].n_loops(), 3);
    }

    #[test]
    fn loop_nest_kinds() {
        let mut s = sched();
        s.block_mut(0).retile(0, vec![8, 8]);
        s.block_mut(0).retile(1, vec![8, 8]);
        s.block_mut(0).parallel = 2;
        s.block_mut(0).vectorize = true;
        // order: i0 i1 j0 j1 k -> reorder so spatial j1 is innermost
        s.block_mut(0).order = vec![(0, 0), (1, 0), (0, 1), (2, 0), (1, 1)];
        let nest = s.loop_nest(0, false);
        assert_eq!(nest.parallel_extent(), 64);
        assert_eq!(nest.vector_lanes(), 8);
        s.validate().unwrap();
    }

    #[test]
    fn reduction_never_parallel_or_vector() {
        let mut s = sched();
        s.block_mut(0).parallel = 3; // would cover k
        s.block_mut(0).vectorize = true; // innermost is k
        let nest = s.loop_nest(0, false);
        let k_loop = nest.loops.iter().find(|l| l.is_reduction).unwrap();
        assert_eq!(k_loop.kind, LoopKind::Serial);
    }

    #[test]
    fn gpu_thread_binding() {
        let mut s = sched();
        s.block_mut(0).retile(0, vec![8, 8]);
        s.block_mut(0).retile(1, vec![8, 8]);
        s.block_mut(0).order = vec![(0, 0), (1, 0), (0, 1), (1, 1), (2, 0)];
        s.block_mut(0).parallel = 2;
        s.block_mut(0).thread_tiles = 2;
        let nest = s.loop_nest(0, true);
        assert_eq!(nest.parallel_extent(), 64); // blockIdx product
        assert_eq!(nest.thread_extent(), 64);
    }

    #[test]
    fn fingerprints_differ() {
        let a = sched();
        let mut b = sched();
        b.block_mut(0).vectorize = true;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_cached_and_invalidated_on_mutation() {
        let mut s = sched();
        let f0 = s.fingerprint();
        assert_eq!(s.fingerprint(), f0); // cached value is stable
        assert_eq!(s.clone().fingerprint(), f0); // clones carry the cache
        s.block_mut(0).vectorize = true;
        let f1 = s.fingerprint();
        assert_ne!(f0, f1, "block_mut must invalidate the cache");
        s.block_mut(0).vectorize = false;
        assert_eq!(s.fingerprint(), f0, "fingerprint is structural");
    }

    #[test]
    fn clone_is_copy_on_write() {
        let a = sched();
        let mut b = a.clone();
        assert!(Arc::ptr_eq(&a.blocks[0], &b.blocks[0]), "clone shares blocks");
        b.block_mut(0).parallel = 2;
        assert!(!Arc::ptr_eq(&a.blocks[0], &b.blocks[0]), "mutation unshares");
        assert_eq!(a.blocks[0].parallel, 0, "original untouched");
        assert_eq!(b.blocks[0].parallel, 2);
    }

    #[test]
    fn validate_catches_bad_factors() {
        let mut s = sched();
        s.block_mut(0).tiles[0] = vec![3, 5]; // 15 != 64
        assert!(s.validate().is_err());
    }

    #[test]
    fn block_fingerprint_cached_and_invalidated_by_block_mut() {
        let mut s = sched();
        let f0 = s.blocks[0].fingerprint();
        assert_eq!(s.blocks[0].fingerprint(), f0, "cached value stable");
        // block_mut must clear the cache even when the Arc is unshared
        // (make_mut performs no clone then) — mutate-through-block_mut is
        // the invariant the block memo's keys depend on
        s.block_mut(0).vectorize = true;
        let f1 = s.blocks[0].fingerprint();
        assert_ne!(f0, f1);
        s.block_mut(0).vectorize = false;
        assert_eq!(s.blocks[0].fingerprint(), f0, "fingerprint is structural");
    }

    #[test]
    fn schedule_fingerprint_is_fold_of_block_fingerprints() {
        let mut s = sched();
        s.block_mut(0).parallel = 1;
        let mut expect = crate::util::fnv::FNV_OFFSET;
        for b in &s.blocks {
            expect = fnv_u64(expect, b.fingerprint());
        }
        assert_eq!(s.fingerprint(), expect);
    }

    #[test]
    fn unchanged_blocks_keep_their_fingerprint_across_cow() {
        // the incremental-evaluation contract: a child schedule shares
        // untouched blocks with its parent, Arc and fingerprint cache
        // included — only the mutated block re-fingerprints
        let w = Arc::new(crate::workloads::mlp::llama4_mlp());
        let a = Schedule::initial(w);
        let fps: Vec<u64> = a.blocks.iter().map(|b| b.fingerprint()).collect();
        let mut b = a.clone();
        b.block_mut(1).unroll = 2;
        for (i, fp) in fps.iter().enumerate() {
            assert_eq!(a.blocks[i].fingerprint(), *fp);
            if i == 1 {
                assert_ne!(b.blocks[i].fingerprint(), *fp, "mutated block re-keys");
                assert!(!Arc::ptr_eq(&a.blocks[i], &b.blocks[i]));
            } else {
                assert_eq!(b.blocks[i].fingerprint(), *fp, "untouched block keeps key");
                assert!(Arc::ptr_eq(&a.blocks[i], &b.blocks[i]));
            }
        }
        // equality ignores the fingerprint cache: a fresh structural twin
        // (never fingerprinted) compares equal to a fingerprinted block
        let fresh = BlockSched::default_for(&a.workload, 0);
        a.blocks[0].fingerprint();
        assert_eq!(*a.blocks[0], fresh);
    }
}

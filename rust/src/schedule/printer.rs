//! TVMScript-like rendering of a *scheduled* program — the text the LLM
//! prompts show for the current/parent/grandparent program variants
//! (paper Appendix B).

use super::{LoopKind, Schedule};

fn kind_str(k: LoopKind) -> &'static str {
    match k {
        LoopKind::Serial => "T.serial",
        LoopKind::Parallel => "T.parallel",
        LoopKind::Vectorized => "T.vectorized",
        LoopKind::Unrolled => "T.unroll",
        LoopKind::BlockIdx => "T.thread_binding(\"blockIdx.x\")",
        LoopKind::ThreadIdx => "T.thread_binding(\"threadIdx.x\")",
    }
}

/// Render one block's scheduled loop nest.
pub fn print_block(s: &Schedule, block: usize, gpu: bool) -> String {
    let blk = &s.workload.blocks[block];
    let bs = &s.blocks[block];
    let nest = s.loop_nest(block, gpu);
    let mut out = String::new();
    let mut indent = 1usize;

    if bs.cache_write {
        out.push_str(&"    ".repeat(indent));
        let buf = &s.workload.buffers[blk.writes[0].buffer];
        out.push_str(&format!(
            "{}_local = T.alloc_buffer(({}), scope=\"{}\")\n",
            buf.name,
            buf.shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", "),
            if gpu { "local" } else { "global" }
        ));
    }
    if let Some(d) = bs.compute_at {
        out.push_str(&"    ".repeat(indent));
        out.push_str(&format!("# computed at consumer depth {d}\n"));
    }

    for l in &nest.loops {
        out.push_str(&"    ".repeat(indent));
        let var = format!("{}_{}", blk.axes[l.axis].name, l.level);
        out.push_str(&format!("for {var} in {}({}):\n", kind_str(l.kind), l.extent));
        indent += 1;
        // show cache_read staging at the right depth
        for (ri, cr) in bs.cache_reads.iter().enumerate() {
            if *cr == Some(indent - 2) {
                out.push_str(&"    ".repeat(indent));
                let buf = &s.workload.buffers[blk.reads[ri].buffer];
                out.push_str(&format!(
                    "{}_{} = T.cache_read({})\n",
                    buf.name,
                    if gpu { "shared" } else { "local" },
                    buf.name
                ));
            }
        }
    }
    out.push_str(&"    ".repeat(indent));
    out.push_str(&format!("with T.block(\"{}\"):\n", blk.name));
    out.push_str(&"    ".repeat(indent + 1));
    // body expression with tiled index names
    let fmt_access = |acc: &crate::tir::Access| -> String {
        let idx: Vec<String> = acc
            .dim_axes
            .iter()
            .map(|dims| {
                if dims.is_empty() {
                    "0".to_string()
                } else {
                    dims.iter()
                        .map(|&a| blk.axes[a].name.clone())
                        .collect::<Vec<_>>()
                        .join(" + ")
                }
            })
            .collect();
        format!("{}[{}]", s.workload.buffers[acc.buffer].name, idx.join(", "))
    };
    let w = fmt_access(&blk.writes[0]);
    let reads: Vec<String> = blk.reads.iter().map(fmt_access).collect();
    use crate::tir::BodyKind::*;
    let body = match blk.body {
        Mac => format!("{w} = {w} + {}", reads.join(" * ")),
        Elementwise => format!("{w} = f({})", reads.join(", ")),
        Transcendental => format!("{w} = T.exp({})", reads.join(", ")),
        Reduce => format!("{w} = T.max({w}, {})", reads.join(", ")),
        Copy => format!("{w} = {}", reads.first().cloned().unwrap_or_default()),
    };
    out.push_str(&body);
    out.push('\n');
    out
}

/// Render the whole scheduled program (all blocks).
pub fn print_schedule(s: &Schedule, gpu: bool) -> String {
    let mut out = String::from("@T.prim_func\n");
    out.push_str(&crate::tir::printer::signature(&s.workload));
    out.push('\n');
    for b in 0..s.workload.blocks.len() {
        out.push_str(&print_block(s, b, gpu));
    }
    out
}

/// Compact rendering of just the dominant block (prompt budget control).
pub fn print_dominant(s: &Schedule, gpu: bool) -> String {
    let mut out = String::from("@T.prim_func\n");
    out.push_str(&crate::tir::printer::signature(&s.workload));
    out.push('\n');
    out.push_str(&print_block(s, s.workload.dominant_block(), gpu));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    #[test]
    fn prints_scheduled_loops() {
        let mut rng = Rng::new(1);
        let s0 = Schedule::initial(Arc::new(gemm::gemm(64, 64, 64)));
        let s1 = apply(&s0, TransformKind::Vectorize, &mut rng, false).unwrap();
        let s2 = apply(&s1, TransformKind::Parallel, &mut rng, false).unwrap();
        let text = print_schedule(&s2, false);
        assert!(text.contains("T.vectorized"));
        assert!(text.contains("T.parallel"));
        assert!(text.contains("with T.block(\"matmul\")"));
    }

    #[test]
    fn gpu_bindings_render() {
        let mut rng = Rng::new(2);
        let s0 = Schedule::initial(Arc::new(gemm::gemm(64, 64, 64)));
        let s1 = apply(&s0, TransformKind::Parallel, &mut rng, true).unwrap();
        let s2 = apply(&s1, TransformKind::ThreadBind, &mut rng, true).unwrap();
        let text = print_schedule(&s2, true);
        assert!(text.contains("blockIdx.x"));
    }

    #[test]
    fn dominant_print_shorter() {
        let s = Schedule::initial(Arc::new(crate::workloads::attention::small_attention(
            64, 2, 16, false,
        )));
        assert!(print_dominant(&s, false).len() <= print_schedule(&s, false).len());
    }
}

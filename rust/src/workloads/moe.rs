//! DeepSeek-R1-style mixture-of-experts layer.
//!
//! Router matmul + gate softmax + top-k expert FFN (SwiGLU) + weighted
//! combine. The expert compute is modeled with an explicit `expert_sel`
//! axis of extent `top_k` — every token flows through `top_k` experts, the
//! standard dense formulation of the sparse dispatch (capacity factor 1.0).

use super::builder::WorkloadBuilder;
use crate::tir::{Access, Axis, BlockDef, BodyKind, Workload};

#[derive(Clone, Copy, Debug)]
pub struct MoeParams {
    pub tokens: i64,
    pub d_model: i64,
    pub d_ff: i64,
    pub n_experts: i64,
    pub top_k: i64,
}

pub fn moe(name: &str, p: MoeParams) -> Workload {
    let mut b = WorkloadBuilder::new(name);
    let x = b.f32("X", &[p.tokens, p.d_model]);
    let w_router = b.f32("Wr", &[p.d_model, p.n_experts]);
    let logits = b.f32("L", &[p.tokens, p.n_experts]);
    let gates = b.f32("G", &[p.tokens, p.n_experts]);
    let w_gate = b.f32("Wg", &[p.n_experts, p.d_model, p.d_ff]);
    let w_up = b.f32("Wu", &[p.n_experts, p.d_model, p.d_ff]);
    let w_down = b.f32("Wd", &[p.n_experts, p.d_ff, p.d_model]);
    let h = b.f32("H", &[p.top_k, p.tokens, p.d_ff]);
    let ff = b.f32("F", &[p.top_k, p.tokens, p.d_model]);
    let y = b.f32("Y", &[p.tokens, p.d_model]);

    let router = b.matmul(
        "router",
        None,
        p.tokens,
        p.n_experts,
        p.d_model,
        x,
        w_router,
        logits,
        false,
        vec![],
    );
    let gate_sm = b.softmax("gate_softmax", &[p.tokens], p.n_experts, logits, gates, vec![router]);

    // expert gate+up matmul: axes (sel, token, ff, red d_model); the
    // selected expert's weight slab is indexed by `sel` (stride into the
    // per-expert weight tensor).
    let gate_up = {
        let axes = vec![
            Axis::spatial("sel", p.top_k),
            Axis::spatial("t", p.tokens),
            Axis::spatial("f", p.d_ff),
            Axis::reduction("c", p.d_model),
        ];
        b_block(
            &mut b,
            BlockDef {
                name: "expert_gate_up".into(),
                axes,
                reads: vec![
                    Access::new(x, vec![vec![1], vec![3]]),
                    Access::new(w_gate, vec![vec![0], vec![3], vec![2]]),
                    Access::new(w_up, vec![vec![0], vec![3], vec![2]]),
                ],
                writes: vec![Access::new(h, vec![vec![0], vec![1], vec![2]])],
                body: BodyKind::Mac,
                flops_per_point: 4.0, // two fused matmuls
                producers: vec![gate_sm],
            },
        )
    };

    // silu(gate) * up folded into gate_up's flops; down projection:
    let down = {
        let axes = vec![
            Axis::spatial("sel", p.top_k),
            Axis::spatial("t", p.tokens),
            Axis::spatial("d", p.d_model),
            Axis::reduction("f", p.d_ff),
        ];
        b_block(
            &mut b,
            BlockDef {
                name: "expert_down".into(),
                axes,
                reads: vec![
                    Access::new(h, vec![vec![0], vec![1], vec![3]]),
                    Access::new(w_down, vec![vec![0], vec![3], vec![2]]),
                ],
                writes: vec![Access::new(ff, vec![vec![0], vec![1], vec![2]])],
                body: BodyKind::Mac,
                flops_per_point: 2.0,
                producers: vec![gate_up],
            },
        )
    };

    // combine: y[t,d] = sum_sel gate * ff[sel,t,d]
    let axes = vec![
        Axis::spatial("t", p.tokens),
        Axis::spatial("d", p.d_model),
        Axis::reduction("sel", p.top_k),
    ];
    b_block(
        &mut b,
        BlockDef {
            name: "combine".into(),
            axes,
            reads: vec![
                Access::new(ff, vec![vec![2], vec![0], vec![1]]),
                Access::new(gates, vec![vec![0], vec![]]),
            ],
            writes: vec![Access::new(y, vec![vec![0], vec![1]])],
            body: BodyKind::Mac,
            flops_per_point: 2.0,
            producers: vec![down],
        },
    );

    b.build()
}

/// Escape hatch: push a hand-built block through the builder.
fn b_block(b: &mut WorkloadBuilder, blk: BlockDef) -> usize {
    b.push_block(blk)
}

/// DeepSeek-R1-style MoE layer at representative scale: 1024 tokens,
/// d_model 2048, per-expert FFN 4096, 8 routed experts, top-2.
pub fn deepseek_moe() -> Workload {
    moe(
        "deepseek_moe",
        MoeParams {
            tokens: 1024,
            d_model: 2048,
            d_ff: 4096,
            n_experts: 8,
            top_k: 2,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moe_structure() {
        let w = deepseek_moe();
        w.validate().unwrap();
        let names: Vec<&str> = w.blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(
            names,
            ["router", "gate_softmax", "expert_gate_up", "expert_down", "combine"]
        );
        assert_eq!(w.blocks[w.dominant_block()].name, "expert_gate_up");
    }

    #[test]
    fn expert_flops_scale_with_topk() {
        let base = MoeParams {
            tokens: 64,
            d_model: 128,
            d_ff: 256,
            n_experts: 8,
            top_k: 2,
        };
        let w2 = moe("m2", base);
        let w4 = moe("m4", MoeParams { top_k: 4, ..base });
        assert!(w4.flops() > w2.flops() * 1.8);
    }

    #[test]
    fn broadcast_gate_access() {
        let w = deepseek_moe();
        let combine = w.blocks.iter().find(|b| b.name == "combine").unwrap();
        // gates access second dim is broadcast (empty axis list)
        assert!(combine.reads[1].dim_axes[1].is_empty());
    }
}

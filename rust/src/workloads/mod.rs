//! The paper's benchmark workloads (§3.1) as tensor-IR definitions.
//!
//! Five representative kernels drawn from production-scale networks, at the
//! paper's full-model dimensions, plus a GEMM micro-workload and the
//! end-to-end Llama-3-8B layer graph:
//!
//! * [`attention::llama3_attention`] — self-attention layer of Llama-3-8B
//! * [`moe::deepseek_moe`]           — MoE layer of DeepSeek-R1
//! * [`attention::flux_attention`]   — self-attention layer of FLUX
//! * [`conv::flux_conv`]             — convolution layer of FLUX
//! * [`mlp::llama4_mlp`]             — MLP layer of Llama-4-Scout
//! * [`gemm::gemm`]                  — plain GEMM (tests / quickstart)
//! * [`llama_e2e::llama3_8b_graph`]  — full-model layer graph (Table 3)

pub mod builder;
pub mod attention;
pub mod moe;
pub mod conv;
pub mod mlp;
pub mod gemm;
pub mod llama_e2e;
pub mod scenarios;

use crate::tir::Workload;

/// The five paper benchmarks, in the order the paper's tables list them.
pub fn paper_benchmarks() -> Vec<Workload> {
    vec![
        attention::llama3_attention(),
        moe::deepseek_moe(),
        attention::flux_attention(),
        conv::flux_conv(),
        mlp::llama4_mlp(),
    ]
}

/// The fixed registry of hand-built benchmark workloads.
fn registry(name: &str) -> Option<Workload> {
    match name {
        "llama3_attention" => Some(attention::llama3_attention()),
        "deepseek_moe" => Some(moe::deepseek_moe()),
        "flux_attention" => Some(attention::flux_attention()),
        "flux_conv" => Some(conv::flux_conv()),
        "llama4_mlp" => Some(mlp::llama4_mlp()),
        "gemm" => Some(gemm::gemm(1024, 1024, 1024)),
        _ => None,
    }
}

/// Resolve a workload name with a diagnostic error: the fixed registry
/// first, then the scenario grammar (`family` or `family@key=val,...`,
/// see [`scenarios`]). This is the CLI-facing twin of [`by_name`] —
/// same resolution, but a failed scenario parse explains *why*.
pub fn resolve(name: &str) -> Result<Workload, String> {
    if let Some(w) = registry(name) {
        return Ok(w);
    }
    scenarios::ScenarioSpec::parse(name)?.lower()
}

/// Look a workload up by name: the fixed registry, plus every name the
/// scenario grammar accepts (so CLIs, [`crate::coordinator::RunSpec`]s,
/// and the drivers take `attention@seq=1024,heads=16` wherever they
/// take `llama3_attention`).
pub fn by_name(name: &str) -> Option<Workload> {
    resolve(name).ok()
}

/// Paper display names, aligned with `paper_benchmarks()` order.
pub const PAPER_BENCH_LABELS: [&str; 5] = [
    "Llama-3-8B Attention Layer",
    "DeepSeek-R1 MoE Layer",
    "FLUX Attention Layer",
    "FLUX Convolution Layer",
    "Llama-4-Scout MLP Layer",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_validate() {
        for w in paper_benchmarks() {
            w.validate().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert!(w.flops() > 1e9, "{} suspiciously small", w.name);
        }
    }

    #[test]
    fn registry_lookup() {
        for name in [
            "llama3_attention",
            "deepseek_moe",
            "flux_attention",
            "flux_conv",
            "llama4_mlp",
            "gemm",
        ] {
            assert!(by_name(name).is_some(), "{name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn labels_align() {
        let benches = paper_benchmarks();
        assert_eq!(benches.len(), PAPER_BENCH_LABELS.len());
    }

    #[test]
    fn by_name_accepts_scenario_grammar() {
        let w = by_name("attention@head_dim=32,heads=4,seq=256").unwrap();
        assert_eq!(w.name, "attention@head_dim=32,heads=4,seq=256");
        assert_eq!(w.blocks.len(), 6);
        // same point through the explicit scenario API
        let spec = scenarios::ScenarioSpec::parse(&w.name).unwrap();
        assert_eq!(spec.lower().unwrap().flops(), w.flops());
        // malformed scenario names stay unknown, with a diagnostic via resolve
        assert!(by_name("attention@heads=zero").is_none());
        assert!(resolve("attention@heads=zero").is_err());
        assert!(resolve("llama3_attention").is_ok());
    }
}

//! Plain GEMM micro-workload — the quickstart example and the schedule /
//! simulator unit-test substrate.

use super::builder::WorkloadBuilder;
use crate::tir::Workload;

/// C[m,n] = A[m,k] @ B[k,n], f32.
pub fn gemm(m: i64, n: i64, k: i64) -> Workload {
    let mut b = WorkloadBuilder::new("gemm");
    let a = b.f32("A", &[m, k]);
    let w = b.f32("B", &[k, n]);
    let c = b.f32("C", &[m, n]);
    b.matmul("matmul", None, m, n, k, a, w, c, false, vec![]);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flops() {
        let w = gemm(128, 64, 32);
        assert_eq!(w.flops() as i64, 2 * 128 * 64 * 32);
    }
}

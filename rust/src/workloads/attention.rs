//! Self-attention workloads: Llama-3-8B (causal) and FLUX (non-causal
//! image-token attention).
//!
//! Block DAG (the classic 5-stage attention pipeline + residual):
//!   qkv_proj -> scores -> softmax -> av -> out_proj -> residual
//!
//! Weight and activation tensors are declared in their *view* shapes
//! (e.g. Wqkv as [3, d, heads, head_dim]) so every buffer dimension is
//! indexed by single block axes — the affine form the footprint analysis
//! consumes. Causality is modeled as a 0.5× effective KV extent on the
//! scores / softmax / av blocks (the simulator needs work and traffic, not
//! the triangular structure itself).

use super::builder::WorkloadBuilder;
use crate::tir::{Access, Axis, BlockDef, BodyKind, Workload};

/// Parameters of an attention layer.
#[derive(Clone, Copy, Debug)]
pub struct AttnParams {
    pub seq: i64,
    pub heads: i64,
    pub head_dim: i64,
    pub causal: bool,
}

impl AttnParams {
    pub fn d_model(&self) -> i64 {
        self.heads * self.head_dim
    }
}

/// Build the 6-block attention workload.
pub fn attention(name: &str, p: AttnParams) -> Workload {
    let d = p.d_model();
    let kv = if p.causal { p.seq / 2 } else { p.seq };

    let mut b = WorkloadBuilder::new(name);
    let x = b.f32("X", &[p.seq, d]);
    let wqkv = b.f32("Wqkv", &[3, d, p.heads, p.head_dim]);
    let qkv = b.f32("QKV", &[3, p.heads, p.seq, p.head_dim]);
    let s_buf = b.f32("S", &[p.heads, p.seq, kv]);
    let p_buf = b.f32("P", &[p.heads, p.seq, kv]);
    let o_buf = b.f32("O", &[p.heads, p.seq, p.head_dim]);
    let wo = b.f32("Wo", &[p.heads, p.head_dim, d]);
    let y = b.f32("Y", &[p.seq, d]);

    // qkv_proj: QKV[w,h,s,dh] += X[s,c] * Wqkv[w,c,h,dh]
    let qkv_blk = b.push_block(BlockDef {
        name: "qkv_proj".into(),
        axes: vec![
            Axis::spatial("w", 3),
            Axis::spatial("h", p.heads),
            Axis::spatial("s", p.seq),
            Axis::spatial("dh", p.head_dim),
            Axis::reduction("c", d),
        ],
        reads: vec![
            Access::new(x, vec![vec![2], vec![4]]),
            Access::new(wqkv, vec![vec![0], vec![4], vec![1], vec![3]]),
        ],
        writes: vec![Access::new(qkv, vec![vec![0], vec![1], vec![2], vec![3]])],
        body: BodyKind::Mac,
        flops_per_point: 2.0,
        producers: vec![],
    });

    // scores: S[h,sq,sk] += Q[h,sq,dh] * K[h,sk,dh]
    let s_blk = b.push_block(BlockDef {
        name: "scores".into(),
        axes: vec![
            Axis::spatial("h", p.heads),
            Axis::spatial("sq", p.seq),
            Axis::spatial("sk", kv),
            Axis::reduction("dh", p.head_dim),
        ],
        reads: vec![
            Access::new(qkv, vec![vec![], vec![0], vec![1], vec![3]]), // Q slab
            Access::new(qkv, vec![vec![], vec![0], vec![2], vec![3]]), // K slab
        ],
        writes: vec![Access::new(s_buf, vec![vec![0], vec![1], vec![2]])],
        body: BodyKind::Mac,
        flops_per_point: 2.0,
        producers: vec![qkv_blk],
    });

    let sm_blk = b.softmax("softmax", &[p.heads, p.seq], kv, s_buf, p_buf, vec![s_blk]);

    // av: O[h,sq,dh] += P[h,sq,sk] * V[h,sk,dh]
    let av_blk = b.push_block(BlockDef {
        name: "av".into(),
        axes: vec![
            Axis::spatial("h", p.heads),
            Axis::spatial("sq", p.seq),
            Axis::spatial("dh", p.head_dim),
            Axis::reduction("sk", kv),
        ],
        reads: vec![
            Access::new(p_buf, vec![vec![0], vec![1], vec![3]]),
            Access::new(qkv, vec![vec![], vec![0], vec![3], vec![2]]), // V slab
        ],
        writes: vec![Access::new(o_buf, vec![vec![0], vec![1], vec![2]])],
        body: BodyKind::Mac,
        flops_per_point: 2.0,
        producers: vec![sm_blk],
    });

    // out_proj: Y[s,j] += O[h,s,dh] * Wo[h,dh,j]
    let o_blk = b.push_block(BlockDef {
        name: "out_proj".into(),
        axes: vec![
            Axis::spatial("s", p.seq),
            Axis::spatial("j", d),
            Axis::reduction("h", p.heads),
            Axis::reduction("dh", p.head_dim),
        ],
        reads: vec![
            Access::new(o_buf, vec![vec![2], vec![0], vec![3]]),
            Access::new(wo, vec![vec![2], vec![3], vec![1]]),
        ],
        writes: vec![Access::new(y, vec![vec![0], vec![1]])],
        body: BodyKind::Mac,
        flops_per_point: 2.0,
        producers: vec![av_blk],
    });

    b.elementwise(
        "residual",
        &[p.seq, d],
        &[y, x],
        y,
        BodyKind::Elementwise,
        1.0,
        vec![o_blk],
    );
    b.build()
}

/// Llama-3-8B self-attention: d_model=4096, 32 heads, head_dim=128,
/// context 2048, causal.
pub fn llama3_attention() -> Workload {
    attention(
        "llama3_attention",
        AttnParams {
            seq: 2048,
            heads: 32,
            head_dim: 128,
            causal: true,
        },
    )
}

/// FLUX (stable diffusion) attention: 24 heads x 128 over 4096 image
/// tokens, non-causal.
pub fn flux_attention() -> Workload {
    attention(
        "flux_attention",
        AttnParams {
            seq: 4096,
            heads: 24,
            head_dim: 128,
            causal: false,
        },
    )
}

/// Scaled-down attention for e2e graphs and fast tests.
pub fn small_attention(seq: i64, heads: i64, head_dim: i64, causal: bool) -> Workload {
    attention(
        "small_attention",
        AttnParams {
            seq,
            heads,
            head_dim,
            causal,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_attention_structure() {
        let w = llama3_attention();
        w.validate().unwrap();
        let names: Vec<&str> = w.blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(
            names,
            ["qkv_proj", "scores", "softmax", "av", "out_proj", "residual"]
        );
        assert_eq!(w.blocks[w.dominant_block()].name, "qkv_proj");
    }

    #[test]
    fn causal_halves_score_work() {
        let c = llama3_attention();
        let f = attention(
            "nc",
            AttnParams {
                seq: 2048,
                heads: 32,
                head_dim: 128,
                causal: false,
            },
        );
        let score_flops = |w: &Workload| {
            w.blocks.iter().find(|b| b.name == "scores").unwrap().flops()
        };
        assert!((score_flops(&c) * 2.0 - score_flops(&f)).abs() < 1.0);
    }

    #[test]
    fn flux_attention_bigger_seq() {
        let w = flux_attention();
        w.validate().unwrap();
        assert!(w.flops() > 1e11);
    }

    #[test]
    fn producer_graph_is_chain() {
        let w = llama3_attention();
        let cons = w.consumers();
        assert!(cons[0].contains(&1)); // qkv_proj feeds scores
        assert!(cons[3].contains(&4)); // av feeds out_proj
    }

    #[test]
    fn qkv_flops_match_projection_math() {
        let p = AttnParams {
            seq: 64,
            heads: 2,
            head_dim: 16,
            causal: false,
        };
        let w = attention("t", p);
        let qkv = w.blocks.iter().find(|b| b.name == "qkv_proj").unwrap();
        let d = p.d_model();
        assert_eq!(qkv.flops() as i64, 2 * 3 * p.seq * d * d);
    }
}

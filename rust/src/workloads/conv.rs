//! FLUX (stable-diffusion) convolution layer, lowered as im2col + GEMM —
//! the lowering both TVM and our Layer-2 JAX model use, so the rust-side
//! search space matches the executable artifact's structure.

use super::builder::WorkloadBuilder;
use crate::tir::{Access, Axis, BodyKind, Workload};

#[derive(Clone, Copy, Debug)]
pub struct ConvParams {
    pub h: i64,
    pub w: i64,
    pub c_in: i64,
    pub c_out: i64,
    pub kh: i64,
    pub kw: i64,
}

pub fn conv2d(name: &str, p: ConvParams) -> Workload {
    let oh = p.h - p.kh + 1;
    let ow = p.w - p.kw + 1;
    let kdim = p.kh * p.kw * p.c_in;

    let mut b = WorkloadBuilder::new(name);
    let x = b.f32("X", &[p.h, p.w, p.c_in]);
    let patches = b.f32("Patches", &[oh * ow, kdim]);
    let wgt = b.f32("W", &[kdim, p.c_out]);
    let y = b.f32("Y", &[oh * ow, p.c_out]);

    // im2col: axes (oh, ow, kh, kw, c); reads X[oh+kh, ow+kw, c],
    // writes Patches[oh*ow, (kh kw c)] — modeled with the flattened
    // output dims indexed by their contributing axes.
    let im2col = {
        let axes = vec![
            Axis::spatial("oh", oh),
            Axis::spatial("ow", ow),
            Axis::spatial("kh", p.kh),
            Axis::spatial("kw", p.kw),
            Axis::spatial("c", p.c_in),
        ];
        let read = Access::new(x, vec![vec![0, 2], vec![1, 3], vec![4]]);
        let write = Access::new(patches, vec![vec![0, 1], vec![2, 3, 4]]);
        b.copy("im2col", axes, read, write, vec![])
    };

    // GEMM: (oh*ow) x c_out x kdim
    let gemm = b.matmul(
        "conv_gemm",
        None,
        oh * ow,
        p.c_out,
        kdim,
        patches,
        wgt,
        y,
        false,
        vec![im2col],
    );

    // epilogue: bias + SiLU
    b.elementwise(
        "bias_act",
        &[oh * ow, p.c_out],
        &[y],
        y,
        BodyKind::Elementwise,
        4.0,
        vec![gemm],
    );

    b.build()
}

/// FLUX conv layer at representative scale: 64x64 latent, 320 channels, 3x3.
pub fn flux_conv() -> Workload {
    conv2d(
        "flux_conv",
        ConvParams {
            h: 64,
            w: 64,
            c_in: 320,
            c_out: 320,
            kh: 3,
            kw: 3,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_structure() {
        let w = flux_conv();
        w.validate().unwrap();
        let names: Vec<&str> = w.blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(names, ["im2col", "conv_gemm", "bias_act"]);
        assert_eq!(w.blocks[w.dominant_block()].name, "conv_gemm");
    }

    #[test]
    fn gemm_flops_match_conv_math() {
        let p = ConvParams {
            h: 16,
            w: 16,
            c_in: 8,
            c_out: 4,
            kh: 3,
            kw: 3,
        };
        let wl = conv2d("c", p);
        let gemm = wl.blocks.iter().find(|b| b.name == "conv_gemm").unwrap();
        let oh = 14;
        let ow = 14;
        assert_eq!(
            gemm.flops() as i64,
            2 * oh * ow * p.c_out * p.kh * p.kw * p.c_in
        );
    }

    #[test]
    fn im2col_sliding_window_access() {
        let wl = flux_conv();
        let im = &wl.blocks[0];
        // X's first dim indexed by oh + kh (two axes)
        assert_eq!(im.reads[0].dim_axes[0], vec![0, 2]);
    }
}

//! Fluent builder for tensor-IR workloads — removes the boilerplate of
//! hand-writing `Access` index lists for the common block shapes
//! (matmul, batched matmul, elementwise epilogue, softmax, copy).

use crate::tir::{Access, Axis, BlockDef, BodyKind, Buffer, DType, Workload};

pub struct WorkloadBuilder {
    name: String,
    buffers: Vec<Buffer>,
    blocks: Vec<BlockDef>,
}

impl WorkloadBuilder {
    pub fn new(name: &str) -> Self {
        WorkloadBuilder {
            name: name.to_string(),
            buffers: Vec::new(),
            blocks: Vec::new(),
        }
    }

    pub fn buffer(&mut self, name: &str, shape: &[i64], dtype: DType) -> usize {
        self.buffers.push(Buffer::new(name, shape, dtype));
        self.buffers.len() - 1
    }

    pub fn f32(&mut self, name: &str, shape: &[i64]) -> usize {
        self.buffer(name, shape, DType::F32)
    }

    /// `out[b?, m, n] += lhs[b?, m, k] * rhs[k, n]` — optionally batched.
    /// Returns the block index. `producers` are fusion-graph edges.
    #[allow(clippy::too_many_arguments)]
    pub fn matmul(
        &mut self,
        name: &str,
        batch: Option<i64>,
        m: i64,
        n: i64,
        k: i64,
        lhs: usize,
        rhs: usize,
        out: usize,
        rhs_batched: bool,
        producers: Vec<usize>,
    ) -> usize {
        let mut axes = Vec::new();
        let mut ai = 0;
        let b_ax = batch.map(|b| {
            axes.push(Axis::spatial("b", b));
            ai += 1;
            ai - 1
        });
        let m_ax = {
            axes.push(Axis::spatial("i", m));
            ai += 1;
            ai - 1
        };
        let n_ax = {
            axes.push(Axis::spatial("j", n));
            ai += 1;
            ai - 1
        };
        let k_ax = {
            axes.push(Axis::reduction("k", k));
            ai += 1;
            ai - 1
        };

        let lhs_dims = match b_ax {
            Some(b) => vec![vec![b], vec![m_ax], vec![k_ax]],
            None => vec![vec![m_ax], vec![k_ax]],
        };
        let rhs_dims = match (b_ax, rhs_batched) {
            (Some(b), true) => vec![vec![b], vec![k_ax], vec![n_ax]],
            _ => vec![vec![k_ax], vec![n_ax]],
        };
        let out_dims = match b_ax {
            Some(b) => vec![vec![b], vec![m_ax], vec![n_ax]],
            None => vec![vec![m_ax], vec![n_ax]],
        };

        self.blocks.push(BlockDef {
            name: name.to_string(),
            axes,
            reads: vec![Access::new(lhs, lhs_dims), Access::new(rhs, rhs_dims)],
            writes: vec![Access::new(out, out_dims)],
            body: BodyKind::Mac,
            flops_per_point: 2.0,
            producers,
        });
        self.blocks.len() - 1
    }

    /// Elementwise block over `shape`; reads each input at the same
    /// coordinates it writes the output.
    pub fn elementwise(
        &mut self,
        name: &str,
        shape: &[i64],
        inputs: &[usize],
        out: usize,
        body: BodyKind,
        flops_per_point: f64,
        producers: Vec<usize>,
    ) -> usize {
        let axes: Vec<Axis> = shape
            .iter()
            .enumerate()
            .map(|(i, &e)| Axis::spatial(&format!("e{i}"), e))
            .collect();
        let dims: Vec<Vec<usize>> = (0..shape.len()).map(|i| vec![i]).collect();
        self.blocks.push(BlockDef {
            name: name.to_string(),
            axes,
            reads: inputs
                .iter()
                .map(|&b| Access::new(b, dims.clone()))
                .collect(),
            writes: vec![Access::new(out, dims)],
            body,
            flops_per_point,
            producers,
        });
        self.blocks.len() - 1
    }

    /// Row-softmax over `rows x cols` (reduction over the last dim, then a
    /// transcendental rescale). Modeled as one block with a reduction axis.
    pub fn softmax(
        &mut self,
        name: &str,
        rows_shape: &[i64],
        cols: i64,
        input: usize,
        out: usize,
        producers: Vec<usize>,
    ) -> usize {
        let mut axes: Vec<Axis> = rows_shape
            .iter()
            .enumerate()
            .map(|(i, &e)| Axis::spatial(&format!("r{i}"), e))
            .collect();
        axes.push(Axis::reduction("c", cols));
        let c_ax = axes.len() - 1;
        let mut dims: Vec<Vec<usize>> = (0..rows_shape.len()).map(|i| vec![i]).collect();
        dims.push(vec![c_ax]);
        self.blocks.push(BlockDef {
            name: name.to_string(),
            axes,
            reads: vec![Access::new(input, dims.clone())],
            writes: vec![Access::new(out, dims)],
            body: BodyKind::Transcendental,
            // exp + running max + sum + divide ≈ 8 flops/elem equivalent
            flops_per_point: 8.0,
            producers,
        });
        self.blocks.len() - 1
    }

    /// Data-movement block (im2col / layout change): reads `input` via the
    /// provided dims, writes `out` at its natural coordinates.
    #[allow(clippy::too_many_arguments)]
    pub fn copy(
        &mut self,
        name: &str,
        axes: Vec<Axis>,
        read: Access,
        write: Access,
        producers: Vec<usize>,
    ) -> usize {
        self.blocks.push(BlockDef {
            name: name.to_string(),
            axes,
            reads: vec![read],
            writes: vec![write],
            body: BodyKind::Copy,
            flops_per_point: 0.0,
            producers,
        });
        self.blocks.len() - 1
    }

    /// Escape hatch: append a hand-constructed block (for shapes the
    /// helpers don't cover, e.g. the MoE expert-selection axis).
    pub fn push_block(&mut self, blk: BlockDef) -> usize {
        self.blocks.push(blk);
        self.blocks.len() - 1
    }

    pub fn build(self) -> Workload {
        let w = Workload::new(self.name, self.buffers, self.blocks);
        w.validate()
            .unwrap_or_else(|e| panic!("workload {} invalid: {e}", w.name));
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_builder_shapes() {
        let mut b = WorkloadBuilder::new("t");
        let a = b.f32("A", &[32, 16]);
        let w = b.f32("B", &[16, 8]);
        let c = b.f32("C", &[32, 8]);
        b.matmul("mm", None, 32, 8, 16, a, w, c, false, vec![]);
        let wl = b.build();
        assert_eq!(wl.blocks[0].axes.len(), 3);
        assert_eq!(wl.flops(), 2.0 * 32.0 * 8.0 * 16.0);
    }

    #[test]
    fn batched_matmul_rhs_batched() {
        let mut b = WorkloadBuilder::new("t");
        let q = b.f32("Q", &[4, 32, 16]);
        let k = b.f32("K", &[4, 16, 32]);
        let s = b.f32("S", &[4, 32, 32]);
        b.matmul("scores", Some(4), 32, 32, 16, q, k, s, true, vec![]);
        let wl = b.build();
        assert_eq!(wl.blocks[0].axes.len(), 4);
        // rhs batched: K read has 3 dims
        assert_eq!(wl.blocks[0].reads[1].dim_axes.len(), 3);
    }

    #[test]
    fn softmax_block_has_reduction() {
        let mut b = WorkloadBuilder::new("t");
        let s = b.f32("S", &[4, 32, 32]);
        let p = b.f32("P", &[4, 32, 32]);
        b.softmax("softmax", &[4, 32], 32, s, p, vec![]);
        let wl = b.build();
        assert!(wl.blocks[0].has_reduction());
    }
}

//! Scenario subsystem: parameterized workload families + matrix expansion.
//!
//! COLT evaluates on six hand-built workloads; the sweep literature the
//! paper positions against (LiteCoOp's shape sweeps, REASONING COMPILER's
//! per-hardware grids) evaluates across *parameterized* scenario
//! matrices. This module makes those native:
//!
//! * [`ScenarioSpec`] — one point in a family's parameter space,
//!   deterministically lowered to a well-formed
//!   [`Workload`](crate::tir::Workload) through the same builders the
//!   hand-built benchmarks use. Every spec has a canonical *name*
//!   ([`ScenarioSpec::name`]) in the grammar `family@key=val,key2=val2`
//!   (keys sorted, values canonicalized), and
//!   [`crate::workloads::by_name`] parses that grammar — so every CLI,
//!   [`RunSpec`](crate::coordinator::RunSpec), and driver path accepts
//!   scenario names wherever it accepts a registry name.
//! * [`ScenarioGrid`] — a cross-product over per-key value lists
//!   (`m=256,512;k=64,128`), expanded to a deterministic
//!   `Vec<ScenarioSpec>` for the sweep drivers (`experiments sweep`,
//!   `collab_search --sweep`).
//!
//! The lowered workload's `name` **is** the canonical scenario name,
//! which also keys the evaluation cache
//! ([`crate::mcts::evalcache::trace_key`] folds the workload name):
//! distinct scenario points never share cache entries, identical points
//! always do — including across processes via the persistent cache file
//! (see [`crate::mcts::evalcache::EvalCache`]).
//!
//! # Families and keys
//!
//! | family      | keys (defaults)                                                        |
//! |-------------|------------------------------------------------------------------------|
//! | `gemm`      | `m`,`n`,`k` (1024), `batch` (absent = unbatched), `dtype` (f32)         |
//! | `attention` | `seq` (2048), `heads` (32), `head_dim` (128), `causal` (true), `dtype`  |
//! | `conv`      | `h`,`w` (64), `c_in`,`c_out` (320), `kh`,`kw` (3), `dtype`              |
//! | `mlp`       | `tokens` (1024), `d_model` (5120), `d_ff` (8192), `dtype`               |
//! | `moe`       | `tokens` (1024), `d_model` (2048), `d_ff` (4096), `experts` (8), `top_k` (2), `dtype` |
//! | `llama_e2e` | `seq` (2048), `heads` (32), `head_dim` (128), `d_ff` (14336), `causal`, `dtype` — one fused decoder layer (attention + SwiGLU FFN) |
//!
//! `dtype` values: `f32`, `bf16`, `f16`, `i32` (long aliases `float32`
//! etc. accepted, canonicalized to the short form). Unset keys take the
//! family defaults at lowering time; the canonical name lists only the
//! explicitly set keys.

use super::builder::WorkloadBuilder;
use super::{attention, conv, gemm, mlp, moe};
use crate::tir::{DType, Workload};
use std::collections::BTreeMap;

/// A parameterized workload family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Gemm,
    Attention,
    Conv,
    Mlp,
    Moe,
    /// One fused Llama-style decoder layer: the 6-block attention
    /// pipeline chained into a SwiGLU FFN reading its residual output.
    LlamaE2e,
}

/// Value type of one scenario parameter.
#[derive(Clone, Copy, Debug)]
enum Kind {
    Int,
    Bool,
    Dtype,
}

/// Per-dimension extent bound. Large enough for any realistic shape,
/// small enough that no family's buffer shape entry overflows `i64`
/// during construction; full iteration domains are additionally bounded
/// by [`MAX_DOMAIN_POINTS`] at lowering time.
pub const MAX_EXTENT: i64 = 1 << 20;

/// Bound on any lowered block's iteration-domain point count, checked in
/// [`ScenarioSpec::lower`] before the simulator can compute (and
/// overflow) `i64` products over the axes.
pub const MAX_DOMAIN_POINTS: f64 = 1e15;

/// Bound on one grid expansion ([`ScenarioGrid::expand`]) — a
/// fat-fingered cross product should fail loudly, not enqueue a
/// million searches.
pub const MAX_SCENARIOS: usize = 4096;

impl Family {
    pub const ALL: [Family; 6] = [
        Family::Gemm,
        Family::Attention,
        Family::Conv,
        Family::Mlp,
        Family::Moe,
        Family::LlamaE2e,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::Gemm => "gemm",
            Family::Attention => "attention",
            Family::Conv => "conv",
            Family::Mlp => "mlp",
            Family::Moe => "moe",
            Family::LlamaE2e => "llama_e2e",
        }
    }

    pub fn parse(s: &str) -> Result<Family, String> {
        Family::ALL
            .iter()
            .copied()
            .find(|f| f.name() == s)
            .ok_or_else(|| {
                format!(
                    "unknown scenario family {s:?} (families: {})",
                    Family::ALL.map(Family::name).join(", ")
                )
            })
    }

    fn schema(self) -> &'static [(&'static str, Kind)] {
        match self {
            Family::Gemm => &[
                ("m", Kind::Int),
                ("n", Kind::Int),
                ("k", Kind::Int),
                ("batch", Kind::Int),
                ("dtype", Kind::Dtype),
            ],
            Family::Attention => &[
                ("seq", Kind::Int),
                ("heads", Kind::Int),
                ("head_dim", Kind::Int),
                ("causal", Kind::Bool),
                ("dtype", Kind::Dtype),
            ],
            Family::Conv => &[
                ("h", Kind::Int),
                ("w", Kind::Int),
                ("c_in", Kind::Int),
                ("c_out", Kind::Int),
                ("kh", Kind::Int),
                ("kw", Kind::Int),
                ("dtype", Kind::Dtype),
            ],
            Family::Mlp => &[
                ("tokens", Kind::Int),
                ("d_model", Kind::Int),
                ("d_ff", Kind::Int),
                ("dtype", Kind::Dtype),
            ],
            Family::Moe => &[
                ("tokens", Kind::Int),
                ("d_model", Kind::Int),
                ("d_ff", Kind::Int),
                ("experts", Kind::Int),
                ("top_k", Kind::Int),
                ("dtype", Kind::Dtype),
            ],
            Family::LlamaE2e => &[
                ("seq", Kind::Int),
                ("heads", Kind::Int),
                ("head_dim", Kind::Int),
                ("d_ff", Kind::Int),
                ("causal", Kind::Bool),
                ("dtype", Kind::Dtype),
            ],
        }
    }

    /// The family's valid parameter keys, schema order.
    pub fn keys(self) -> Vec<&'static str> {
        self.schema().iter().map(|(k, _)| *k).collect()
    }
}

fn parse_dtype(s: &str) -> Option<DType> {
    match s {
        "f32" | "float32" => Some(DType::F32),
        "bf16" | "bfloat16" => Some(DType::BF16),
        "f16" | "float16" => Some(DType::F16),
        "i32" | "int32" => Some(DType::I32),
        _ => None,
    }
}

fn dtype_name(d: DType) -> &'static str {
    match d {
        DType::F32 => "f32",
        DType::BF16 => "bf16",
        DType::F16 => "f16",
        DType::I32 => "i32",
    }
}

fn canonicalize(kind: Kind, key: &str, val: &str) -> Result<String, String> {
    match kind {
        Kind::Int => {
            let v: i64 = val
                .parse()
                .map_err(|_| format!("{key}={val:?}: expected an integer"))?;
            if !(1..=MAX_EXTENT).contains(&v) {
                return Err(format!("{key}={v}: out of range 1..={MAX_EXTENT}"));
            }
            Ok(v.to_string())
        }
        Kind::Bool => match val {
            "true" | "1" => Ok("true".into()),
            "false" | "0" => Ok("false".into()),
            _ => Err(format!("{key}={val:?}: expected true/false")),
        },
        Kind::Dtype => {
            let d = parse_dtype(val)
                .ok_or_else(|| format!("{key}={val:?}: expected one of f32, bf16, f16, i32"))?;
            Ok(dtype_name(d).to_string())
        }
    }
}

/// One point in a family's parameter space. See the module docs for the
/// grammar and the per-family keys/defaults.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScenarioSpec {
    family: Family,
    /// Explicitly set parameters, key → canonical value rendering.
    /// `BTreeMap` ⇒ the canonical name lists keys in sorted order.
    params: BTreeMap<String, String>,
}

impl ScenarioSpec {
    /// All-defaults spec for a family.
    pub fn new(family: Family) -> ScenarioSpec {
        ScenarioSpec {
            family,
            params: BTreeMap::new(),
        }
    }

    pub fn family(&self) -> Family {
        self.family
    }

    /// Explicitly set parameters (canonical key → value renderings).
    pub fn params(&self) -> &BTreeMap<String, String> {
        &self.params
    }

    /// Set one parameter from its string form. Values are canonicalized
    /// (int normalization, bool/dtype aliases); unknown keys and
    /// malformed or out-of-range values are rejected. Setting a key
    /// twice keeps the last value.
    pub fn set(&mut self, key: &str, val: &str) -> Result<(), String> {
        let kind = self
            .family
            .schema()
            .iter()
            .find(|(k, _)| *k == key)
            .map(|&(_, kind)| kind)
            .ok_or_else(|| {
                format!(
                    "scenario family {}: unknown key {key:?} (valid: {})",
                    self.family.name(),
                    self.family.keys().join(", ")
                )
            })?;
        let canon = canonicalize(kind, key, val)
            .map_err(|e| format!("scenario family {}: {e}", self.family.name()))?;
        self.params.insert(key.to_string(), canon);
        Ok(())
    }

    /// Canonical name: `family` when no key is set, else
    /// `family@key=val,...` with keys sorted and values canonical.
    /// `parse(spec.name())` reproduces the spec exactly (the grammar's
    /// fixed point), and the lowered workload carries this name.
    pub fn name(&self) -> String {
        if self.params.is_empty() {
            return self.family.name().to_string();
        }
        let kv: Vec<String> = self
            .params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        format!("{}@{}", self.family.name(), kv.join(","))
    }

    /// Parse `family` or `family@key=val,key2=val2,...` (whitespace
    /// around keys/values tolerated, values canonicalized).
    pub fn parse(text: &str) -> Result<ScenarioSpec, String> {
        let (fam, rest) = match text.split_once('@') {
            Some((f, r)) => (f, Some(r)),
            None => (text, None),
        };
        let mut spec = ScenarioSpec::new(Family::parse(fam.trim())?);
        if let Some(rest) = rest {
            if rest.trim().is_empty() {
                return Err(format!("scenario {text:?}: empty parameter list after '@'"));
            }
            for kv in rest.split(',') {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("scenario {text:?}: expected key=value, got {kv:?}"))?;
                spec.set(k.trim(), v.trim())?;
            }
        }
        Ok(spec)
    }

    // --- typed accessors over the canonical params (canonicalization in
    // `set` guarantees these parses cannot fail) ---

    fn int_or(&self, key: &str, default: i64) -> i64 {
        self.params
            .get(key)
            .map(|v| v.parse().expect("canonical int"))
            .unwrap_or(default)
    }

    fn opt_int(&self, key: &str) -> Option<i64> {
        self.params.get(key).map(|v| v.parse().expect("canonical int"))
    }

    fn bool_or(&self, key: &str, default: bool) -> bool {
        self.params.get(key).map(|v| v == "true").unwrap_or(default)
    }

    fn dtype(&self) -> DType {
        self.params
            .get("dtype")
            .and_then(|v| parse_dtype(v))
            .unwrap_or(DType::F32)
    }

    /// Deterministically lower to a well-formed workload. The result's
    /// `name` is the canonical scenario name; unset keys take family
    /// defaults; structural constraints (causal seq, conv kernel fit,
    /// MoE top-k, the [`MAX_DOMAIN_POINTS`] bound) are checked and the
    /// lowered workload is validated before it is returned.
    pub fn lower(&self) -> Result<Workload, String> {
        let mut w = match self.family {
            Family::Gemm => self.lower_gemm(),
            Family::Attention => self.lower_attention(),
            Family::Conv => self.lower_conv(),
            Family::Mlp => Ok(mlp::mlp(
                "mlp",
                mlp::MlpParams {
                    tokens: self.int_or("tokens", 1024),
                    d_model: self.int_or("d_model", 5120),
                    d_ff: self.int_or("d_ff", 8192),
                },
            )),
            Family::Moe => self.lower_moe(),
            Family::LlamaE2e => self.lower_llama(),
        }?;
        w.name = self.name();
        let dt = self.dtype();
        if dt != DType::F32 {
            for buf in &mut w.buffers {
                buf.dtype = dt;
            }
        }
        for blk in &w.blocks {
            let pts: f64 = blk.axes.iter().map(|a| a.extent as f64).product();
            if pts > MAX_DOMAIN_POINTS {
                return Err(format!(
                    "scenario {}: block {} iteration domain ({pts:.3e} points) exceeds {MAX_DOMAIN_POINTS:.0e}",
                    w.name, blk.name
                ));
            }
        }
        w.validate().map_err(|e| format!("scenario {}: {e}", w.name))?;
        Ok(w)
    }

    fn lower_gemm(&self) -> Result<Workload, String> {
        let (m, n, k) = (
            self.int_or("m", 1024),
            self.int_or("n", 1024),
            self.int_or("k", 1024),
        );
        match self.opt_int("batch") {
            None => Ok(gemm::gemm(m, n, k)),
            Some(batch) => {
                // batched GEMM with shared (unbatched) weights
                let mut b = WorkloadBuilder::new("gemm");
                let a = b.f32("A", &[batch, m, k]);
                let w = b.f32("B", &[k, n]);
                let c = b.f32("C", &[batch, m, n]);
                b.matmul("matmul", Some(batch), m, n, k, a, w, c, false, vec![]);
                Ok(b.build())
            }
        }
    }

    fn lower_attention(&self) -> Result<Workload, String> {
        let seq = self.int_or("seq", 2048);
        let causal = self.bool_or("causal", true);
        if causal && seq < 2 {
            return Err(format!(
                "scenario {}: causal attention needs seq >= 2 (kv extent = seq/2)",
                self.name()
            ));
        }
        Ok(attention::attention(
            "attention",
            attention::AttnParams {
                seq,
                heads: self.int_or("heads", 32),
                head_dim: self.int_or("head_dim", 128),
                causal,
            },
        ))
    }

    fn lower_conv(&self) -> Result<Workload, String> {
        let (h, w) = (self.int_or("h", 64), self.int_or("w", 64));
        let (kh, kw) = (self.int_or("kh", 3), self.int_or("kw", 3));
        if kh > h || kw > w {
            return Err(format!(
                "scenario {}: kernel {kh}x{kw} larger than input {h}x{w}",
                self.name()
            ));
        }
        Ok(conv::conv2d(
            "conv",
            conv::ConvParams {
                h,
                w,
                c_in: self.int_or("c_in", 320),
                c_out: self.int_or("c_out", 320),
                kh,
                kw,
            },
        ))
    }

    fn lower_moe(&self) -> Result<Workload, String> {
        let n_experts = self.int_or("experts", 8);
        let top_k = self.int_or("top_k", 2);
        if top_k > n_experts {
            return Err(format!(
                "scenario {}: top_k {top_k} > experts {n_experts}",
                self.name()
            ));
        }
        Ok(moe::moe(
            "moe",
            moe::MoeParams {
                tokens: self.int_or("tokens", 1024),
                d_model: self.int_or("d_model", 2048),
                d_ff: self.int_or("d_ff", 4096),
                n_experts,
                top_k,
            },
        ))
    }

    fn lower_llama(&self) -> Result<Workload, String> {
        let seq = self.int_or("seq", 2048);
        let heads = self.int_or("heads", 32);
        let head_dim = self.int_or("head_dim", 128);
        let causal = self.bool_or("causal", true);
        if causal && seq < 2 {
            return Err(format!(
                "scenario {}: causal attention needs seq >= 2 (kv extent = seq/2)",
                self.name()
            ));
        }
        let attn = attention::attention(
            "llama_layer",
            attention::AttnParams {
                seq,
                heads,
                head_dim,
                causal,
            },
        );
        let ffn = mlp::mlp(
            "llama_ffn",
            mlp::MlpParams {
                tokens: seq,
                d_model: heads * head_dim,
                d_ff: self.int_or("d_ff", 14336),
            },
        );
        let y = attn.buffer_idx("Y");
        fuse(attn, ffn, y, "llama_e2e")
    }
}

/// Chain `tail` onto `head` as one workload: `tail`'s buffer 0 (its
/// input activation, by builder convention) is identified with `head`'s
/// buffer `head_out`, producer-less `tail` blocks are rooted at `head`'s
/// final block, block/producer indices are offset, and colliding buffer
/// names get a `_t` suffix. Topological order is preserved (appended
/// blocks come after everything they consume), so the fused workload
/// validates whenever both inputs do.
fn fuse(mut head: Workload, tail: Workload, head_out: usize, name: &str) -> Result<Workload, String> {
    if head.buffers[head_out].shape != tail.buffers[0].shape {
        return Err(format!(
            "fuse {name}: output buffer shape {:?} != consumer input shape {:?}",
            head.buffers[head_out].shape, tail.buffers[0].shape
        ));
    }
    let buf_offset = head.buffers.len();
    let blk_offset = head.blocks.len();
    let head_last = blk_offset - 1;
    let map_buf = |i: usize| if i == 0 { head_out } else { buf_offset + i - 1 };
    let existing: std::collections::BTreeSet<String> =
        head.buffers.iter().map(|b| b.name.clone()).collect();
    for (bi, mut buf) in tail.buffers.into_iter().enumerate() {
        if bi == 0 {
            continue;
        }
        if existing.contains(&buf.name) {
            buf.name.push_str("_t");
        }
        head.buffers.push(buf);
    }
    for mut blk in tail.blocks.into_iter() {
        for acc in blk.reads.iter_mut().chain(blk.writes.iter_mut()) {
            acc.buffer = map_buf(acc.buffer);
        }
        blk.producers = if blk.producers.is_empty() {
            vec![head_last]
        } else {
            blk.producers.iter().map(|p| p + blk_offset).collect()
        };
        head.blocks.push(blk);
    }
    head.name = name.to_string();
    Ok(head)
}

/// A cross-product over per-key value lists for one family — the sweep
/// drivers' input. Dimension order is preserved from the grid text; the
/// expansion varies the **last** dimension fastest, so
/// `m=1,2;k=3,4` → `[{m=1,k=3},{m=1,k=4},{m=2,k=3},{m=2,k=4}]`.
#[derive(Clone, Debug)]
pub struct ScenarioGrid {
    pub family: Family,
    dims: Vec<(String, Vec<String>)>,
}

impl ScenarioGrid {
    /// Parse a grid over `family` from `key=v1,v2;key2=v3,...`. Empty
    /// grid text (or only separators) means "one all-defaults scenario".
    /// Keys, values, and duplicates are validated up front.
    pub fn parse(family: &str, grid: &str) -> Result<ScenarioGrid, String> {
        let family = Family::parse(family.trim())?;
        let mut dims: Vec<(String, Vec<String>)> = Vec::new();
        for dim in grid.split(';').filter(|d| !d.trim().is_empty()) {
            let (k, vs) = dim
                .split_once('=')
                .ok_or_else(|| format!("sweep grid: expected key=v1,v2,..., got {dim:?}"))?;
            let k = k.trim();
            if dims.iter().any(|(seen, _)| seen == k) {
                return Err(format!("sweep grid: key {k:?} listed twice"));
            }
            let mut vals = Vec::new();
            for v in vs.split(',').filter(|v| !v.trim().is_empty()) {
                // canonicalize (and validate) through a scratch spec
                let mut scratch = ScenarioSpec::new(family);
                scratch.set(k, v.trim())?;
                vals.push(scratch.params[k].clone());
            }
            if vals.is_empty() {
                return Err(format!("sweep grid: no values for key {k:?}"));
            }
            dims.push((k.to_string(), vals));
        }
        Ok(ScenarioGrid { family, dims })
    }

    /// Parse the one-argument form `family:key=v1,v2;key2=...` (or a
    /// bare `family` for the single all-defaults scenario).
    pub fn parse_arg(text: &str) -> Result<ScenarioGrid, String> {
        match text.split_once(':') {
            Some((f, g)) => ScenarioGrid::parse(f, g),
            None => ScenarioGrid::parse(text, ""),
        }
    }

    /// Number of scenarios the expansion will produce.
    pub fn len(&self) -> usize {
        self.dims.iter().map(|(_, vs)| vs.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cross product into specs (deterministic order, last
    /// dimension fastest). Every spec is lowered once here so invalid
    /// combinations (e.g. `kh > h`) fail before any search starts; the
    /// expansion is also bounded by [`MAX_SCENARIOS`].
    pub fn expand(&self) -> Result<Vec<ScenarioSpec>, String> {
        let mut total = 1usize;
        for (_, vs) in &self.dims {
            total = total
                .checked_mul(vs.len())
                .filter(|&t| t <= MAX_SCENARIOS)
                .ok_or_else(|| {
                    format!("sweep grid: expansion exceeds {MAX_SCENARIOS} scenarios")
                })?;
        }
        let mut out = Vec::with_capacity(total);
        for i in 0..total {
            let mut spec = ScenarioSpec::new(self.family);
            let mut rem = i;
            for (k, vs) in self.dims.iter().rev() {
                spec.set(k, &vs[rem % vs.len()])?;
                rem /= vs.len();
            }
            spec.lower()?;
            out.push(spec);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_name_fixed_point() {
        let spec = ScenarioSpec::parse("gemm@n=512, m=256,dtype=float32").unwrap();
        // keys sorted, values canonical (float32 -> f32)
        assert_eq!(spec.name(), "gemm@dtype=f32,m=256,n=512");
        let reparsed = ScenarioSpec::parse(&spec.name()).unwrap();
        assert_eq!(reparsed, spec);
        assert_eq!(reparsed.name(), spec.name());
    }

    #[test]
    fn bare_family_parses_to_defaults() {
        let spec = ScenarioSpec::parse("attention").unwrap();
        assert_eq!(spec.name(), "attention");
        let w = spec.lower().unwrap();
        // defaults match the hand-built llama3 attention shape
        assert_eq!(w.flops(), attention::llama3_attention().flops());
        assert_eq!(w.blocks.len(), 6);
    }

    #[test]
    fn gemm_defaults_match_registry_gemm() {
        let w = ScenarioSpec::parse("gemm").unwrap().lower().unwrap();
        assert_eq!(w.flops(), gemm::gemm(1024, 1024, 1024).flops());
    }

    #[test]
    fn lowered_name_is_canonical_scenario_name() {
        let spec = ScenarioSpec::parse("mlp@tokens=64,d_ff=128,d_model=32").unwrap();
        let w = spec.lower().unwrap();
        assert_eq!(w.name, "mlp@d_ff=128,d_model=32,tokens=64");
        assert_eq!(w.name, spec.name());
    }

    #[test]
    fn unknown_family_key_and_value_rejected() {
        assert!(ScenarioSpec::parse("resnet@h=3").is_err());
        assert!(ScenarioSpec::parse("gemm@q=3").is_err());
        assert!(ScenarioSpec::parse("gemm@m=abc").is_err());
        assert!(ScenarioSpec::parse("gemm@m=0").is_err());
        assert!(ScenarioSpec::parse("gemm@m=-5").is_err());
        assert!(ScenarioSpec::parse("gemm@").is_err());
        assert!(ScenarioSpec::parse("gemm@m").is_err());
        assert!(ScenarioSpec::parse("attention@dtype=f64").is_err());
        // out-of-range extent
        assert!(ScenarioSpec::parse(&format!("gemm@m={}", MAX_EXTENT + 1)).is_err());
    }

    #[test]
    fn structural_constraints_checked_at_lowering() {
        // causal attention with seq=1 would need a zero-extent kv axis
        assert!(ScenarioSpec::parse("attention@seq=1").unwrap().lower().is_err());
        assert!(ScenarioSpec::parse("attention@seq=1,causal=false")
            .unwrap()
            .lower()
            .is_ok());
        // conv kernel larger than the input
        assert!(ScenarioSpec::parse("conv@h=2,kh=3").unwrap().lower().is_err());
        // moe top_k > experts
        assert!(ScenarioSpec::parse("moe@experts=2,top_k=3")
            .unwrap()
            .lower()
            .is_err());
        // iteration-domain blowup (each extent individually legal)
        assert!(ScenarioSpec::parse("gemm@m=1048576,n=1048576,k=1048576")
            .unwrap()
            .lower()
            .is_err());
    }

    #[test]
    fn dtype_param_rewrites_every_buffer() {
        let w = ScenarioSpec::parse("mlp@tokens=8,d_model=16,d_ff=32,dtype=bf16")
            .unwrap()
            .lower()
            .unwrap();
        assert!(w.buffers.iter().all(|b| b.dtype == DType::BF16));
        let f32w = ScenarioSpec::parse("mlp@tokens=8,d_model=16,d_ff=32")
            .unwrap()
            .lower()
            .unwrap();
        let bytes = |w: &Workload| w.buffers.iter().map(|b| b.bytes()).sum::<i64>();
        assert_eq!(bytes(&w) * 2, bytes(&f32w));
    }

    #[test]
    fn batched_gemm_has_batch_axis_and_shared_weights() {
        let w = ScenarioSpec::parse("gemm@batch=4,m=32,n=16,k=8")
            .unwrap()
            .lower()
            .unwrap();
        assert_eq!(w.blocks.len(), 1);
        assert_eq!(w.blocks[0].axes.len(), 4); // b, i, j, k
        assert_eq!(w.blocks[0].reads[1].dim_axes.len(), 2); // weights unbatched
        assert_eq!(w.flops(), 2.0 * 4.0 * 32.0 * 16.0 * 8.0);
    }

    #[test]
    fn llama_e2e_fuses_attention_into_ffn() {
        let w = ScenarioSpec::parse("llama_e2e@seq=64,heads=2,head_dim=16,d_ff=128")
            .unwrap()
            .lower()
            .unwrap();
        w.validate().unwrap();
        let names: Vec<&str> = w.blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "qkv_proj", "scores", "softmax", "av", "out_proj", "residual", "gate_proj",
                "up_proj", "silu_mul", "down_proj"
            ]
        );
        // the FFN's first matmuls read the attention residual output and
        // are rooted at the residual block
        let y = w.buffer_idx("Y");
        let gate = w.blocks.iter().find(|b| b.name == "gate_proj").unwrap();
        assert_eq!(gate.reads[0].buffer, y);
        assert_eq!(gate.producers, vec![5]);
        // the FFN's own Y output was renamed away from the collision
        assert!(w.buffers.iter().any(|b| b.name == "Y_t"));
    }

    #[test]
    fn grid_expands_cross_product_in_order() {
        let grid = ScenarioGrid::parse("gemm", "m=16,32;k=8,64").unwrap();
        assert_eq!(grid.len(), 4);
        let specs = grid.expand().unwrap();
        let names: Vec<String> = specs.iter().map(ScenarioSpec::name).collect();
        assert_eq!(
            names,
            [
                "gemm@k=8,m=16",
                "gemm@k=64,m=16",
                "gemm@k=8,m=32",
                "gemm@k=64,m=32"
            ]
        );
    }

    #[test]
    fn grid_rejects_bad_input() {
        assert!(ScenarioGrid::parse("gemm", "m=16;m=32").is_err()); // dup key
        assert!(ScenarioGrid::parse("gemm", "m").is_err());
        assert!(ScenarioGrid::parse("gemm", "m=").is_err());
        assert!(ScenarioGrid::parse("gemm", "q=1").is_err());
        assert!(ScenarioGrid::parse("nope", "").is_err());
        // invalid combination caught at expand (lowering check)
        assert!(ScenarioGrid::parse("conv", "h=2;kh=3").unwrap().expand().is_err());
    }

    #[test]
    fn grid_empty_text_is_one_default_scenario() {
        let specs = ScenarioGrid::parse("moe", "  ").unwrap().expand().unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name(), "moe");
        let arg = ScenarioGrid::parse_arg("moe").unwrap().expand().unwrap();
        assert_eq!(arg, specs);
    }

    #[test]
    fn parse_arg_splits_family_and_grid() {
        let grid = ScenarioGrid::parse_arg("attention:seq=64,128;heads=2").unwrap();
        assert_eq!(grid.family, Family::Attention);
        assert_eq!(grid.len(), 4);
        for spec in grid.expand().unwrap() {
            assert!(spec.lower().is_ok());
        }
    }
}

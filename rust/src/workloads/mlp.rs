//! Llama-4-Scout MLP layer: SwiGLU (gate/up matmuls + elementwise silu·mul
//! + down matmul).

use super::builder::WorkloadBuilder;
use crate::tir::{BodyKind, Workload};

#[derive(Clone, Copy, Debug)]
pub struct MlpParams {
    pub tokens: i64,
    pub d_model: i64,
    pub d_ff: i64,
}

pub fn mlp(name: &str, p: MlpParams) -> Workload {
    let mut b = WorkloadBuilder::new(name);
    let x = b.f32("X", &[p.tokens, p.d_model]);
    let wg = b.f32("Wg", &[p.d_model, p.d_ff]);
    let wu = b.f32("Wu", &[p.d_model, p.d_ff]);
    let wd = b.f32("Wd", &[p.d_ff, p.d_model]);
    let g = b.f32("G", &[p.tokens, p.d_ff]);
    let u = b.f32("U", &[p.tokens, p.d_ff]);
    let h = b.f32("H", &[p.tokens, p.d_ff]);
    let y = b.f32("Y", &[p.tokens, p.d_model]);

    let gate = b.matmul("gate_proj", None, p.tokens, p.d_ff, p.d_model, x, wg, g, false, vec![]);
    let up = b.matmul("up_proj", None, p.tokens, p.d_ff, p.d_model, x, wu, u, false, vec![]);
    let act = b.elementwise(
        "silu_mul",
        &[p.tokens, p.d_ff],
        &[g, u],
        h,
        BodyKind::Transcendental,
        6.0, // silu = x * sigmoid(x): exp + div + 2 mul
        vec![gate, up],
    );
    b.matmul("down_proj", None, p.tokens, p.d_model, p.d_ff, h, wd, y, false, vec![act]);
    b.build()
}

/// Llama-4-Scout MLP at the paper scale: 1024 tokens, d_model 5120,
/// d_ff 8192 (the dense shared-expert FFN width).
pub fn llama4_mlp() -> Workload {
    mlp(
        "llama4_mlp",
        MlpParams {
            tokens: 1024,
            d_model: 5120,
            d_ff: 8192,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_structure() {
        let w = llama4_mlp();
        w.validate().unwrap();
        let names: Vec<&str> = w.blocks.iter().map(|b| b.name.as_str()).collect();
        assert_eq!(names, ["gate_proj", "up_proj", "silu_mul", "down_proj"]);
    }

    #[test]
    fn matmul_flops_dominate() {
        let w = llama4_mlp();
        let mm_flops: f64 = w
            .blocks
            .iter()
            .filter(|b| b.name.ends_with("proj"))
            .map(|b| b.flops())
            .sum();
        assert!(mm_flops / w.flops() > 0.99);
    }

    #[test]
    fn silu_consumes_both_projections() {
        let w = llama4_mlp();
        let silu = w.blocks.iter().find(|b| b.name == "silu_mul").unwrap();
        assert_eq!(silu.producers, vec![0, 1]);
    }
}

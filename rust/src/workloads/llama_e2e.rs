//! End-to-end Llama-3-8B compilation target (paper Table 3 / Table 16).
//!
//! The full model is a layer graph whose unique kernels are tuned once and
//! whose end-to-end latency is the count-weighted sum of per-kernel
//! latencies — exactly how TVM MetaSchedule treats full-model tuning
//! (tasks extracted per unique subgraph, weighted by occurrence).

use super::{attention, mlp};
use crate::tir::Workload;

/// One tuning task of the e2e graph.
#[derive(Clone, Debug)]
pub struct E2eTask {
    pub workload: Workload,
    /// How many times this kernel appears in the full model.
    pub count: i64,
    /// Fraction of the total search budget this task receives
    /// (proportional to count-weighted FLOPs).
    pub budget_frac: f64,
}

/// The full-model graph.
#[derive(Clone, Debug)]
pub struct E2eGraph {
    pub name: String,
    pub tasks: Vec<E2eTask>,
}

impl E2eGraph {
    /// Count-weighted total FLOPs.
    pub fn flops(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| t.workload.flops() * t.count as f64)
            .sum()
    }

    /// End-to-end latency given per-task latencies (seconds each run).
    pub fn latency(&self, per_task: &[f64]) -> f64 {
        assert_eq!(per_task.len(), self.tasks.len());
        self.tasks
            .iter()
            .zip(per_task)
            .map(|(t, &l)| l * t.count as f64)
            .sum()
    }
}

/// Llama-3-8B: 32 decoder layers, each = attention + MLP; plus the LM head
/// GEMM. Unique tasks: one attention kernel, one MLP kernel, one head GEMM.
pub fn llama3_8b_graph() -> E2eGraph {
    let attn = attention::attention(
        "llama3_layer_attn",
        attention::AttnParams {
            seq: 2048,
            heads: 32,
            head_dim: 128,
            causal: true,
        },
    );
    let ffn = mlp::mlp(
        "llama3_layer_mlp",
        mlp::MlpParams {
            tokens: 2048,
            d_model: 4096,
            d_ff: 14336,
        },
    );
    let head = super::gemm::gemm(2048, 128_256, 4096);

    let mut tasks = vec![
        E2eTask {
            workload: attn,
            count: 32,
            budget_frac: 0.0,
        },
        E2eTask {
            workload: ffn,
            count: 32,
            budget_frac: 0.0,
        },
        E2eTask {
            workload: head,
            count: 1,
            budget_frac: 0.0,
        },
    ];
    let total: f64 = tasks
        .iter()
        .map(|t| t.workload.flops() * t.count as f64)
        .sum();
    for t in &mut tasks {
        t.budget_frac = t.workload.flops() * t.count as f64 / total;
    }
    E2eGraph {
        name: "llama3_8b".into(),
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_has_three_unique_tasks() {
        let g = llama3_8b_graph();
        assert_eq!(g.tasks.len(), 3);
        assert_eq!(g.tasks[0].count, 32);
        let frac_sum: f64 = g.tasks.iter().map(|t| t.budget_frac).sum();
        assert!((frac_sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn latency_weighting() {
        let g = llama3_8b_graph();
        let lat = g.latency(&[1.0, 2.0, 10.0]);
        assert_eq!(lat, 32.0 + 64.0 + 10.0);
    }

    #[test]
    fn mlp_budget_dominates_head() {
        let g = llama3_8b_graph();
        assert!(g.tasks[1].budget_frac > g.tasks[2].budget_frac);
    }
}

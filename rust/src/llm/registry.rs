//! The heterogeneous LLM catalog (paper §3.1): parameter counts, serving
//! prices, decoding speeds, and simulated-capability indices.
//!
//! Prices/speeds are representative of OpenAI / Nscale serving at the
//! paper's time (absolute values matter only through the *ratios* they
//! induce — the paper's own cost-reduction factors are ratios too).
//! Capability is the simulation stand-in for "how good this model's
//! schedule-optimization proposals are"; it scales log-linearly in
//! parameter count with per-model idiosyncrasy, matching the paper's
//! observation that no small model can drive the search alone.

/// One servable model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Parameter count in billions (the paper's prompt exposes this).
    pub params_b: f64,
    /// USD per 1M input tokens.
    pub usd_per_mtok_in: f64,
    /// USD per 1M output tokens.
    pub usd_per_mtok_out: f64,
    /// Decode speed, output tokens/second.
    pub tokens_per_sec: f64,
    /// Fixed API round-trip latency (seconds).
    pub base_latency_s: f64,
    /// Proposal quality in [0,1]: drives hit rate in the simulation.
    pub capability: f64,
    /// Probability of an invalid transformation / model name per call.
    pub error_rate: f64,
}

impl ModelSpec {
    /// Simulated wall-clock latency of one call.
    pub fn call_latency(&self, tokens_in: f64, tokens_out: f64) -> f64 {
        // prefill is ~10x decode throughput
        self.base_latency_s + tokens_in / (self.tokens_per_sec * 10.0) + tokens_out / self.tokens_per_sec
    }

    /// Simulated USD cost of one call.
    pub fn call_cost(&self, tokens_in: f64, tokens_out: f64) -> f64 {
        tokens_in * self.usd_per_mtok_in / 1e6 + tokens_out * self.usd_per_mtok_out / 1e6
    }
}

/// The full catalog, largest models first within each family.
pub fn catalog() -> Vec<ModelSpec> {
    vec![
        ModelSpec {
            name: "gpt-5.2",
            params_b: 300.0,
            usd_per_mtok_in: 1.25,
            usd_per_mtok_out: 10.0,
            tokens_per_sec: 42.0,
            base_latency_s: 1.5,
            capability: 0.95,
            error_rate: 0.002,
        },
        ModelSpec {
            name: "Llama-3.3-70B-Instruct",
            params_b: 70.0,
            usd_per_mtok_in: 0.60,
            usd_per_mtok_out: 0.70,
            tokens_per_sec: 70.0,
            base_latency_s: 0.8,
            capability: 0.84,
            error_rate: 0.01,
        },
        ModelSpec {
            name: "DeepSeek-R1-Distill-Qwen-32B",
            params_b: 32.0,
            usd_per_mtok_in: 0.30,
            usd_per_mtok_out: 0.30,
            tokens_per_sec: 80.0,
            base_latency_s: 0.6,
            capability: 0.78,
            error_rate: 0.015,
        },
        ModelSpec {
            name: "Devstral-Small-2505",
            params_b: 24.0,
            usd_per_mtok_in: 0.10,
            usd_per_mtok_out: 0.30,
            tokens_per_sec: 95.0,
            base_latency_s: 0.5,
            capability: 0.70,
            error_rate: 0.02,
        },
        ModelSpec {
            name: "gpt-5-mini",
            params_b: 20.0,
            usd_per_mtok_in: 0.25,
            usd_per_mtok_out: 2.0,
            tokens_per_sec: 110.0,
            base_latency_s: 0.5,
            capability: 0.74,
            error_rate: 0.01,
        },
        ModelSpec {
            name: "Qwen3-14B",
            params_b: 14.0,
            usd_per_mtok_in: 0.12,
            usd_per_mtok_out: 0.12,
            tokens_per_sec: 120.0,
            base_latency_s: 0.4,
            capability: 0.71,
            error_rate: 0.02,
        },
        ModelSpec {
            name: "Qwen3-8B",
            params_b: 8.0,
            usd_per_mtok_in: 0.08,
            usd_per_mtok_out: 0.08,
            tokens_per_sec: 140.0,
            base_latency_s: 0.35,
            capability: 0.68,
            error_rate: 0.025,
        },
        ModelSpec {
            name: "Llama-3.1-8B-Instruct",
            params_b: 8.0,
            usd_per_mtok_in: 0.05,
            usd_per_mtok_out: 0.08,
            tokens_per_sec: 150.0,
            base_latency_s: 0.35,
            capability: 0.64,
            error_rate: 0.03,
        },
        ModelSpec {
            name: "DeepSeek-R1-Distill-Qwen-7B",
            params_b: 7.0,
            usd_per_mtok_in: 0.10,
            usd_per_mtok_out: 0.10,
            tokens_per_sec: 150.0,
            base_latency_s: 0.35,
            capability: 0.66,
            error_rate: 0.03,
        },
    ]
}

/// Look up a spec by exact name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    catalog().into_iter().find(|m| m.name == name)
}

/// The paper's three collaborative configurations (§3.1), parameterized by
/// the largest model ("gpt-5.2" or "Llama-3.3-70B-Instruct").
pub fn paper_config(n_llms: usize, largest: &str) -> Vec<ModelSpec> {
    let mut names: Vec<&str> = match n_llms {
        2 => vec![largest, "gpt-5-mini"],
        4 => vec![
            largest,
            "gpt-5-mini",
            "DeepSeek-R1-Distill-Qwen-32B",
            "Llama-3.1-8B-Instruct",
        ],
        8 => vec![
            largest,
            "gpt-5-mini",
            "DeepSeek-R1-Distill-Qwen-32B",
            "Llama-3.1-8B-Instruct",
            "DeepSeek-R1-Distill-Qwen-7B",
            "Qwen3-8B",
            "Qwen3-14B",
            "Devstral-Small-2505",
        ],
        1 => vec![largest],
        n => panic!("unsupported config size {n}"),
    };
    names.dedup();
    names
        .into_iter()
        .map(|n| by_name(n).unwrap_or_else(|| panic!("unknown model {n}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_complete() {
        assert_eq!(catalog().len(), 9);
        assert!(by_name("gpt-5.2").is_some());
        assert!(by_name("gpt-6").is_none());
    }

    #[test]
    fn capability_monotone_ish_in_size() {
        let c = catalog();
        let biggest = c.iter().max_by(|a, b| a.params_b.total_cmp(&b.params_b)).unwrap();
        assert_eq!(biggest.name, "gpt-5.2");
        assert!(biggest.capability >= c.iter().map(|m| m.capability).fold(0.0, f64::max) - 1e-9);
    }

    #[test]
    fn big_models_cost_more() {
        let big = by_name("gpt-5.2").unwrap();
        let small = by_name("Qwen3-8B").unwrap();
        assert!(big.call_cost(2000.0, 150.0) > small.call_cost(2000.0, 150.0) * 5.0);
        assert!(big.call_latency(2000.0, 150.0) > small.call_latency(2000.0, 150.0));
    }

    #[test]
    fn paper_configs() {
        assert_eq!(paper_config(2, "gpt-5.2").len(), 2);
        assert_eq!(paper_config(4, "gpt-5.2").len(), 4);
        assert_eq!(paper_config(8, "gpt-5.2").len(), 8);
        let l = paper_config(8, "Llama-3.3-70B-Instruct");
        assert_eq!(l[0].name, "Llama-3.3-70B-Instruct");
        assert_eq!(l.len(), 8);
    }
}

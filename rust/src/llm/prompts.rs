//! Prompt rendering — the paper's Appendix-B templates, reproduced
//! faithfully and rendered with live search context.
//!
//! The simulated models do not parse this text (their behavior is driven
//! by the structured [`PromptCtx`]), but the rendered prompt is what the
//! token/cost accounting measures, exactly as a real deployment would pay
//! for it — including the paper's point that the course-alteration prompt
//! is *shorter* than a regular large-model prompt.

use crate::schedule::transforms::TransformKind;
use std::sync::Arc;

/// Program variant summary shown in the prompt (leaf / parent /
/// grandparent). The renderings are shared `Arc<str>`s: the search engine
/// renders each node's code/trace once at insertion and every prompt
/// context built from it afterwards is a refcount bump, not a string
/// copy.
#[derive(Clone, Debug)]
pub struct VariantCtx {
    pub code: Arc<str>,
    pub trace_tail: Arc<str>,
    pub score: f64,
}

/// Global per-model statistics block.
#[derive(Clone, Debug)]
pub struct ModelStatLine {
    pub name: String,
    pub params_b: f64,
    pub regular_calls: usize,
    pub regular_hit_rate: f64,
    pub ca_calls: usize,
    pub ca_hit_rate: f64,
    pub errors: usize,
}

/// Everything the active model sees at an expansion.
#[derive(Clone, Debug)]
pub struct PromptCtx {
    pub current: VariantCtx,
    pub parent: Option<VariantCtx>,
    pub grandparent: Option<VariantCtx>,
    pub vocabulary: Vec<TransformKind>,
    pub leaf_depth: usize,
    pub trials_done: usize,
    pub trials_budget: usize,
    pub model_stats: Vec<ModelStatLine>,
    /// Names of the models that expanded current / parent / grandparent.
    pub local_models: [Option<String>; 3],
}

fn variant_section(title: &str, v: &VariantCtx) -> String {
    format!(
        "{title}:\nCode:\n{}\nTransformation history:\n{}\nPredicted score: {:.4}\n",
        v.code, v.trace_tail, v.score
    )
}

fn stats_section(ctx: &PromptCtx) -> String {
    let mut s = String::from("Global Per-Model Stats\n");
    for m in &ctx.model_stats {
        s.push_str(&format!(
            "Model {}: params={:.1}B, regular_calls={}, regular_hit_rate={:.3}, \
             course_alteration_calls={}, course_alteration_hit_rate={:.3}, errors={}\n",
            m.name, m.params_b, m.regular_calls, m.regular_hit_rate, m.ca_calls, m.ca_hit_rate,
            m.errors
        ));
    }
    s
}

fn local_section(ctx: &PromptCtx) -> String {
    let n = |o: &Option<String>| o.clone().unwrap_or_else(|| "N/A".into());
    format!(
        "Local Model Context\nModel used to expand the current node: {}\n\
         Model used to expand the parent node: {}\n\
         Model used to expand the grandparent node: {}\n",
        n(&ctx.local_models[0]),
        n(&ctx.local_models[1]),
        n(&ctx.local_models[2])
    )
}

fn vocab_section(ctx: &PromptCtx) -> String {
    let names: Vec<String> = ctx
        .vocabulary
        .iter()
        .map(|t| format!("\"{}\"", t.name()))
        .collect();
    format!("Available Transformations\n[{}]\n", names.join(", "))
}

/// The regular model-invocation prompt (Appendix B, first template).
pub fn regular_prompt(ctx: &PromptCtx) -> String {
    let mut p = String::new();
    p.push_str(
        "You are an AI scheduling assistant to help with a Monte Carlo Tree Search (MCTS) \
         to find an optimal program in the search space starting from an unoptimized program.\n\
         In this MCTS, the current program is the leaf we are expanding, while immediate parent \
         and grandparent refer to the ancestors in the tree.\n\
         Each program has: a piece of code, a transformation history sequence, a predicted \
         performance score.\n\n\
         Task:\n\
         1. Compare code/transformation history/predicted performance scores to infer what \
         changes might improve performance.\n\
         2. Propose a sequence of transformations from the provided list. You may repeat a \
         transformation to explore different decisions.\n\
         3. Choose exactly one model from the provided model list as the next model to expand \
         the child. Use the smallest model that could give best results. Prefer models with \
         fewer errors.\n\n\
         Output a single valid JSON object in the EXACT format:\n\
         {\"transformations\": [\"Fullname1\", \"Fullname2\", \"...\"], \"next_model\": \"...\"}\n\n\
         Historical Performance Info (Leaf, Parent, Grandparent)\n",
    );
    p.push_str(&variant_section("Current Program", &ctx.current));
    if let Some(par) = &ctx.parent {
        p.push_str(&variant_section("Immediate Parent Schedule", par));
    }
    if let Some(gp) = &ctx.grandparent {
        p.push_str(&variant_section("Grandparent Schedule", gp));
    }
    p.push_str(&vocab_section(ctx));
    p.push_str(&format!(
        "Search Context\nLeaf depth: {}\nTrials progress: {} / {}\n",
        ctx.leaf_depth, ctx.trials_done, ctx.trials_budget
    ));
    p.push_str(&stats_section(ctx));
    p.push_str(&local_section(ctx));
    p
}

/// The course-alteration prompt (Appendix B, second template): shorter,
/// targeted — reuses local program context plus the failed proposal.
pub fn course_alteration_prompt(
    ctx: &PromptCtx,
    failed_model: &str,
    failed_transforms: &[TransformKind],
    failed_next_model: &str,
    failed_child_score: f64,
) -> String {
    let mut p = String::new();
    p.push_str(
        "You are the largest model invoked for course alteration in a Monte Carlo Tree \
         Search (MCTS) for compiler optimization. A smaller model has proposed a sequence of \
         transformations and a next model for expanding the child node. This proposal \
         triggered course alteration because the predicted score of the resulting child is \
         lower than the predicted score of the current program.\n\n\
         Task: Modify the smaller model's proposal by changing the transformation sequence, \
         the next model, or both.\n\
         Output a single valid JSON object in the EXACT format:\n\
         {\"transformations\": [\"Fullname1\", \"Fullname2\", \"...\"], \"next_model\": \"...\"}\n\n",
    );
    p.push_str(&variant_section("Current Program", &ctx.current));
    if let Some(par) = &ctx.parent {
        p.push_str(&variant_section("Immediate Parent Program", par));
    }
    let names: Vec<String> = failed_transforms
        .iter()
        .map(|t| format!("\"{}\"", t.name()))
        .collect();
    p.push_str(&format!(
        "Smaller Model Proposal Triggering Course Alteration\n\
         Smaller model name: {failed_model}\n\
         Proposed transformations: [{}]\n\
         Proposed next model: {failed_next_model}\n\
         Predicted current score: {:.3}\n\
         Predicted child score from smaller model proposal: {:.3}\n",
        names.join(", "),
        ctx.current.score,
        failed_child_score
    ));
    p.push_str(&vocab_section(ctx));
    p.push_str(&format!(
        "Search Context\nLeaf depth: {}\nTrials progress: {} / {}\n",
        ctx.leaf_depth, ctx.trials_done, ctx.trials_budget
    ));
    p.push_str(&stats_section(ctx));
    p.push_str(&local_section(ctx));
    p
}

/// Token estimate for accounting: the classic chars/4 heuristic.
pub fn count_tokens(text: &str) -> f64 {
    text.len() as f64 / 4.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> PromptCtx {
        PromptCtx {
            current: VariantCtx {
                code: "@T.prim_func\ndef main(A, B, C): ...".into(),
                trace_tail: "sch.sample_perfect_tile(loop=j, decision=[1, 64, 1, 64])".into(),
                score: 0.0739,
            },
            parent: Some(VariantCtx {
                code: "@T.prim_func\ndef main(A, B, C): ...".into(),
                trace_tail: "sch.vectorize(...)".into(),
                score: 0.136,
            }),
            grandparent: None,
            vocabulary: vec![
                TransformKind::TileSize,
                TransformKind::Parallel,
                TransformKind::Unroll,
                TransformKind::ComputeLocation,
            ],
            leaf_depth: 3,
            trials_done: 10,
            trials_budget: 300,
            model_stats: vec![ModelStatLine {
                name: "gpt-5-mini".into(),
                params_b: 20.0,
                regular_calls: 12,
                regular_hit_rate: 0.364,
                ca_calls: 0,
                ca_hit_rate: 0.0,
                errors: 0,
            }],
            local_models: [Some("gpt-5.2".into()), Some("gpt-5.2".into()), None],
        }
    }

    #[test]
    fn regular_prompt_has_paper_sections() {
        let p = regular_prompt(&ctx());
        for needle in [
            "AI scheduling assistant",
            "Predicted score: 0.0739",
            "Available Transformations",
            "Trials progress: 10 / 300",
            "regular_hit_rate=0.364",
            "Model used to expand the current node: gpt-5.2",
            "\"next_model\"",
        ] {
            assert!(p.contains(needle), "missing: {needle}");
        }
    }

    #[test]
    fn ca_prompt_is_shorter_than_regular() {
        let c = ctx();
        let reg = regular_prompt(&c);
        let ca = course_alteration_prompt(
            &c,
            "gpt-5-mini",
            &[TransformKind::TileSize, TransformKind::Unroll],
            "gpt-5.2",
            0.028,
        );
        assert!(ca.len() < reg.len(), "ca {} >= regular {}", ca.len(), reg.len());
        assert!(ca.contains("course alteration"));
        assert!(ca.contains("Predicted child score from smaller model proposal: 0.028"));
    }

    #[test]
    fn token_counting() {
        assert_eq!(count_tokens("abcdefgh"), 2.0);
    }
}

//! Simulated heterogeneous LLM serving substrate.
//!
//! Stands in for the OpenAI / Nscale APIs of the paper (DESIGN.md
//! §Substitutions). Each call renders the real prompt (token-accounted),
//! pays the model's latency and USD price, and produces a joint proposal
//! ⟨transformation sequence, next model⟩ whose *quality* scales with the
//! model's capability: more capable models explore more candidate
//! proposals internally and judge them with less noise. Models also carry
//! idiosyncratic transform affinities (seeded from the model name), so a
//! heterogeneous set covers the transformation space better than any
//! single model — the diversity mechanism the paper's scaling results
//! attribute the 8-LLM gains to.

pub mod faults;
pub mod registry;
pub mod prompts;

use crate::schedule::transforms::TransformKind;
use crate::util::Rng;
use faults::{FaultKind, FaultPlan, FaultReport};
use prompts::{count_tokens, PromptCtx};
use registry::ModelSpec;

/// Running statistics per model (the prompt's "Global Per-Model Stats").
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    pub regular_calls: usize,
    pub regular_hits: usize,
    pub ca_calls: usize,
    pub ca_hits: usize,
    pub errors: usize,
    pub total_cost_usd: f64,
    pub total_latency_s: f64,
    pub tokens_in: f64,
    pub tokens_out: f64,
}

impl ModelStats {
    pub fn regular_hit_rate(&self) -> f64 {
        if self.regular_calls == 0 {
            0.0
        } else {
            self.regular_hits as f64 / self.regular_calls as f64
        }
    }
    pub fn ca_hit_rate(&self) -> f64 {
        if self.ca_calls == 0 {
            0.0
        } else {
            self.ca_hits as f64 / self.ca_calls as f64
        }
    }
    pub fn calls(&self) -> usize {
        self.regular_calls + self.ca_calls
    }
}

/// Call type, for invocation-rate accounting (paper Table 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CallKind {
    Regular,
    CourseAlteration,
}

/// A joint proposal returned by a model.
#[derive(Clone, Debug)]
pub struct Proposal {
    pub transforms: Vec<TransformKind>,
    /// Index into the model set.
    pub next_model: usize,
    /// Errors the model made while producing this (invalid names that the
    /// engine had to repair) — each costs +1 in the stats.
    pub n_errors: usize,
}

/// Accounting record of one simulated API call.
#[derive(Clone, Debug)]
pub struct CallRecord {
    pub model: usize,
    pub kind: CallKind,
    pub tokens_in: f64,
    pub tokens_out: f64,
    pub cost_usd: f64,
    pub latency_s: f64,
}

/// The collaborating model set plus all accounting state.
#[derive(Clone, Debug)]
pub struct ModelSet {
    pub specs: Vec<ModelSpec>,
    pub stats: Vec<ModelStats>,
    /// Index of the largest model (course-alteration target).
    pub largest: usize,
    /// Injected fault schedule (see [`faults`]); the default zero plan
    /// never draws and leaves every call path bit-identical.
    pub faults: FaultPlan,
    /// Tally of everything the resilient call path absorbed.
    pub fault_report: FaultReport,
    /// Per-model, per-transform affinity weights (idiosyncrasy).
    affinity: Vec<Vec<f64>>,
}

fn name_hash(name: &str, salt: u64) -> u64 {
    let mut h = 1469598103934665603u64 ^ salt;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(1099511628211);
    }
    h
}

impl ModelSet {
    pub fn new(specs: Vec<ModelSpec>) -> ModelSet {
        assert!(!specs.is_empty());
        let largest = specs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.params_b.total_cmp(&b.1.params_b))
            .map(|(i, _)| i)
            .unwrap();
        let affinity = specs
            .iter()
            .map(|m| {
                let mut rng = Rng::new(name_hash(m.name, 0xAFF1));
                TransformKind::ALL.iter().map(|_| 0.5 + rng.f64()).collect()
            })
            .collect();
        let stats = vec![ModelStats::default(); specs.len()];
        ModelSet {
            specs,
            stats,
            largest,
            faults: FaultPlan::none(),
            fault_report: FaultReport::default(),
            affinity,
        }
    }

    /// Install a fault schedule (see [`faults::FaultPlan`]). A zero-rate
    /// plan is a bit-identical passthrough.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.faults = plan;
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// φ_small(llm): the paper's normalized small-model preference (§2.3).
    pub fn phi_small(&self, model: usize) -> f64 {
        let logs: Vec<f64> = self.specs.iter().map(|m| m.params_b.ln()).collect();
        let max = logs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = logs.iter().cloned().fold(f64::INFINITY, f64::min);
        (max - logs[model]) / (max - min + 1e-9)
    }

    pub fn idx_by_name(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|m| m.name == name)
    }

    /// The prompt stats block for the current state.
    pub fn stat_lines(&self) -> Vec<prompts::ModelStatLine> {
        self.specs
            .iter()
            .zip(&self.stats)
            .map(|(m, s)| prompts::ModelStatLine {
                name: m.name.to_string(),
                params_b: m.params_b,
                regular_calls: s.regular_calls,
                regular_hit_rate: s.regular_hit_rate(),
                ca_calls: s.ca_calls,
                ca_hit_rate: s.ca_hit_rate(),
                errors: s.errors,
            })
            .collect()
    }

    /// Record a call's accounting (cost, latency, token counts).
    fn account(
        &mut self,
        model: usize,
        kind: CallKind,
        prompt_text: &str,
        out_tokens: f64,
    ) -> CallRecord {
        let tin = count_tokens(prompt_text);
        let spec = &self.specs[model];
        let rec = CallRecord {
            model,
            kind,
            tokens_in: tin,
            tokens_out: out_tokens,
            cost_usd: spec.call_cost(tin, out_tokens),
            latency_s: spec.call_latency(tin, out_tokens),
        };
        let st = &mut self.stats[model];
        st.total_cost_usd += rec.cost_usd;
        st.total_latency_s += rec.latency_s;
        st.tokens_in += tin;
        st.tokens_out += out_tokens;
        match kind {
            CallKind::Regular => st.regular_calls += 1,
            CallKind::CourseAlteration => st.ca_calls += 1,
        }
        rec
    }

    /// Credit a hit (child improved over parent) to the producing call.
    pub fn credit_hit(&mut self, model: usize, kind: CallKind) {
        match kind {
            CallKind::Regular => self.stats[model].regular_hits += 1,
            CallKind::CourseAlteration => self.stats[model].ca_hits += 1,
        }
    }

    /// The fallback-escalation target: the roster model with the smallest
    /// parameter count strictly greater than `model`'s (first roster
    /// index on ties, so escalation is deterministic).
    pub fn next_larger(&self, model: usize) -> Option<usize> {
        let here = self.specs[model].params_b;
        self.specs
            .iter()
            .enumerate()
            .filter(|(_, m)| m.params_b > here)
            .min_by(|a, b| a.1.params_b.total_cmp(&b.1.params_b))
            .map(|(i, _)| i)
    }

    /// The resilient call path (see [`faults`] module docs): decide which
    /// model actually serves this call, charging every faulted attempt,
    /// backoff, and escalation on the way. Runs **before** the call's
    /// candidate deliberation and draws only from the plan's dedicated
    /// stream — a zero plan returns `model` untouched without a single
    /// draw, keeping fault-free runs bit-identical.
    fn resolve_call(
        &mut self,
        mut model: usize,
        ctx: &PromptCtx,
        kind: CallKind,
        banned: &[TransformKind],
    ) -> usize {
        if self.faults.is_zero() {
            return model;
        }
        loop {
            for attempt in 0..=self.faults.max_retries {
                let Some(fault) = self.faults.draw(model) else {
                    return model; // this attempt succeeds
                };
                self.charge_fault(model, fault, ctx, kind, banned);
                if attempt < self.faults.max_retries {
                    let backoff = self.faults.backoff_base_s * (1u64 << attempt) as f64;
                    self.stats[model].total_latency_s += backoff;
                    self.fault_report.retries += 1;
                    self.fault_report.backoff_latency_s += backoff;
                }
            }
            // retries exhausted on this model: escalate toward the top of
            // the roster (the same direction course-alteration takes)
            match self.next_larger(model) {
                Some(next) => {
                    self.fault_report.fallbacks += 1;
                    model = next;
                }
                None => {
                    // top of the roster: proceed with the call anyway —
                    // a search can degrade but never stall
                    self.fault_report.forced += 1;
                    return model;
                }
            }
        }
    }

    /// Charge one faulted attempt per [`FaultKind`] semantics: every
    /// fault counts as a model error; timeouts/rate-limits/transients
    /// cost wall-clock only, malformed proposals pay full call freight
    /// (latency, tokens, and USD) for output the engine had to discard.
    fn charge_fault(
        &mut self,
        model: usize,
        fault: FaultKind,
        ctx: &PromptCtx,
        kind: CallKind,
        banned: &[TransformKind],
    ) {
        self.stats[model].errors += 1;
        self.fault_report.record(fault);
        let spec = self.specs[model].clone();
        let (lat, cost) = match fault {
            FaultKind::Timeout => (self.faults.timeout_s, 0.0),
            FaultKind::RateLimit => (faults::RATE_LIMIT_LATENCY_S, 0.0),
            FaultKind::Transient => (spec.base_latency_s, 0.0),
            FaultKind::Malformed => {
                let prompt_text = match kind {
                    CallKind::Regular => prompts::regular_prompt(ctx),
                    CallKind::CourseAlteration => prompts::course_alteration_prompt(
                        ctx,
                        "small-model",
                        banned,
                        spec.name,
                        0.0,
                    ),
                };
                let tin = count_tokens(&prompt_text);
                let out = 30.0 + 60.0 * spec.capability;
                let st = &mut self.stats[model];
                st.tokens_in += tin;
                st.tokens_out += out;
                (spec.call_latency(tin, out), spec.call_cost(tin, out))
            }
        };
        let st = &mut self.stats[model];
        st.total_latency_s += lat;
        st.total_cost_usd += cost;
        self.fault_report.fault_latency_s += lat;
        self.fault_report.fault_cost_usd += cost;
    }

    /// The vocabulary a call actually samples from: `banned` removed,
    /// falling back to the full vocabulary when the ban covers everything.
    fn effective_vocab(
        vocabulary: &[TransformKind],
        banned: &[TransformKind],
    ) -> Vec<TransformKind> {
        let vocab: Vec<TransformKind> = vocabulary
            .iter()
            .copied()
            .filter(|t| !banned.contains(t))
            .collect();
        if vocab.is_empty() {
            vocabulary.to_vec()
        } else {
            vocab
        }
    }

    /// Capability-scaled internal lookahead width: how many candidate
    /// sequences the model considers per call (CA calls think harder).
    fn lookahead_width(&self, model: usize, kind: CallKind) -> usize {
        let cap = self.specs[model].capability;
        let extra = if kind == CallKind::CourseAlteration { 3 } else { 0 };
        1 + (cap * cap * 7.0).round() as usize + extra
    }

    /// Capability-scaled judgment noise on candidate scores.
    fn noise_sigma(&self, model: usize) -> f64 {
        0.02 + 0.30 * (1.0 - self.specs[model].capability)
    }

    /// Per-call affinity weights over an effective vocabulary, computed
    /// once per proposal (they are invariant across a call's candidate
    /// draws) and shared by every [`ModelSet::draw_seq`] of that call.
    fn seq_weights(&self, model: usize, vocab: &[TransformKind]) -> Vec<f64> {
        let aff = &self.affinity[model];
        vocab
            .iter()
            .map(|t| aff[TransformKind::ALL.iter().position(|a| a == t).unwrap()])
            .collect()
    }

    /// Draw one affinity-weighted candidate sequence (1–4 transforms).
    /// RNG draw order: length first, then one weighted pick per element.
    fn draw_seq(weights: &[f64], vocab: &[TransformKind], rng: &mut Rng) -> Vec<TransformKind> {
        let len = 1 + rng.below(4);
        (0..len).map(|_| vocab[rng.weighted(weights)]).collect()
    }

    /// Everything after a call's candidate deliberation, shared by
    /// [`ModelSet::propose`] and [`ModelSet::propose_scored`]: invalid-name
    /// error emission + repair, size-aware next-model routing, prompt
    /// rendering, and cost/latency accounting — in exactly that RNG draw
    /// order.
    #[allow(clippy::too_many_arguments)]
    fn finalize_proposal(
        &mut self,
        model: usize,
        ctx: &PromptCtx,
        kind: CallKind,
        banned: &[TransformKind],
        vocab: &[TransformKind],
        mut best_seq: Vec<TransformKind>,
        rng: &mut Rng,
    ) -> (Proposal, CallRecord) {
        let spec = self.specs[model].clone();
        let cap = spec.capability;
        let mut n_errors = 0usize;

        // invalid transformation name emission
        if rng.chance(spec.error_rate) {
            n_errors += 1;
            self.stats[model].errors += 1;
            // engine repairs by resampling one valid transform
            if !best_seq.is_empty() {
                let i = rng.below(best_seq.len());
                best_seq[i] = *rng.choice(vocab);
            }
        }

        // --- next model: size-aware instruction following ----------------
        let n = self.len();
        let mut next_model = model;
        if n > 1 {
            if rng.chance(spec.error_rate) {
                // invalid next_model name: error, engine falls back to self
                n_errors += 1;
                self.stats[model].errors += 1;
            } else {
                let recent: Vec<&String> = ctx.local_models.iter().flatten().collect();
                let utilities: Vec<f64> = (0..n)
                    .map(|j| {
                        let st = &self.stats[j];
                        let mut u = 0.75 * self.phi_small(j) + 1.25 * st.regular_hit_rate()
                            - 0.35 * (st.errors.min(5) as f64 / 5.0);
                        // cold-start exploration bonus for untried models
                        if st.regular_calls == 0 {
                            u += 0.25;
                        }
                        // local-context diversity: avoid the models that
                        // expanded the last two ancestors
                        if recent.iter().any(|r| r.as_str() == self.specs[j].name) {
                            u -= 0.15;
                        }
                        u
                    })
                    .collect();
                let temp = 0.15 + 0.45 * (1.0 - cap);
                next_model = rng.softmax_sample(&utilities, temp);
            }
        }

        // --- accounting ---------------------------------------------------
        let prompt_text = match kind {
            CallKind::Regular => prompts::regular_prompt(ctx),
            CallKind::CourseAlteration => prompts::course_alteration_prompt(
                ctx,
                "small-model",
                banned,
                self.specs[next_model].name,
                0.0,
            ),
        };
        // output: the JSON proposal (~30 tokens) + brief reasoning scaled
        // by model verbosity
        let out_tokens = 30.0 + 60.0 * cap;
        let rec = self.account(model, kind, &prompt_text, out_tokens);

        (
            Proposal {
                transforms: best_seq,
                next_model,
                n_errors,
            },
            rec,
        )
    }

    /// Simulate one model invocation: returns the proposal and the call
    /// record. `score_candidates` maps a proposed transform sequence to
    /// the engine's estimate of the resulting child's score — the
    /// capability-scaled internal deliberation ("which of the moves I can
    /// think of looks best").
    pub fn propose(
        &mut self,
        model: usize,
        ctx: &PromptCtx,
        kind: CallKind,
        banned: &[TransformKind],
        score_candidates: &mut dyn FnMut(&[TransformKind]) -> f64,
        rng: &mut Rng,
    ) -> (Proposal, CallRecord) {
        // the resilient pre-call loop may escalate to a larger model; the
        // returned CallRecord's `model` names whoever actually served
        let model = self.resolve_call(model, ctx, kind, banned);
        let vocab = Self::effective_vocab(&ctx.vocabulary, banned);

        // --- transformation sequence: capability-scaled lookahead -------
        // (candidate draws interleave with scoring + judgment noise, one
        // candidate at a time — the fused serial draw order)
        let n_cands = self.lookahead_width(model, kind);
        let noise_sigma = self.noise_sigma(model);
        let weights = self.seq_weights(model, &vocab);
        let mut best_seq: Vec<TransformKind> = Vec::new();
        let mut best_score = f64::NEG_INFINITY;
        for _ in 0..n_cands {
            let seq = Self::draw_seq(&weights, &vocab, rng);
            let s = score_candidates(&seq) + rng.normal_ms(0.0, noise_sigma);
            if s > best_score {
                best_score = s;
                best_seq = seq;
            }
        }
        self.finalize_proposal(model, ctx, kind, banned, &vocab, best_seq, rng)
    }

    /// Phase A of a **split** proposal (tree-parallel search): draw the
    /// candidate sequences this model would consider, without scoring
    /// them. The engine evaluates the candidates (batched, across
    /// workers) and then finishes the call with
    /// [`ModelSet::propose_scored`]. `&self`: drawing mutates no
    /// accounting state, so many lanes can prepare candidates before any
    /// call is committed.
    ///
    /// Note the split path draws all candidates first and all judgment
    /// noise later (in `propose_scored`), whereas [`ModelSet::propose`]
    /// interleaves them per candidate — both are deterministic in their
    /// RNG, but the streams differ by construction.
    pub fn draw_candidates(
        &self,
        model: usize,
        vocabulary: &[TransformKind],
        kind: CallKind,
        banned: &[TransformKind],
        rng: &mut Rng,
    ) -> Vec<Vec<TransformKind>> {
        let vocab = Self::effective_vocab(vocabulary, banned);
        let weights = self.seq_weights(model, &vocab);
        (0..self.lookahead_width(model, kind))
            .map(|_| Self::draw_seq(&weights, &vocab, rng))
            .collect()
    }

    /// Phase B of a split proposal: `scored` pairs each candidate from
    /// [`ModelSet::draw_candidates`] (same order) with the engine's score
    /// for it. Adds the model's judgment noise, picks the best candidate,
    /// and runs the shared call tail (error repair, routing, accounting)
    /// exactly like [`ModelSet::propose`].
    pub fn propose_scored(
        &mut self,
        model: usize,
        ctx: &PromptCtx,
        kind: CallKind,
        banned: &[TransformKind],
        scored: Vec<(Vec<TransformKind>, f64)>,
        rng: &mut Rng,
    ) -> (Proposal, CallRecord) {
        // same resilient pre-call loop as `propose`; on escalation the
        // larger model adjudicates the candidates the original (faulted)
        // model drew — its judgment noise and routing, its bill
        let model = self.resolve_call(model, ctx, kind, banned);
        let vocab = Self::effective_vocab(&ctx.vocabulary, banned);
        let noise_sigma = self.noise_sigma(model);
        let mut best_seq: Vec<TransformKind> = Vec::new();
        let mut best_score = f64::NEG_INFINITY;
        for (seq, base_score) in scored {
            let s = base_score + rng.normal_ms(0.0, noise_sigma);
            if s > best_score {
                best_score = s;
                best_seq = seq;
            }
        }
        self.finalize_proposal(model, ctx, kind, banned, &vocab, best_seq, rng)
    }

    /// Aggregate spend across the whole set.
    pub fn total_cost_usd(&self) -> f64 {
        self.stats.iter().map(|s| s.total_cost_usd).sum()
    }

    /// Aggregate serial LLM latency (the paper's compile-time component:
    /// calls are serial by design — §1 "all models are invoked serially").
    pub fn total_latency_s(&self) -> f64 {
        self.stats.iter().map(|s| s.total_latency_s).sum()
    }

    pub fn total_calls(&self) -> usize {
        self.stats.iter().map(|s| s.calls()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use registry::paper_config;

    fn ctx(set: &ModelSet) -> PromptCtx {
        PromptCtx {
            current: prompts::VariantCtx {
                code: "code".into(),
                trace_tail: "".into(),
                score: 0.5,
            },
            parent: None,
            grandparent: None,
            vocabulary: TransformKind::vocabulary(false),
            leaf_depth: 1,
            trials_done: 0,
            trials_budget: 100,
            model_stats: set.stat_lines(),
            local_models: [None, None, None],
        }
    }

    #[test]
    fn phi_small_extremes() {
        let set = ModelSet::new(paper_config(8, "gpt-5.2"));
        let biggest = set.largest;
        assert!((set.phi_small(biggest) - 0.0).abs() < 1e-9);
        let smallest = set
            .specs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.params_b.total_cmp(&b.1.params_b))
            .unwrap()
            .0;
        assert!((set.phi_small(smallest) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn propose_accounts_cost_and_latency() {
        let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
        let c = ctx(&set);
        let mut rng = Rng::new(1);
        let (prop, rec) = set.propose(0, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);
        assert!(!prop.transforms.is_empty());
        assert!(rec.cost_usd > 0.0 && rec.latency_s > 0.0);
        assert_eq!(set.stats[0].regular_calls, 1);
        assert!(set.total_cost_usd() > 0.0);
    }

    #[test]
    fn capable_models_pick_better_sequences() {
        // random-landscape scoring: the true value of the chosen sequence
        // should be higher for capable models (more lookahead, less noise)
        let mut set = ModelSet::new(paper_config(8, "gpt-5.2"));
        let c = ctx(&set);
        fn score(seq: &[TransformKind]) -> f64 {
            // deterministic pseudo-random landscape over sequences
            let mut h = 0xcbf29ce484222325u64;
            for t in seq {
                h ^= t.name().len() as u64 ^ (t.name().as_bytes()[0] as u64) << 8;
                h = h.wrapping_mul(0x100000001b3);
            }
            (h >> 11) as f64 / (1u64 << 53) as f64
        }
        let small = set.idx_by_name("Llama-3.1-8B-Instruct").unwrap();
        let largest = set.largest;
        let mut sum_big = 0.0;
        let mut sum_small = 0.0;
        for seed in 0..300 {
            let mut rng = Rng::new(seed);
            let (p, _) = set.propose(largest, &c, CallKind::Regular, &[], &mut score, &mut rng);
            sum_big += score(&p.transforms);
            let mut rng = Rng::new(seed + 10_000);
            let (p, _) = set.propose(small, &c, CallKind::Regular, &[], &mut score, &mut rng);
            sum_small += score(&p.transforms);
        }
        assert!(
            sum_big > sum_small * 1.05,
            "big {sum_big} vs small {sum_small}"
        );
    }

    #[test]
    fn size_aware_routing_prefers_small_models() {
        let mut set = ModelSet::new(paper_config(8, "gpt-5.2"));
        let c = ctx(&set);
        let mut rng = Rng::new(3);
        let largest = set.largest;
        let mut big_picks = 0;
        for _ in 0..300 {
            let (p, _) = set.propose(largest, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);
            if p.next_model == largest {
                big_picks += 1;
            }
        }
        assert!(big_picks < 60, "largest picked {big_picks}/300");
    }

    #[test]
    fn error_rates_accumulate() {
        let mut set = ModelSet::new(paper_config(8, "gpt-5.2"));
        let c = ctx(&set);
        let small = set.idx_by_name("DeepSeek-R1-Distill-Qwen-7B").unwrap();
        let mut rng = Rng::new(4);
        for _ in 0..500 {
            set.propose(small, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);
        }
        assert!(
            set.stats[small].errors > 5,
            "errors {}",
            set.stats[small].errors
        );
    }

    #[test]
    fn draw_candidates_respects_ban_and_width() {
        let set = ModelSet::new(paper_config(8, "gpt-5.2"));
        let c = ctx(&set);
        let mut rng = Rng::new(6);
        let banned = [TransformKind::TileSize, TransformKind::Unroll];
        let largest = set.largest;
        let cands =
            set.draw_candidates(largest, &c.vocabulary, CallKind::Regular, &banned, &mut rng);
        // capability-scaled width: the largest model considers several
        // candidates, CA calls consider even more
        assert!(cands.len() > 1);
        assert!(cands.iter().all(|s| !s.is_empty()));
        assert!(
            cands.iter().flatten().all(|t| !banned.contains(t)),
            "banned transform drawn"
        );
        let ca = set.draw_candidates(
            largest,
            &c.vocabulary,
            CallKind::CourseAlteration,
            &banned,
            &mut rng,
        );
        assert_eq!(ca.len(), cands.len() + 3);
        // drawing is deterministic in the rng and mutates no accounting
        let mut r2 = Rng::new(6);
        let again =
            set.draw_candidates(largest, &c.vocabulary, CallKind::Regular, &banned, &mut r2);
        assert_eq!(cands, again);
        assert_eq!(set.total_calls(), 0);
    }

    #[test]
    fn propose_scored_picks_high_scores_and_accounts_like_propose() {
        let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
        let c = ctx(&set);
        let largest = set.largest;
        let mut rng = Rng::new(7);
        let cands = set.draw_candidates(largest, &c.vocabulary, CallKind::Regular, &[], &mut rng);
        // give one candidate an overwhelming score: the (noisy) argmax
        // must pick it
        let winner = cands.len() / 2;
        let scored: Vec<(Vec<TransformKind>, f64)> = cands
            .iter()
            .enumerate()
            .map(|(i, s)| (s.clone(), if i == winner { 100.0 } else { 0.0 }))
            .collect();
        let (prop, rec) =
            set.propose_scored(largest, &c, CallKind::Regular, &[], scored, &mut rng);
        // the 100-vs-0 gap dwarfs judgment noise, so the winner is chosen;
        // error repair may still have resampled at most one element
        assert_eq!(prop.transforms.len(), cands[winner].len());
        let diffs = prop
            .transforms
            .iter()
            .zip(&cands[winner])
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            diffs <= 1,
            "picked {:?}, expected (≤1-repair of) {:?}",
            prop.transforms,
            cands[winner]
        );
        // the call is fully accounted, exactly like the fused propose path
        assert!(rec.cost_usd > 0.0 && rec.latency_s > 0.0);
        assert_eq!(set.stats[largest].regular_calls, 1);
        assert!(set.total_cost_usd() > 0.0);
    }

    // ---------------------------------------------------- fault injection

    const ALL_FAULT_KINDS: [FaultKind; 4] = [
        FaultKind::Timeout,
        FaultKind::RateLimit,
        FaultKind::Transient,
        FaultKind::Malformed,
    ];

    /// Rates that can only ever produce `kind`.
    fn rates_only(kind: FaultKind, rate: f64) -> faults::FaultRates {
        let mut r = faults::FaultRates::default();
        match kind {
            FaultKind::Timeout => r.timeout = rate,
            FaultKind::RateLimit => r.rate_limit = rate,
            FaultKind::Transient => r.transient = rate,
            FaultKind::Malformed => r.malformed = rate,
        }
        r
    }

    /// Find a stream seed whose first draws fault exactly per `pattern`
    /// at the given rate — deterministic, no test-only injection hooks:
    /// the real stream is simply seeded to produce the wanted schedule.
    fn seed_with_pattern(rate: f64, pattern: &[bool]) -> u64 {
        'seed: for seed in 0..100_000u64 {
            let mut s = seed;
            for &want in pattern {
                if (faults::unit(&mut s) < rate) != want {
                    continue 'seed;
                }
            }
            return seed;
        }
        panic!("no seed produces pattern {pattern:?} at rate {rate}");
    }

    /// The exact (latency, cost) one faulted attempt charges, per the
    /// [`FaultKind`] semantics table.
    fn fault_charge(
        set: &ModelSet,
        plan: &FaultPlan,
        model: usize,
        kind: FaultKind,
        c: &PromptCtx,
    ) -> (f64, f64) {
        let spec = &set.specs[model];
        match kind {
            FaultKind::Timeout => (plan.timeout_s, 0.0),
            FaultKind::RateLimit => (faults::RATE_LIMIT_LATENCY_S, 0.0),
            FaultKind::Transient => (spec.base_latency_s, 0.0),
            FaultKind::Malformed => {
                let tin = count_tokens(&prompts::regular_prompt(c));
                let out = 30.0 + 60.0 * spec.capability;
                (spec.call_latency(tin, out), spec.call_cost(tin, out))
            }
        }
    }

    #[test]
    fn explicit_zero_rate_plan_is_bit_identical_passthrough() {
        // same seed, one set with no plan, one with an all-zero plan
        // installed: identical proposal, record, and accounting bits
        let mut plain = ModelSet::new(paper_config(2, "gpt-5.2"));
        let mut zeroed = ModelSet::new(paper_config(2, "gpt-5.2"));
        zeroed.set_fault_plan(FaultPlan::uniform(2, faults::FaultRates::default(), 99));
        let c = ctx(&plain);
        for call in 0..20 {
            let mut ra = Rng::new(call);
            let mut rb = Rng::new(call);
            let (pa, ca) = plain.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut ra);
            let (pb, cb) = zeroed.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rb);
            assert_eq!(pa.transforms, pb.transforms);
            assert_eq!(pa.next_model, pb.next_model);
            assert_eq!(ca.latency_s.to_bits(), cb.latency_s.to_bits());
            assert_eq!(ca.cost_usd.to_bits(), cb.cost_usd.to_bits());
            assert_eq!(ra.state(), rb.state(), "engine RNG perturbed");
        }
        assert!(zeroed.fault_report.is_empty());
        for (a, b) in plain.stats.iter().zip(&zeroed.stats) {
            assert_eq!(a.total_latency_s.to_bits(), b.total_latency_s.to_bits());
            assert_eq!(a.total_cost_usd.to_bits(), b.total_cost_usd.to_bits());
            assert_eq!(a.errors, b.errors);
        }
    }

    #[test]
    fn fault_matrix_retry_success_exact_accounting() {
        // each kind: fault once on the small model, succeed on retry 1 —
        // charged exactly one fault + one backoff on top of the clean call
        for kind in ALL_FAULT_KINDS {
            let rate = 0.5;
            let stream = seed_with_pattern(rate, &[true, false]);
            let mut base = ModelSet::new(paper_config(2, "gpt-5.2"));
            let c = ctx(&base);
            let mut rng = Rng::new(11);
            let (_, base_rec) = base.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);

            let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
            let mut plan = FaultPlan::none();
            plan.rates = vec![faults::FaultRates::default(), rates_only(kind, rate)];
            plan.stream = stream;
            let (flat, fcost) = fault_charge(&set, &plan, 1, kind, &c);
            set.set_fault_plan(plan);
            let mut rng = Rng::new(11);
            let (_, rec) = set.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);

            assert_eq!(rec.model, 1, "{}: no escalation on retry success", kind.name());
            assert_eq!(set.stats[1].errors, base.stats[1].errors + 1);
            assert_eq!(set.stats[1].regular_calls, 1, "faults must not count as calls");
            let r = &set.fault_report;
            assert_eq!((r.injected(), r.retries, r.fallbacks, r.forced), (1, 1, 0, 0));
            // exact accounting, accumulated in the call path's order:
            // fault, backoff(2^0), then the clean call
            let mut want_lat = flat;
            want_lat += set.faults.backoff_base_s;
            want_lat += base_rec.latency_s;
            assert_eq!(
                set.stats[1].total_latency_s.to_bits(),
                want_lat.to_bits(),
                "{}: latency misaccounted",
                kind.name()
            );
            let mut want_cost = fcost;
            want_cost += base_rec.cost_usd;
            assert_eq!(
                set.stats[1].total_cost_usd.to_bits(),
                want_cost.to_bits(),
                "{}: cost misaccounted",
                kind.name()
            );
            assert_eq!(r.backoff_latency_s.to_bits(), set.faults.backoff_base_s.to_bits());
            assert_eq!(r.fault_latency_s.to_bits(), flat.to_bits());
            assert_eq!(r.fault_cost_usd.to_bits(), fcost.to_bits());
        }
    }

    #[test]
    fn fault_matrix_fallback_escalation_exact_accounting() {
        // each kind: the small model always faults → 3 attempts + 2
        // backoffs charged to it, then the call escalates to the larger
        // model, which serves it cleanly
        for kind in ALL_FAULT_KINDS {
            let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
            let c = ctx(&set);
            let mut plan = FaultPlan::none();
            plan.rates = vec![faults::FaultRates::default(), rates_only(kind, 1.0)];
            plan.stream = 7;
            let (flat, fcost) = fault_charge(&set, &plan, 1, kind, &c);
            let backoff_base = plan.backoff_base_s;
            set.set_fault_plan(plan);
            let mut rng = Rng::new(13);
            let (_, rec) = set.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);

            assert_eq!(rec.model, 0, "{}: must escalate to the largest", kind.name());
            assert_eq!(set.stats[0].regular_calls, 1);
            assert_eq!(set.stats[1].regular_calls, 0);
            assert_eq!(set.stats[1].errors, 3);
            let r = &set.fault_report;
            assert_eq!((r.injected(), r.retries, r.fallbacks, r.forced), (3, 2, 1, 0));
            // fault, backoff(2^0), fault, backoff(2^1), fault — all on
            // the small model; the clean call lands on the big one
            let mut want_lat = flat;
            want_lat += backoff_base;
            want_lat += flat;
            want_lat += backoff_base * 2.0;
            want_lat += flat;
            assert_eq!(
                set.stats[1].total_latency_s.to_bits(),
                want_lat.to_bits(),
                "{}: faulted-model latency misaccounted",
                kind.name()
            );
            let mut want_cost = fcost;
            want_cost += fcost;
            want_cost += fcost;
            assert_eq!(set.stats[1].total_cost_usd.to_bits(), want_cost.to_bits());
            assert_eq!(set.stats[0].total_latency_s.to_bits(), rec.latency_s.to_bits());
        }
    }

    #[test]
    fn fault_matrix_retry_exhaustion_at_largest_is_forced_not_stalled() {
        // each kind: the largest model always faults → retries exhaust
        // with nowhere to escalate; the call proceeds anyway ("forced")
        for kind in ALL_FAULT_KINDS {
            let mut base = ModelSet::new(paper_config(2, "gpt-5.2"));
            let c = ctx(&base);
            let mut rng = Rng::new(17);
            let (_, base_rec) = base.propose(0, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);

            let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
            let mut plan = FaultPlan::none();
            plan.rates = vec![rates_only(kind, 1.0)];
            plan.stream = 21;
            let (flat, fcost) = fault_charge(&set, &plan, 0, kind, &c);
            let backoff_base = plan.backoff_base_s;
            set.set_fault_plan(plan);
            let mut rng = Rng::new(17);
            let (_, rec) = set.propose(0, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);

            assert_eq!(rec.model, 0);
            assert_eq!(set.stats[0].regular_calls, 1);
            assert_eq!(set.stats[0].errors, base.stats[0].errors + 3);
            let r = &set.fault_report;
            assert_eq!((r.injected(), r.retries, r.fallbacks, r.forced), (3, 2, 0, 1));
            // fault, backoff(2^0), fault, backoff(2^1), fault, clean call
            let mut want_lat = flat;
            want_lat += backoff_base;
            want_lat += flat;
            want_lat += backoff_base * 2.0;
            want_lat += flat;
            want_lat += base_rec.latency_s;
            assert_eq!(
                set.stats[0].total_latency_s.to_bits(),
                want_lat.to_bits(),
                "{}: forced-path latency misaccounted",
                kind.name()
            );
            let mut want_cost = fcost;
            want_cost += fcost;
            want_cost += fcost;
            want_cost += base_rec.cost_usd;
            assert_eq!(set.stats[0].total_cost_usd.to_bits(), want_cost.to_bits());
        }
    }

    #[test]
    fn faulted_propose_scored_escalates_too() {
        // the split (tree-parallel) call path runs the same resilient
        // loop: candidates drawn by the small model, adjudicated and
        // billed by the escalation target after exhaustion
        let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
        let c = ctx(&set);
        let mut plan = FaultPlan::none();
        plan.rates = vec![faults::FaultRates::default(), rates_only(FaultKind::Transient, 1.0)];
        plan.stream = 3;
        set.set_fault_plan(plan);
        let mut rng = Rng::new(23);
        let cands = set.draw_candidates(1, &c.vocabulary, CallKind::Regular, &[], &mut rng);
        let scored: Vec<(Vec<TransformKind>, f64)> =
            cands.into_iter().map(|s| (s, 0.5)).collect();
        let (_, rec) = set.propose_scored(1, &c, CallKind::Regular, &[], scored, &mut rng);
        assert_eq!(rec.model, 0, "split path must escalate like the fused path");
        assert_eq!(set.stats[0].regular_calls, 1);
        assert_eq!(set.fault_report.fallbacks, 1);
        assert_eq!(set.stats[1].errors, 3);
    }

    #[test]
    fn fault_errors_surface_in_stat_lines() {
        let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
        let c = ctx(&set);
        let mut plan = FaultPlan::none();
        plan.rates = vec![faults::FaultRates::default(), rates_only(FaultKind::RateLimit, 1.0)];
        set.set_fault_plan(plan);
        let mut rng = Rng::new(29);
        set.propose(1, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);
        let lines = set.stat_lines();
        assert_eq!(lines[1].errors, 3, "fault errors must reach the prompt stats");
    }

    #[test]
    fn ca_prompt_cheaper_than_regular() {
        let mut set = ModelSet::new(paper_config(2, "gpt-5.2"));
        let c = ctx(&set);
        let mut rng = Rng::new(5);
        let (_, reg) = set.propose(0, &c, CallKind::Regular, &[], &mut |_| 0.5, &mut rng);
        let (_, ca) = set.propose(
            0,
            &c,
            CallKind::CourseAlteration,
            &[TransformKind::Unroll],
            &mut |_| 0.5,
            &mut rng,
        );
        assert!(ca.tokens_in < reg.tokens_in);
    }
}

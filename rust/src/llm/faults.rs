//! Deterministic fault injection for the simulated LLM serving substrate.
//!
//! COLT's premise is that the framework absorbs the unreliability of its
//! small models; a production serving system additionally has to absorb
//! the unreliability of the *APIs* those models sit behind. [`FaultPlan`]
//! makes that unreliability injectable and reproducible: per-model rates
//! for the four failure classes real serving endpoints exhibit —
//! timeouts, 429 rate limits, transient 5xx errors, and malformed
//! (unparseable) proposals — drawn from a **dedicated SplitMix64 stream**
//! that is completely separate from the engine RNG.
//!
//! Determinism contract:
//! * a plan whose rates are all zero performs **no stream draws at all**,
//!   so every fault-free search is bit-identical to a search with no plan
//!   installed (locked by `prop_zero_rate_fault_plan_is_bit_identical_…`
//!   in the property harness and the `chaos_smoke` CI gate);
//! * with a fixed `(plan, seed)`, faulted runs are bit-deterministic: the
//!   stream advances exactly once per faulted-model call attempt, and the
//!   stream state is persisted in tree snapshots so checkpoint/resume
//!   keeps the fault schedule intact.
//!
//! Recovery protocol (implemented by `ModelSet::resolve_call`): each
//! faulted attempt is charged honestly (see [`FaultKind`] semantics),
//! retried up to [`FaultPlan::max_retries`] times with exponential
//! backoff `backoff_base_s * 2^attempt`; on retry exhaustion the call
//! falls back to the next-larger roster model (dovetailing with the
//! paper's course-alteration escalation toward the largest model); at the
//! top of the roster the call proceeds anyway ("forced"), so a search can
//! degrade but never stall. Everything is tallied in [`FaultReport`].

use crate::util::rng::splitmix64;

/// Simulated latency of one 429 round trip (the server answers fast —
/// the point of a rate limit is that *no work* was done).
pub const RATE_LIMIT_LATENCY_S: f64 = 0.05;

/// One injected failure class, with its charging semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The call never answered: charged the plan's full `timeout_s` of
    /// wall-clock, no tokens, no cost.
    Timeout,
    /// HTTP 429: charged [`RATE_LIMIT_LATENCY_S`], no cost.
    RateLimit,
    /// Transient 5xx: charged the model's base round-trip latency, no
    /// cost.
    Transient,
    /// The call "succeeded" but returned an unparseable proposal: charged
    /// the **full** call latency, tokens, and USD cost — paid freight for
    /// unusable output.
    Malformed,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Timeout => "timeout",
            FaultKind::RateLimit => "rate_limit",
            FaultKind::Transient => "transient",
            FaultKind::Malformed => "malformed",
        }
    }
}

/// Per-model injection rates: the probability of each fault class per
/// call *attempt* (retries re-draw).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultRates {
    pub timeout: f64,
    pub rate_limit: f64,
    pub transient: f64,
    pub malformed: f64,
}

impl FaultRates {
    /// Same rate for every fault class.
    pub fn uniform(rate: f64) -> FaultRates {
        FaultRates {
            timeout: rate,
            rate_limit: rate,
            transient: rate,
            malformed: rate,
        }
    }

    pub fn is_zero(&self) -> bool {
        self.timeout == 0.0
            && self.rate_limit == 0.0
            && self.transient == 0.0
            && self.malformed == 0.0
    }
}

/// A seeded, per-model fault schedule. See the module docs for the
/// determinism contract and the recovery protocol built around it.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// `rates[i]` applies to roster model `i`; missing trailing entries
    /// mean zero rates for those models.
    pub rates: Vec<FaultRates>,
    /// Dedicated SplitMix64 stream state — advanced exactly once per
    /// call attempt on a nonzero-rate model, never by anything else.
    pub stream: u64,
    /// Retries per model after the first failed attempt (so a model gets
    /// `max_retries + 1` attempts before the call escalates).
    pub max_retries: usize,
    /// Backoff before retry `k` (0-based): `backoff_base_s * 2^k`,
    /// charged into the model's `total_latency_s`.
    pub backoff_base_s: f64,
    /// Simulated wall-clock cost of one timed-out attempt.
    pub timeout_s: f64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The zero plan: no rates, no draws, bit-identical passthrough.
    pub fn none() -> FaultPlan {
        FaultPlan {
            rates: Vec::new(),
            stream: 0,
            max_retries: 2,
            backoff_base_s: 0.5,
            timeout_s: 30.0,
        }
    }

    /// The same rates for all `n_models` roster models, streamed from
    /// `seed` (the usual chaos-test construction).
    pub fn uniform(n_models: usize, rates: FaultRates, seed: u64) -> FaultPlan {
        FaultPlan {
            rates: vec![rates; n_models],
            stream: seed,
            ..FaultPlan::none()
        }
    }

    /// True iff this plan can never fire (and therefore never draws).
    pub fn is_zero(&self) -> bool {
        self.rates.iter().all(FaultRates::is_zero)
    }

    pub fn rates_for(&self, model: usize) -> FaultRates {
        self.rates.get(model).copied().unwrap_or_default()
    }

    /// Decide one call attempt on `model`: `None` = the attempt succeeds.
    /// Models with all-zero rates return `None` **without advancing the
    /// stream**, so installing rates for one model leaves every other
    /// model's schedule untouched.
    pub fn draw(&mut self, model: usize) -> Option<FaultKind> {
        let r = self.rates_for(model);
        if r.is_zero() {
            return None;
        }
        let u = unit(&mut self.stream);
        let mut acc = r.timeout;
        if u < acc {
            return Some(FaultKind::Timeout);
        }
        acc += r.rate_limit;
        if u < acc {
            return Some(FaultKind::RateLimit);
        }
        acc += r.transient;
        if u < acc {
            return Some(FaultKind::Transient);
        }
        acc += r.malformed;
        if u < acc {
            return Some(FaultKind::Malformed);
        }
        None
    }
}

/// Uniform `[0,1)` from a SplitMix64 stream — the same 53-high-bit recipe
/// as `Rng::f64`, so rates behave identically across both RNG layers.
pub fn unit(state: &mut u64) -> f64 {
    (splitmix64(state) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Aggregate tally of everything the resilient call path did — surfaced
/// in `SearchResult::faults`, report lines, and tree snapshots, and
/// grid-summed across fleet lanes by the tree merge.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultReport {
    pub timeouts: usize,
    pub rate_limits: usize,
    pub transients: usize,
    pub malformed: usize,
    /// Backoff-then-retry events (each charged `backoff_base_s * 2^k`).
    pub retries: usize,
    /// Retry-exhaustion escalations to the next-larger roster model.
    pub fallbacks: usize,
    /// Calls that exhausted retries at the top of the roster and
    /// proceeded anyway (the no-stall guarantee).
    pub forced: usize,
    /// Total backoff wall-clock charged into `total_latency_s`.
    pub backoff_latency_s: f64,
    /// Total latency of the faulted attempts themselves.
    pub fault_latency_s: f64,
    /// USD paid for malformed (completed-but-unusable) attempts.
    pub fault_cost_usd: f64,
}

impl FaultReport {
    /// Total faults injected across all classes.
    pub fn injected(&self) -> usize {
        self.timeouts + self.rate_limits + self.transients + self.malformed
    }

    pub fn is_empty(&self) -> bool {
        *self == FaultReport::default()
    }

    pub fn record(&mut self, kind: FaultKind) {
        match kind {
            FaultKind::Timeout => self.timeouts += 1,
            FaultKind::RateLimit => self.rate_limits += 1,
            FaultKind::Transient => self.transients += 1,
            FaultKind::Malformed => self.malformed += 1,
        }
    }

    /// One-line human summary (CLI + report emitters).
    pub fn summary(&self) -> String {
        format!(
            "{} injected ({} timeout, {} rate-limit, {} transient, {} malformed), \
             {} retries, {} fallbacks, {} forced, {:.2}s backoff, {:.2}s fault latency, \
             ${:.4} fault cost",
            self.injected(),
            self.timeouts,
            self.rate_limits,
            self.transients,
            self.malformed,
            self.retries,
            self.fallbacks,
            self.forced,
            self.backoff_latency_s,
            self.fault_latency_s,
            self.fault_cost_usd,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_plan_never_draws() {
        let mut p = FaultPlan::none();
        assert!(p.is_zero());
        let before = p.stream;
        for m in 0..8 {
            assert_eq!(p.draw(m), None);
        }
        assert_eq!(p.stream, before, "zero plan advanced its stream");
        // zero rates installed explicitly behave the same
        let mut p = FaultPlan::uniform(4, FaultRates::default(), 123);
        assert!(p.is_zero());
        for m in 0..4 {
            assert_eq!(p.draw(m), None);
        }
        assert_eq!(p.stream, 123);
    }

    #[test]
    fn zero_rate_models_do_not_perturb_others() {
        // model 1 has rates, model 0 does not: interleaving calls to
        // model 0 must not shift model 1's fault schedule
        let mk = || FaultPlan {
            rates: vec![FaultRates::default(), FaultRates::uniform(0.25)],
            stream: 7,
            ..FaultPlan::none()
        };
        let mut a = mk();
        let seq_a: Vec<_> = (0..64).map(|_| a.draw(1)).collect();
        let mut b = mk();
        let seq_b: Vec<_> = (0..64)
            .map(|_| {
                assert_eq!(b.draw(0), None);
                b.draw(1)
            })
            .collect();
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn draw_is_deterministic_and_rate_faithful() {
        let rates = FaultRates {
            timeout: 0.1,
            rate_limit: 0.1,
            transient: 0.1,
            malformed: 0.1,
        };
        let mut p = FaultPlan::uniform(1, rates, 42);
        let seq: Vec<_> = (0..10_000).map(|_| p.draw(0)).collect();
        let mut q = FaultPlan::uniform(1, rates, 42);
        let again: Vec<_> = (0..10_000).map(|_| q.draw(0)).collect();
        assert_eq!(seq, again, "same seed, same schedule");
        let faults = seq.iter().filter(|f| f.is_some()).count();
        // total rate 0.4: the empirical frequency lands near it
        assert!(
            (3500..4500).contains(&faults),
            "empirical fault count {faults} wildly off 0.4 rate"
        );
        // every kind shows up under equal per-kind rates
        for kind in [
            FaultKind::Timeout,
            FaultKind::RateLimit,
            FaultKind::Transient,
            FaultKind::Malformed,
        ] {
            assert!(
                seq.iter().any(|f| *f == Some(kind)),
                "{} never drawn",
                kind.name()
            );
        }
    }

    #[test]
    fn report_counts_and_summary() {
        let mut r = FaultReport::default();
        assert!(r.is_empty());
        r.record(FaultKind::Timeout);
        r.record(FaultKind::Malformed);
        r.retries = 3;
        assert_eq!(r.injected(), 2);
        assert!(!r.is_empty());
        let s = r.summary();
        assert!(s.contains("2 injected") && s.contains("1 timeout") && s.contains("3 retries"));
    }
}

//! # LiteCoOp — lightweight multi-LLM shared-tree reasoning for
//! model-serving compiler optimizations.
//!
//! Full reproduction of the paper's system as a three-layer Rust + JAX +
//! Pallas stack (see DESIGN.md):
//!
//! * **Layer 3 (this crate)** — the paper's contribution: a shared MCTS
//!   tree over joint ⟨program, llm⟩ states with LA-UCT selection, endogenous
//!   model routing, and course alteration ([`mcts`]), plus every substrate
//!   it needs: a tensor IR ([`tir`]), schedule transformations
//!   ([`schedule`]), CPU/GPU performance simulators ([`sim`]), a
//!   gradient-boosted-trees cost model ([`costmodel`]), and a simulated
//!   heterogeneous LLM serving substrate ([`llm`]).
//! * **Layer 2** — JAX workload definitions (python/compile/model.py),
//!   AOT-lowered to HLO text and executed from [`runtime`] via PJRT.
//! * **Layer 1** — Pallas kernels (flash-attention, tiled matmul) called by
//!   Layer 2, validated against pure-jnp oracles at build time.
//!
//! The experiment harness ([`coordinator`], `bin/experiments.rs`)
//! regenerates every table and figure of the paper's evaluation.
//! Schedule legality is owned by the static analyzer ([`analysis`]):
//! every transform application is gated on its Deny-level lints, so no
//! illegal schedule ever enters a search tree.

// The crate is dependency-free and pure-safe Rust; keep it provably so.
#![forbid(unsafe_code)]

pub mod util;
pub mod tir;
pub mod workloads;
pub mod schedule;
pub mod analysis;
pub mod sim;
pub mod costmodel;
pub mod llm;
pub mod mcts;
pub mod baselines;
pub mod coordinator;
pub mod runtime;
pub mod stats;
pub mod benchutil;

/// Crate-wide result alias (see [`util::error`] for the error type).
pub type Result<T> = std::result::Result<T, util::error::Error>;

//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean / stddev / min reporting,
//! and a `bench_fn` entry point that the `cargo bench` targets use. Output
//! format is a stable, grep-friendly line per benchmark:
//!
//! `bench <name> ... mean 12.34us  std 0.56us  min 11.90us  iters 1000`
//!
//! Bench targets that track a perf trajectory over time additionally
//! collect their [`Summary`]s and emit a machine-readable JSON report via
//! [`write_json_report`] (e.g. `hot_paths` writes `BENCH_hotpaths.json`).

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

impl Summary {
    /// Summarize raw per-iteration samples (nanoseconds, non-empty) into a
    /// [`Summary`] — the single source of the mean/std/min statistics used
    /// by [`bench_fn`] and by hand-timed benches (e.g. the deep-iteration
    /// bench in `hot_paths`).
    pub fn from_samples(name: &str, samples_ns: &[f64], iters: usize) -> Summary {
        let n = samples_ns.len() as f64;
        let mean = samples_ns.iter().sum::<f64>() / n;
        let var = samples_ns.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        Summary {
            name: name.to_string(),
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
            iters,
        }
    }

    pub fn line(&self) -> String {
        format!(
            "bench {:<44} mean {:>12}  std {:>12}  min {:>12}  iters {}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`budget` of total measurement time, with 10% warmup.
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Summary {
    // calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let target = budget.as_nanos() as f64;
    let iters = ((target / one) as usize).clamp(5, 100_000);

    // warmup
    for _ in 0..(iters / 10).max(1) {
        f();
    }

    // measure in batches to reduce timer overhead for fast closures
    let batch = if one < 1_000.0 { 100 } else { 1 };
    let rounds = (iters / batch).max(5);
    let mut samples = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }

    let s = Summary::from_samples(name, &samples, rounds * batch);
    println!("{}", s.line());
    s
}

/// Serialize a bench run to machine-readable JSON:
/// `{"bench": <id>, "results": [{"name", "mean_ns", "std_ns", "min_ns",
/// "iters"}, ...]}` with results in run order. Deterministic layout (the
/// writer sorts object keys), so diffs between runs show only the numbers.
pub fn json_report(bench: &str, summaries: &[Summary]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let results: Vec<Json> = summaries
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("name", s.name.as_str().into())
                .set("mean_ns", s.mean_ns.into())
                .set("std_ns", s.std_ns.into())
                .set("min_ns", s.min_ns.into())
                .set("iters", s.iters.into());
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("bench", bench.into()).set("results", Json::Arr(results));
    root
}

/// Write [`json_report`] to `path` (with a trailing newline).
pub fn write_json_report(path: &str, bench: &str, summaries: &[Summary]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", json_report(bench, summaries)))
}

/// Time a single long-running operation (end-to-end experiment benches).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed();
    println!(
        "bench {:<44} once {:>12}",
        name,
        fmt_ns(dt.as_nanos() as f64)
    );
    (out, dt)
}

/// Tiny deterministic property-testing helper (proptest is unavailable
/// offline): run `cases` random cases through `prop`, reporting the seed of
/// the first failure so it can be replayed exactly.
pub fn check_prop<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut crate::util::Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = crate::util::Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let s = bench_fn("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns + 1.0);
        assert!(s.iters >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with('s'));
    }

    #[test]
    fn from_samples_stats() {
        let s = Summary::from_samples("x", &[10.0, 20.0, 30.0], 3);
        assert!((s.mean_ns - 20.0).abs() < 1e-9);
        assert_eq!(s.min_ns, 10.0);
        assert!((s.std_ns - (200.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert_eq!(s.iters, 3);
        assert_eq!(s.name, "x");
    }

    #[test]
    fn json_report_roundtrips() {
        use crate::util::json::Json;
        let summaries = vec![
            Summary {
                name: "trace_key_depth16".into(),
                mean_ns: 42.5,
                std_ns: 1.25,
                min_ns: 40.0,
                iters: 1000,
            },
            Summary {
                name: "apply_deep".into(),
                mean_ns: 900.0,
                std_ns: 10.0,
                min_ns: 880.0,
                iters: 500,
            },
        ];
        let j = json_report("hot_paths", &summaries);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("hot_paths"));
        let rs = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("trace_key_depth16"));
        assert_eq!(rs[0].get("mean_ns").unwrap().as_f64(), Some(42.5));
        assert_eq!(rs[1].get("iters").unwrap().as_f64(), Some(500.0));
    }

    #[test]
    fn write_json_report_writes_parseable_file() {
        use crate::util::json::Json;
        // pid-suffixed so concurrent test runs on one machine don't race
        let path = std::env::temp_dir()
            .join(format!("litecoop_bench_report_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let summaries = vec![Summary {
            name: "n".into(),
            mean_ns: 1.0,
            std_ns: 0.0,
            min_ns: 1.0,
            iters: 5,
        }];
        write_json_report(&path, "t", &summaries).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim_end()).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn check_prop_passes() {
        check_prop("rng-in-range", 50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_prop_reports_seed() {
        check_prop("always-fails", 3, 9, |_| Err("nope".into()));
    }
}

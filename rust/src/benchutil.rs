//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean / stddev / min reporting,
//! and a `bench_fn` entry point that the `cargo bench` targets use. Output
//! format is a stable, grep-friendly line per benchmark:
//!
//! `bench <name> ... mean 12.34us  std 0.56us  min 11.90us  iters 1000`
//!
//! Bench targets that track a perf trajectory over time additionally
//! collect their [`Summary`]s and emit a machine-readable JSON report via
//! [`write_json_report`] (e.g. `hot_paths` writes `BENCH_hotpaths.json`).
//! Reports round-trip through [`load_report`], and
//! [`compare_to_baseline`] turns (baseline, current) report pairs into
//! the per-bench verdicts the `experiments perfgate` CI gate enforces.

pub mod hotpaths;

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub mean_ns: f64,
    /// Median per-iteration time — the statistic the perf gate compares
    /// (robust to scheduler-noise outliers that skew the mean).
    pub median_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
    /// Heap allocations per iteration, when the bench target installed a
    /// counting allocator (see `benches/hot_paths.rs`); `None` when not
    /// measured. Reported in the JSON only when present.
    pub allocs_per_iter: Option<f64>,
}

impl Summary {
    /// Summarize raw per-iteration samples (nanoseconds, non-empty) into a
    /// [`Summary`] — the single source of the mean/median/std/min
    /// statistics used by [`bench_fn`] and by hand-timed benches (e.g. the
    /// deep-iteration bench in `hot_paths`).
    pub fn from_samples(name: &str, samples_ns: &[f64], iters: usize) -> Summary {
        let n = samples_ns.len() as f64;
        let mean = samples_ns.iter().sum::<f64>() / n;
        let var = samples_ns.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let min = samples_ns.iter().cloned().fold(f64::INFINITY, f64::min);
        let mut sorted = samples_ns.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mid = sorted.len() / 2;
        let median = if sorted.len() % 2 == 0 {
            0.5 * (sorted[mid - 1] + sorted[mid])
        } else {
            sorted[mid]
        };
        Summary {
            name: name.to_string(),
            mean_ns: mean,
            median_ns: median,
            std_ns: var.sqrt(),
            min_ns: min,
            iters,
            allocs_per_iter: None,
        }
    }

    pub fn line(&self) -> String {
        let allocs = match self.allocs_per_iter {
            Some(a) => format!("  allocs/iter {a:.1}"),
            None => String::new(),
        };
        format!(
            "bench {:<44} mean {:>12}  med {:>12}  std {:>12}  min {:>12}  iters {}{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            self.iters,
            allocs
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`budget` of total measurement time, with 10% warmup.
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Summary {
    // calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let target = budget.as_nanos() as f64;
    let iters = ((target / one) as usize).clamp(5, 100_000);

    // warmup
    for _ in 0..(iters / 10).max(1) {
        f();
    }

    // measure in batches to reduce timer overhead for fast closures
    let batch = if one < 1_000.0 { 100 } else { 1 };
    let rounds = (iters / batch).max(5);
    let mut samples = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }

    let s = Summary::from_samples(name, &samples, rounds * batch);
    println!("{}", s.line());
    s
}

/// Serialize a bench run to machine-readable JSON:
/// `{"bench": <id>, "results": [{"name", "mean_ns", "median_ns",
/// "std_ns", "min_ns", "iters"}, ...]}` with results in run order
/// (`allocs_per_iter` appears only on benches that measured it).
/// Deterministic layout (the writer sorts object keys), so diffs between
/// runs show only the numbers.
pub fn json_report(bench: &str, summaries: &[Summary]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let results: Vec<Json> = summaries
        .iter()
        .map(|s| {
            let mut o = Json::obj();
            o.set("name", s.name.as_str().into())
                .set("mean_ns", s.mean_ns.into())
                .set("median_ns", s.median_ns.into())
                .set("std_ns", s.std_ns.into())
                .set("min_ns", s.min_ns.into())
                .set("iters", s.iters.into());
            if let Some(a) = s.allocs_per_iter {
                o.set("allocs_per_iter", a.into());
            }
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("bench", bench.into()).set("results", Json::Arr(results));
    root
}

/// Write [`json_report`] to `path` (with a trailing newline).
pub fn write_json_report(path: &str, bench: &str, summaries: &[Summary]) -> std::io::Result<()> {
    std::fs::write(path, format!("{}\n", json_report(bench, summaries)))
}

/// Parse a [`json_report`]-format file back into summaries (run order
/// preserved). Reports written before `median_ns` existed fall back to
/// `mean_ns`, so an old committed baseline stays comparable instead of
/// failing the gate on a format change.
pub fn load_report(path: &str) -> Result<Vec<Summary>, String> {
    use crate::util::json::Json;
    let j = Json::parse_file(path)?;
    let rs = j
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: missing 'results' array"))?;
    rs.iter()
        .map(|r| {
            let name = r
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("{path}: result missing 'name'"))?
                .to_string();
            let num = |k: &str| r.get(k).and_then(Json::as_f64);
            let mean_ns =
                num("mean_ns").ok_or_else(|| format!("{path}: '{name}' missing 'mean_ns'"))?;
            Ok(Summary {
                median_ns: num("median_ns").unwrap_or(mean_ns),
                mean_ns,
                std_ns: num("std_ns").unwrap_or(0.0),
                min_ns: num("min_ns").unwrap_or(mean_ns),
                iters: num("iters").unwrap_or(0.0) as usize,
                allocs_per_iter: num("allocs_per_iter"),
                name,
            })
        })
        .collect()
}

/// One row of a perf-gate comparison ([`compare_to_baseline`]).
#[derive(Clone, Debug)]
pub struct GateRow {
    pub name: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    /// Relative change in percent; positive = slower than baseline.
    pub delta_pct: f64,
    /// `current` exceeds `baseline` by more than the tolerance.
    pub regressed: bool,
}

impl GateRow {
    /// Human-readable gate line (mirrors [`Summary::line`]'s layout).
    pub fn line(&self) -> String {
        format!(
            "gate  {:<44} base {:>12}  now {:>12}  delta {:>+7.1}%  {}",
            self.name,
            fmt_ns(self.baseline_ns),
            fmt_ns(self.current_ns),
            self.delta_pct,
            if self.regressed { "REGRESSED" } else { "ok" }
        )
    }
}

/// Compare a current run against a committed baseline: median vs median
/// (the robust center under scheduler noise; [`load_report`] substitutes
/// the mean for pre-median baselines) per benchmark name present in
/// **both** reports, in baseline order. Benchmarks only one side has are
/// skipped, so adding or retiring a bench never trips the gate; a bench
/// regresses when it is more than `tolerance_pct` percent slower than
/// its baseline median.
pub fn compare_to_baseline(
    baseline: &[Summary],
    current: &[Summary],
    tolerance_pct: f64,
) -> Vec<GateRow> {
    baseline
        .iter()
        .filter_map(|b| {
            let c = current.iter().find(|c| c.name == b.name)?;
            let delta_pct = (c.median_ns / b.median_ns.max(f64::MIN_POSITIVE) - 1.0) * 100.0;
            Some(GateRow {
                name: b.name.clone(),
                baseline_ns: b.median_ns,
                current_ns: c.median_ns,
                delta_pct,
                regressed: delta_pct > tolerance_pct,
            })
        })
        .collect()
}

/// Time a single long-running operation (end-to-end experiment benches).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed();
    println!(
        "bench {:<44} once {:>12}",
        name,
        fmt_ns(dt.as_nanos() as f64)
    );
    (out, dt)
}

/// Tiny deterministic property-testing helper (proptest is unavailable
/// offline): run `cases` random cases through `prop`, reporting the seed of
/// the first failure so it can be replayed exactly.
pub fn check_prop<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut crate::util::Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = crate::util::Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let s = bench_fn("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns + 1.0);
        assert!(s.iters >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with('s'));
    }

    /// Summary literal for gate tests: only name and median matter.
    fn summary(name: &str, median_ns: f64) -> Summary {
        Summary {
            name: name.into(),
            mean_ns: median_ns,
            median_ns,
            std_ns: 0.0,
            min_ns: median_ns,
            iters: 10,
            allocs_per_iter: None,
        }
    }

    #[test]
    fn from_samples_stats() {
        let s = Summary::from_samples("x", &[10.0, 20.0, 30.0], 3);
        assert!((s.mean_ns - 20.0).abs() < 1e-9);
        assert_eq!(s.median_ns, 20.0);
        assert_eq!(s.min_ns, 10.0);
        assert!((s.std_ns - (200.0f64 / 3.0).sqrt()).abs() < 1e-9);
        assert_eq!(s.iters, 3);
        assert_eq!(s.name, "x");
        assert_eq!(s.allocs_per_iter, None);
        // even sample count: median = midpoint of the two central samples,
        // robust against the outlier that drags the mean
        let s = Summary::from_samples("y", &[40.0, 10.0, 20.0, 1000.0], 4);
        assert_eq!(s.median_ns, 30.0);
        assert!(s.mean_ns > 200.0);
    }

    #[test]
    fn json_report_roundtrips() {
        use crate::util::json::Json;
        let summaries = vec![
            Summary {
                name: "trace_key_depth16".into(),
                mean_ns: 42.5,
                median_ns: 41.75,
                std_ns: 1.25,
                min_ns: 40.0,
                iters: 1000,
                allocs_per_iter: None,
            },
            Summary {
                name: "apply_deep".into(),
                mean_ns: 900.0,
                median_ns: 890.0,
                std_ns: 10.0,
                min_ns: 880.0,
                iters: 500,
                allocs_per_iter: Some(3.5),
            },
        ];
        let j = json_report("hot_paths", &summaries);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("hot_paths"));
        let rs = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("trace_key_depth16"));
        assert_eq!(rs[0].get("mean_ns").unwrap().as_f64(), Some(42.5));
        assert_eq!(rs[0].get("median_ns").unwrap().as_f64(), Some(41.75));
        // allocs_per_iter appears only where it was measured
        assert!(rs[0].get("allocs_per_iter").is_none());
        assert_eq!(rs[1].get("allocs_per_iter").unwrap().as_f64(), Some(3.5));
        assert_eq!(rs[1].get("iters").unwrap().as_f64(), Some(500.0));
    }

    #[test]
    fn write_json_report_writes_parseable_file() {
        use crate::util::json::Json;
        // pid-suffixed so concurrent test runs on one machine don't race
        let path = std::env::temp_dir()
            .join(format!("litecoop_bench_report_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let summaries = vec![Summary {
            name: "n".into(),
            mean_ns: 1.0,
            median_ns: 1.0,
            std_ns: 0.0,
            min_ns: 1.0,
            iters: 5,
            allocs_per_iter: Some(0.0),
        }];
        write_json_report(&path, "t", &summaries).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.ends_with('\n'));
        assert!(Json::parse(text.trim_end()).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_report_roundtrips_written_report() {
        let path = std::env::temp_dir()
            .join(format!("litecoop_bench_load_test_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let mut a = summary("alpha", 100.0);
        a.allocs_per_iter = Some(2.0);
        let b = summary("beta", 250.0);
        write_json_report(&path, "hot_paths", &[a, b]).unwrap();
        let back = load_report(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "alpha");
        assert_eq!(back[0].median_ns, 100.0);
        assert_eq!(back[0].allocs_per_iter, Some(2.0));
        assert_eq!(back[1].name, "beta");
        assert_eq!(back[1].iters, 10);
        assert_eq!(back[1].allocs_per_iter, None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_report_falls_back_to_mean_for_old_baselines() {
        // a pre-median report (the format the first committed baselines
        // may carry) must load with median := mean, not fail the gate
        let path = std::env::temp_dir()
            .join(format!("litecoop_bench_old_format_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        std::fs::write(
            &path,
            r#"{"bench":"hot_paths","results":[{"name":"old","mean_ns":50.0,"std_ns":1.0,"min_ns":48.0,"iters":7}]}"#,
        )
        .unwrap();
        let back = load_report(&path).unwrap();
        assert_eq!(back[0].median_ns, 50.0);
        assert_eq!(back[0].min_ns, 48.0);
        let _ = std::fs::remove_file(&path);
        assert!(load_report("/nonexistent/litecoop_bench.json").is_err());
    }

    #[test]
    fn gate_flags_synthetic_regression_beyond_tolerance() {
        // fabricated baseline vs a current run with one >tolerance
        // regression — the exact scenario `experiments perfgate` must
        // turn into a nonzero exit
        let baseline = vec![
            summary("stable", 100.0),
            summary("regressed", 100.0),
            summary("improved", 100.0),
            summary("retired_bench", 40.0),
        ];
        let current = vec![
            summary("stable", 104.0),    // +4% — inside a 10% tolerance
            summary("regressed", 125.0), // +25% — beyond tolerance
            summary("improved", 60.0),   // faster never trips the gate
            summary("brand_new_bench", 7.0),
        ];
        let rows = compare_to_baseline(&baseline, &current, 10.0);
        // names present in both reports only, baseline order
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].name, "stable");
        assert!(!rows[0].regressed);
        assert!(rows[1].regressed, "{:?}", rows[1]);
        assert!((rows[1].delta_pct - 25.0).abs() < 1e-9);
        assert!(!rows[2].regressed);
        assert!(rows[2].delta_pct < 0.0);
        assert!(rows.iter().any(|r| r.regressed));
        // a zero-tolerance gate flags even the small drift
        let strict = compare_to_baseline(&baseline, &current, 0.0);
        assert!(strict[0].regressed);
        // line rendering marks the verdicts
        assert!(rows[1].line().contains("REGRESSED"));
        assert!(rows[0].line().contains("ok"));
    }

    #[test]
    fn check_prop_passes() {
        check_prop("rng-in-range", 50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_prop_reports_seed() {
        check_prop("always-fails", 3, 9, |_| Err("nope".into()));
    }
}

//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean / stddev / min reporting,
//! and a `bench_fn` entry point that the `cargo bench` targets use. Output
//! format is a stable, grep-friendly line per benchmark:
//!
//! `bench <name> ... mean 12.34us  std 0.56us  min 11.90us  iters 1000`

use std::time::{Duration, Instant};

/// One benchmark measurement summary.
#[derive(Clone, Debug)]
pub struct Summary {
    pub name: String,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

impl Summary {
    pub fn line(&self) -> String {
        format!(
            "bench {:<44} mean {:>12}  std {:>12}  min {:>12}  iters {}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`budget` of total measurement time, with 10% warmup.
pub fn bench_fn<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Summary {
    // calibrate
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as f64;
    let target = budget.as_nanos() as f64;
    let iters = ((target / one) as usize).clamp(5, 100_000);

    // warmup
    for _ in 0..(iters / 10).max(1) {
        f();
    }

    // measure in batches to reduce timer overhead for fast closures
    let batch = if one < 1_000.0 { 100 } else { 1 };
    let rounds = (iters / batch).max(5);
    let mut samples = Vec::with_capacity(rounds);
    for _ in 0..rounds {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }

    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let s = Summary {
        name: name.to_string(),
        mean_ns: mean,
        std_ns: var.sqrt(),
        min_ns: min,
        iters: rounds * batch,
    };
    println!("{}", s.line());
    s
}

/// Time a single long-running operation (end-to-end experiment benches).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, Duration) {
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed();
    println!(
        "bench {:<44} once {:>12}",
        name,
        fmt_ns(dt.as_nanos() as f64)
    );
    (out, dt)
}

/// Tiny deterministic property-testing helper (proptest is unavailable
/// offline): run `cases` random cases through `prop`, reporting the seed of
/// the first failure so it can be replayed exactly.
pub fn check_prop<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut crate::util::Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = crate::util::Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let s = bench_fn("noop-ish", Duration::from_millis(20), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.mean_ns + 1.0);
        assert!(s.iters >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("us"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2_000_000_000.0).ends_with('s'));
    }

    #[test]
    fn check_prop_passes() {
        check_prop("rng-in-range", 50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_prop_reports_seed() {
        check_prop("always-fails", 3, 9, |_| Err("nope".into()));
    }
}

//! `litecoop` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   search   --workload <name> --target cpu|gpu --llms N --budget N
//!            [--largest M] [--lambda X] [--search-threads S]
//!            [--cache-file PATH]
//!            [--lanes N [--lane-threads T] [--registry-dir DIR]
//!             [--keep-lane-files]]
//!            <name> is a registry name (`workloads` subcommand) or a
//!            scenario name like `attention@seq=1024,heads=16` (see
//!            workloads::scenarios). --cache-file loads a persistent
//!            eval cache before the search and saves the warmed cache
//!            after it, so repeated searches across processes reuse
//!            ground-truth evaluations. --lanes N runs a root-parallel
//!            fleet instead of one search: N independent lanes on
//!            distinct seed streams split the budget, checkpoint
//!            through tree snapshots, and are merged into one resumable
//!            tree (coordinator::distributed); with --registry-dir the
//!            lanes warm-start from the scenario's serve-registry tree
//!            and the merged tree is persisted back for the daemon.
//!   lint     <scenario> [--storm N --seed S] [--target cpu|gpu]
//!            run the static legality analyzer on a workload's initial
//!            schedule, or (with --storm N) on every state of an N-step
//!            random transform storm. Prints all diagnostics and exits
//!            nonzero if any Deny-level lint fires (which would mean the
//!            apply-time gate is broken — see `litecoop::analysis`).
//!   serve    --registry-dir DIR [--max-trees K] [--budget-per-request N]
//!            [--llms N] [--largest M] [--target cpu|gpu]
//!            [--search-threads S] [--seed S] [--expect-warm-on-repeat]
//!            [--deadline SECS]
//!            resident daemon: read scenario names from stdin (one per
//!            line), resume each scenario's persisted MCTS tree from the
//!            registry (cold on first request), run N more samples,
//!            persist the tree back, and print the incumbent speedup.
//!            Up to K trees stay resident (LRU; eviction persists
//!            first). --expect-warm-on-repeat exits nonzero unless every
//!            repeated request resumes warm with cache hits and a
//!            monotone speedup (the CI smoke contract). --deadline SECS
//!            caps each request's simulated compile time: the sampling
//!            budget is trimmed deterministically once the engine's
//!            simulated clock exceeds the deadline, and trimmed replies
//!            carry a `deadline=trimmed` marker.
//!   models   (print the LLM catalog)
//!   workloads (print the benchmark registry)
//!   runtime  --artifact <name>  (load + execute an AOT artifact via PJRT)

use litecoop::baselines;
use litecoop::llm::registry;
use litecoop::mcts::evalcache::EvalCache;
use litecoop::mcts::SearchConfig;
use litecoop::runtime::Runtime;
use litecoop::schedule::Schedule;
use litecoop::sim::Target;
use litecoop::util::cli::Args;
use litecoop::workloads;
use std::sync::Arc;

fn main() -> litecoop::Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("search") | None => cmd_search(&args),
        Some("models") => {
            for m in registry::catalog() {
                println!(
                    "{:<32} {:>6.1}B  ${:>5.2}/M-in ${:>5.2}/M-out  {:>5.0} tok/s",
                    m.name, m.params_b, m.usd_per_mtok_in, m.usd_per_mtok_out, m.tokens_per_sec
                );
            }
            Ok(())
        }
        Some("workloads") => {
            for w in workloads::paper_benchmarks() {
                println!(
                    "{:<20} {:>8.1} GFLOP  {} blocks",
                    w.name,
                    w.flops() / 1e9,
                    w.blocks.len()
                );
            }
            Ok(())
        }
        Some("lint") => cmd_lint(&args),
        Some("serve") => cmd_serve(&args),
        Some("runtime") => cmd_runtime(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other}; see --help in README");
            std::process::exit(2);
        }
    }
}

fn cmd_search(args: &Args) -> litecoop::Result<()> {
    let workload_name = args.str_or("workload", "llama3_attention");
    let target = if args.str_or("target", "cpu") == "gpu" {
        Target::Gpu
    } else {
        Target::Cpu
    };
    if args.usize_or("lanes", 0) > 0 {
        return cmd_search_lanes(args, target, &workload_name);
    }
    let n_llms = args.usize_or("llms", 8);
    let largest = args.str_or("largest", "gpt-5.2");
    let workload = workloads::resolve(&workload_name)
        .map_err(|e| litecoop::err!("unknown workload {workload_name}: {e}"))?;
    let root = Schedule::initial(Arc::new(workload));
    let cache_file = args.flag("cache-file").map(str::to_string);
    let mut cfg = SearchConfig {
        budget: args.usize_or("budget", 300),
        seed: args.u64_or("seed", 7),
        lambda: args.f64_or("lambda", 0.5),
        search_threads: args.usize_or("search-threads", 1).max(1),
        ..SearchConfig::default()
    };
    if let Some(path) = &cache_file {
        let warm = EvalCache::load_file_or_cold(path);
        println!("eval-cache warm start: {} entries from {path}", warm.len());
        cfg.warm_cache = Some(Arc::new(warm));
    }
    println!(
        "LiteCoOp search: {workload_name} on {:?}, {n_llms} LLMs (largest {largest}), budget {}, search threads {}",
        target, cfg.budget, cfg.search_threads
    );
    let (r, warmed) = if n_llms == 1 {
        baselines::single_llm_with_cache(&largest, target, root, cfg, &workload_name)
    } else {
        baselines::litecoop_with_cache(n_llms, &largest, target, root, cfg, &workload_name)
    };
    if let Some(path) = &cache_file {
        match warmed.save_file(path) {
            Ok(()) => println!("eval cache saved: {} entries -> {path}", warmed.len()),
            Err(e) => eprintln!("warning: failed to save eval cache: {e}"),
        }
    }
    println!("final speedup      : {:.2}x", r.best_speedup);
    println!("compile time (sim) : {:.0}s", r.compile_time_s);
    println!("API cost (sim)     : ${:.3}", r.api_cost_usd);
    println!("course alterations : {}", r.n_ca_events);
    println!("model errors       : {}", r.n_errors);
    println!("analyzer rejects   : {}", r.lint_rejects);
    println!(
        "eval cache         : {} hits / {} misses ({:.1}% hit rate)",
        r.eval_cache.hits,
        r.eval_cache.misses,
        r.eval_cache.hit_rate() * 100.0
    );
    let total: usize = r.call_counts.iter().map(|(_, a, b)| a + b).sum();
    for (name, reg, ca) in &r.call_counts {
        if reg + ca > 0 {
            println!(
                "  {:<32} {:>5.1}% ({} regular, {} CA)",
                name,
                (reg + ca) as f64 / total as f64 * 100.0,
                reg,
                ca
            );
        }
    }
    println!("\nbest schedule trace (tail):\n{}", r.best_schedule.trace.render_tail(12));
    Ok(())
}

fn cmd_search_lanes(args: &Args, target: Target, scenario: &str) -> litecoop::Result<()> {
    use litecoop::coordinator::distributed::{run_fleet, FleetOpts};
    use litecoop::runtime::driver::default_threads;
    let opts = FleetOpts {
        scenario: scenario.to_string(),
        target,
        lanes: args.usize_or("lanes", 4).max(1),
        total_budget: args.usize_or("budget", 300),
        n_llms: args.usize_or("llms", 8),
        largest: args.str_or("largest", "gpt-5.2"),
        base_seed: args.u64_or("seed", 7),
        search_threads: args.usize_or("search-threads", 1).max(1),
        threads: args.usize_or("lane-threads", default_threads()).max(1),
        registry_dir: args.flag("registry-dir").map(str::to_string),
        cache_file: args.flag("cache-file").map(str::to_string),
        keep_lane_files: args.has("keep-lane-files"),
        fail_lanes: Vec::new(),
        flaky_lanes: Vec::new(),
    };
    println!(
        "LiteCoOp fleet: {scenario} on {:?}, {} lanes x {} LLMs, total budget {} (split across lanes)",
        target, opts.lanes, opts.n_llms, opts.total_budget
    );
    let r = run_fleet(&opts).map_err(|e| litecoop::err!("{e}"))?;
    for (l, s) in r.lane_speedups.iter().enumerate() {
        println!("lane {l:<2} speedup     : {s:.2}x");
    }
    for (what, why) in &r.skipped {
        println!("skipped {what}      : {why}");
    }
    println!("merged speedup     : {:.2}x ({} of {} lanes)", r.merged_speedup, r.lanes_merged, r.lanes_run);
    println!("merged tree        : {} nodes, {} samples", r.merged_nodes, r.merged_samples);
    match &r.tree_path {
        Some(p) => println!("registry tree      : {p}"),
        None => println!("registry tree      : (no --registry-dir; merged tree not persisted)"),
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> litecoop::Result<()> {
    use litecoop::analysis::{self, Severity};
    use litecoop::schedule::transforms::{apply, TransformKind};
    use litecoop::util::Rng;

    let scenario = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| args.str_or("workload", "llama3_attention"));
    let gpu = args.str_or("target", "cpu") == "gpu";
    let storm = args.usize_or("storm", 0);
    let seed = args.u64_or("seed", 7);
    let workload = workloads::resolve(&scenario)
        .map_err(|e| litecoop::err!("unknown workload {scenario}: {e}"))?;
    let mut sched = Schedule::initial(Arc::new(workload));
    let vocab = TransformKind::vocabulary(gpu);
    let mut rng = Rng::new(seed);
    let mut denies = 0usize;
    let mut warns = 0usize;
    let mut applied = 0usize;
    // state 0 is the initial schedule; states 1..=storm are reached by a
    // random transform storm through the Deny-gated `apply`
    for step in 0..=storm {
        if step > 0 {
            if apply(&sched, *rng.choice(&vocab), &mut rng, gpu).map(|s| sched = s).is_ok() {
                applied += 1;
            }
        }
        for d in analysis::analyze(&sched, gpu) {
            match d.severity {
                Severity::Deny => denies += 1,
                Severity::Warn => warns += 1,
            }
            println!("state {step:>4}  {d}");
        }
    }
    println!(
        "lint: {scenario} on {}, {storm} storm steps ({applied} applied, {} analyzer \
         rejections); diagnostics: {denies} deny, {warns} warn",
        if gpu { "gpu" } else { "cpu" },
        analysis::lint_rejects(),
    );
    if denies > 0 {
        eprintln!("error: Deny-level diagnostics on reachable schedules — the apply gate is broken");
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> litecoop::Result<()> {
    use litecoop::coordinator::serve::{serve, ServeOpts};
    let opts = ServeOpts {
        registry_dir: args.str_or("registry-dir", "trees"),
        max_trees: args.usize_or("max-trees", 8).max(1),
        budget_per_request: args.usize_or("budget-per-request", 60).max(1),
        n_llms: args.usize_or("llms", 4),
        largest: args.str_or("largest", "gpt-5.2"),
        target: if args.str_or("target", "cpu") == "gpu" {
            Target::Gpu
        } else {
            Target::Cpu
        },
        search_threads: args.usize_or("search-threads", 1).max(1),
        seed: args.u64_or("seed", 7),
        expect_warm_on_repeat: args.has("expect-warm-on-repeat"),
        deadline_s: args.flag("deadline").and_then(|s| s.parse().ok()),
        chaos_panic_scenarios: Vec::new(),
    };
    eprintln!(
        "litecoop serve: registry {} (max {} resident trees), {} samples/request, {} LLMs; \
         reading scenario names from stdin",
        opts.registry_dir, opts.max_trees, opts.budget_per_request, opts.n_llms
    );
    let stdin = std::io::stdin();
    let summary = serve(&opts, stdin.lock(), std::io::stdout().lock())
        .map_err(|e| litecoop::err!("{e}"))?;
    eprintln!(
        "serve: {} requests ({} resumed, {} errors, {} degraded, {} deadline-trimmed), {} evictions",
        summary.requests,
        summary.resumed,
        summary.errors,
        summary.degraded,
        summary.trimmed,
        summary.evictions
    );
    Ok(())
}

fn cmd_runtime(args: &Args) -> litecoop::Result<()> {
    let rt = Runtime::new(args.str_or("dir", "artifacts"))?;
    println!("PJRT platform: {}", rt.platform());
    let name = args.str_or("artifact", "llama4_mlp");
    let art = rt.load(&name)?;
    let inputs = rt.random_inputs(&art, args.u64_or("seed", 42))?;
    let lat = rt.measure_latency(&art, &inputs, args.usize_or("iters", 5))?;
    println!("{name}: mean latency {:.3} ms over {} iters", lat * 1e3, args.usize_or("iters", 5));
    Ok(())
}

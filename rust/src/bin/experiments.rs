//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §Experiment-index).
//!
//! Usage:
//!   experiments <id> [--budget N] [--reps K] [--threads T]
//!               [--search-threads S] [--quick]
//! ids: fig2 table1 table2 table3 fig3 lambda significance
//!      course_alteration llm_selection call_counts sample_efficiency all
//!
//! Scenario sweeps (parameterized workload matrices, see
//! `workloads::scenarios`):
//!   experiments sweep --family gemm --grid "m=256,512;k=64,128"
//!               [--targets cpu,gpu] [--llms N] [--seed S]
//!               [--cache-file PATH] [--expect-warm]
//! `--cache-file` persists the evaluation cache across processes: run a
//! sweep twice with the same file and the second run warm-starts from
//! every ground-truth evaluation the first one performed.
//! `--expect-warm` (for a sweep that *replays* the previous one) exits
//! nonzero unless the run truly warm-started: entries loaded, hits
//! reported, and no new ground-truth entries computed — the CI smoke
//! contract.
//! `--lanes N` routes every grid scenario through the root-parallel
//! fleet driver ([`litecoop::coordinator::run_lanes`]) instead: N
//! independent lanes per scenario on distinct seed streams, a
//! deterministic keyed-union merge of the lane trees, optional
//! `--registry-dir` persistence of each merged tree into the serve
//! registry, and `--cache-file` federation of every lane's ground
//! truth.
//!
//! Distributed-merge gate:
//!   experiments lanes_smoke [--scenario S] [--budget N] [--llms N]
//!               [--seed S] [--registry-dir DIR] [--keep-registry]
//! runs the same scenario as a 1-lane fleet and then a 4-lane fleet at
//! equal total budget against one serve registry; exits 7 unless the
//! 4-lane merged speedup is >= the 1-lane speedup, every lane survived
//! the merge, and a follow-up serve request resumes the merged tree
//! warm — the root-parallel CI contract.
//!
//! Chaos gate (see `litecoop::llm::faults`):
//!   experiments chaos_smoke [--scenario S] [--budget N] [--llms N]
//!               [--seed S]
//! checks the fault-injection contract: an all-zero-rate FaultPlan is a
//! bit-identical passthrough; a fixed-seed faulted run is
//! bit-deterministic, finishes with speedup >= 1, accounts every
//! retry/backoff/fallback into its reported latency, and survives a
//! mid-run snapshot/resume round-trip; a 4-lane fleet with one lane
//! forced dead merges its survivors bit-identically to a healthy
//! fleet's merge over the same lanes. Exits 8 on any miss.
//!
//! Incremental-evaluation gate:
//!   experiments blockmemo_smoke [--workload W] [--seed S] [--llms N]
//!               [--budget N]
//! runs one fixed-seed search cold and again against the warmed
//! per-block simulation memo; exits 4 unless the reported speedups are
//! bit-identical and the warm run was actually memo-served.
//!
//! Static-analyzer audit (see `litecoop::analysis`):
//!   experiments lint_audit [--storm-cases N] [--steps K] [--seed S]
//! runs N random transform storms per scenario family × target (6
//! families × cpu/gpu), lints every storm endpoint, and emits a
//! per-lint-code diagnostic table. Exits 5 if any Deny-level lint fires
//! on a reachable schedule — the apply-time gate's CI contract.
//!
//! Performance gate (see `litecoop::benchutil`):
//!   experiments perfgate [--baseline PATH] [--tolerance PCT]
//!               [--write-baseline]
//! runs the hot-path benchmark suite and compares each benchmark's
//! median against the committed baseline report (default
//! `BENCH_baseline.json`); exits 6 if any benchmark is more than PCT
//! percent slower (default 25, sized for shared-runner noise). A missing
//! baseline is a loud skip, exit 0 — the gate arms itself the first time
//! a toolchain-bearing run commits `--write-baseline` output.
//!
//! Absolute numbers come from the simulated substrate (DESIGN.md
//! §Substitutions); the *shape* (who wins, routing fractions, reduction
//! factors) is the reproduction target. Reports land in reports/<id>.md.

use litecoop::coordinator::{self, report, RunSpec, Searcher};
use litecoop::mcts::SearchResult;
use litecoop::sim::Target;
use litecoop::stats;
use litecoop::util::cli::Args;
use litecoop::util::table::Table;
use litecoop::workloads::{self, PAPER_BENCH_LABELS};

const BENCH_NAMES: [&str; 5] = [
    "llama3_attention",
    "deepseek_moe",
    "flux_attention",
    "flux_conv",
    "llama4_mlp",
];

#[derive(Clone)]
struct Opts {
    budget: usize,
    reps: u64,
    threads: usize,
    /// In-search tree parallelism per run (`--search-threads`, default 1
    /// = the serial engine).
    search_threads: usize,
    largest: String,
}

fn coop(n: usize, largest: &str) -> Searcher {
    Searcher::Coop {
        n,
        largest: largest.to_string(),
    }
}

fn matrix(benches: &[&str], searchers: &[Searcher], targets: &[Target], o: &Opts) -> Vec<RunSpec> {
    let mut specs = Vec::new();
    for b in benches {
        for s in searchers {
            for &t in targets {
                for rep in 0..o.reps {
                    let mut sp = RunSpec::new(b, t, s.clone(), o.budget, rep * 1000 + 7);
                    sp.search_threads = o.search_threads;
                    specs.push(sp);
                }
            }
        }
    }
    specs
}

fn group<'a>(
    specs: &[RunSpec],
    results: &'a [SearchResult],
    bench: &str,
    searcher: &Searcher,
    target: Target,
) -> Vec<&'a SearchResult> {
    specs
        .iter()
        .zip(results)
        .filter(|(sp, _)| sp.workload == bench && &sp.searcher == searcher && sp.target == target)
        .map(|(_, r)| r)
        .collect()
}

// ------------------------------------------------------------------ fig2/3

fn fig_speedup_curves(o: &Opts, id: &str) {
    let searchers = vec![
        Searcher::Single(o.largest.clone()),
        Searcher::Single("gpt-5-mini".into()),
        coop(2, &o.largest),
        coop(4, &o.largest),
        coop(8, &o.largest),
    ];
    let targets: Vec<Target> = if id == "fig3" {
        vec![Target::Gpu]
    } else {
        vec![Target::Gpu, Target::Cpu]
    };
    let specs = matrix(&BENCH_NAMES, &searchers, &targets, o);
    let results = coordinator::run_many(&specs, o.threads);
    let mut out = format!(
        "# {id}: speedup vs searched samples (largest = {})\n\n",
        o.largest
    );
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        for &t in &targets {
            let series: Vec<(String, Vec<(usize, f64)>)> = searchers
                .iter()
                .map(|s| {
                    let runs = group(&specs, &results, bench, s, t);
                    (s.label(), report::mean_curve(&runs))
                })
                .collect();
            let title = format!("{} — {} ({})", id, PAPER_BENCH_LABELS[bi], t.name());
            out.push_str(&report::curve_table(&title, &series).to_markdown());
            out.push('\n');
        }
    }
    let all: Vec<&SearchResult> = results.iter().collect();
    out.push_str(&format!(
        "\n{}\n{}\n{}\n",
        report::cache_line(&all),
        report::lint_line(&all),
        report::fault_line(&all)
    ));
    report::emit(id, &out).unwrap();
}

// ------------------------------------------------------------------ table1

fn table1(o: &Opts) {
    let searchers = vec![
        Searcher::Single(o.largest.clone()),
        coop(8, &o.largest),
        coop(4, &o.largest),
        coop(2, &o.largest),
    ];
    let targets = [Target::Gpu, Target::Cpu];
    let specs = matrix(&BENCH_NAMES, &searchers, &targets, o);
    let results = coordinator::run_many(&specs, o.threads);

    let mut t = Table::new(
        &format!(
            "Table 1: compile-time and API-cost reduction vs single {} (GPU/CPU)",
            o.largest
        ),
        &["Benchmark", "Metric", "LiteCoOp(8)", "LiteCoOp(4)", "LiteCoOp(2)"],
    );
    let mut agg: Vec<Vec<f64>> = vec![vec![]; 6];
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        let base: Vec<f64> = targets
            .iter()
            .map(|&tg| report::mean_time(&group(&specs, &results, bench, &searchers[0], tg)))
            .collect();
        let base_cost: Vec<f64> = targets
            .iter()
            .map(|&tg| report::mean_cost(&group(&specs, &results, bench, &searchers[0], tg)))
            .collect();
        let mut time_row = vec![PAPER_BENCH_LABELS[bi].to_string(), "Comp. Time ↓(×)".into()];
        let mut cost_row = vec![PAPER_BENCH_LABELS[bi].to_string(), "API Cost ↓(×)".into()];
        for (si, s) in searchers[1..].iter().enumerate() {
            let tr: Vec<f64> = targets
                .iter()
                .enumerate()
                .map(|(ti, &tg)| base[ti] / report::mean_time(&group(&specs, &results, bench, s, tg)))
                .collect();
            let cr: Vec<f64> = targets
                .iter()
                .enumerate()
                .map(|(ti, &tg)| {
                    base_cost[ti] / report::mean_cost(&group(&specs, &results, bench, s, tg))
                })
                .collect();
            time_row.push(format!("{:.2}/{:.2}", tr[0], tr[1]));
            cost_row.push(format!("{:.2}/{:.2}", cr[0], cr[1]));
            agg[si * 2].extend(&tr);
            agg[si * 2 + 1].extend(&cr);
        }
        t.row(time_row);
        t.row(cost_row);
    }
    let mut out = t.to_markdown();
    out.push_str("\nGeometric means over all benchmark-target pairs:\n");
    for (i, label) in [
        "8-LLM time",
        "8-LLM cost",
        "4-LLM time",
        "4-LLM cost",
        "2-LLM time",
        "2-LLM cost",
    ]
    .iter()
    .enumerate()
    {
        out.push_str(&format!("- {label} reduction: {:.2}x\n", stats::geomean(&agg[i])));
    }
    let all: Vec<&SearchResult> = results.iter().collect();
    out.push_str(&format!(
        "\n{}\n{}\n{}\n",
        report::cache_line(&all),
        report::lint_line(&all),
        report::fault_line(&all)
    ));
    report::emit("table1", &out).unwrap();
}

// ------------------------------------------------------------------ table2

fn table2(o: &Opts) {
    let searchers = [coop(8, &o.largest), coop(4, &o.largest), coop(2, &o.largest)];
    let targets = [Target::Gpu, Target::Cpu];
    let specs = matrix(&BENCH_NAMES, &searchers, &targets, o);
    let results = coordinator::run_many(&specs, o.threads);

    let mut out = format!(
        "# Table 2: invocation rates (%) averaged across the five benchmarks (largest = {})\n\n",
        o.largest
    );
    for &tg in &targets {
        let mut t = Table::new(
            &format!("{} target", tg.name()),
            &["Model", "LiteCoOp(8)", "LiteCoOp(4)", "LiteCoOp(2)"],
        );
        let runs8: Vec<&SearchResult> = BENCH_NAMES
            .iter()
            .flat_map(|b| group(&specs, &results, b, &searchers[0], tg))
            .collect();
        let names: Vec<String> = report::mean_invocation_rates(&runs8)
            .into_iter()
            .map(|(n, _, _)| n)
            .collect();
        let mut largest_rows = vec![
            vec![format!("{} (Regular)", o.largest)],
            vec![format!("{} (C.A.)", o.largest)],
            vec![format!("{} (Total)", o.largest)],
        ];
        let mut rows: Vec<Vec<String>> = vec![Vec::new(); names.len()];
        for s in &searchers {
            let runs: Vec<&SearchResult> = BENCH_NAMES
                .iter()
                .flat_map(|b| group(&specs, &results, b, s, tg))
                .collect();
            let rates = report::mean_invocation_rates(&runs);
            let find = |n: &str| {
                rates
                    .iter()
                    .find(|(nm, _, _)| nm == n)
                    .map(|&(_, r, c)| (r, c))
                    .unwrap_or((0.0, 0.0))
            };
            let (lr, lc) = find(&o.largest);
            largest_rows[0].push(format!("{:.1}", lr * 100.0));
            largest_rows[1].push(format!("{:.1}", lc * 100.0));
            largest_rows[2].push(format!("{:.1}", (lr + lc) * 100.0));
            for (ni, name) in names.iter().enumerate() {
                if name == &o.largest {
                    continue;
                }
                if rows[ni].is_empty() {
                    rows[ni].push(name.clone());
                }
                let (r, c) = find(name);
                if r + c > 0.0 {
                    rows[ni].push(format!("{:.1}", (r + c) * 100.0));
                } else {
                    rows[ni].push("–".into());
                }
            }
        }
        for r in largest_rows {
            t.row(r);
        }
        for r in rows.into_iter().filter(|r| !r.is_empty()) {
            t.row(r);
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    report::emit("table2", &out).unwrap();
}

// --------------------------------------------------------------- table3/16

fn table3(o: &Opts) {
    let graph = workloads::llama_e2e::llama3_8b_graph();
    let searchers = vec![
        Searcher::Single(o.largest.clone()),
        Searcher::Single("gpt-5-mini".into()),
        coop(8, &o.largest),
        coop(4, &o.largest),
        coop(2, &o.largest),
    ];
    let mut out = format!(
        "# Table 3 + Table 16: end-to-end Llama-3-8B (largest = {})\n\n",
        o.largest
    );
    for &tg in &[Target::Gpu, Target::Cpu] {
        let mut t = Table::new(
            &format!("{} target", tg.name()),
            &[
                "Config",
                "Speedup ×",
                "vs single ×",
                "Comp.Time ↓×",
                "API Cost ↓×",
                "# Samples",
                "Sample-eff gain ×",
            ],
        );
        let results: Vec<_> = searchers
            .iter()
            .map(|s| coordinator::run_e2e_threaded(&graph, tg, s, o.budget, 7, o.threads))
            .collect();
        let single = &results[0];
        let mini = &results[1];
        let mini_eff = mini.speedup / mini.n_samples as f64;
        for r in &results {
            let eff = r.speedup / r.n_samples as f64;
            t.row(vec![
                r.label.clone(),
                format!("{:.2}", r.speedup),
                format!("{:.2}", r.speedup / single.speedup),
                format!("{:.2}", single.compile_time_s / r.compile_time_s),
                format!("{:.2}", single.api_cost_usd / r.api_cost_usd.max(1e-9)),
                format!("{}", r.n_samples),
                format!("{:.2}", eff / mini_eff),
            ]);
        }
        out.push_str(&t.to_markdown());
        out.push('\n');
    }
    report::emit("table3", &out).unwrap();
}

// ------------------------------------------------------------------ lambda

fn lambda_ablation(o: &Opts) {
    let lambdas = [0.0, 0.25, 0.5, 0.75, 1.0];
    let mut out = String::from("# Appendix D (Tables 4/5): λ ablation, LiteCoOp(8 LLMs), CPU\n\n");
    let mut t = Table::new(
        "Table 4: final speedup by λ",
        &["Benchmark", "λ=0.0", "λ=0.25", "λ=0.5", "λ=0.75", "λ=1.0"],
    );
    let mut specs = Vec::new();
    for b in &BENCH_NAMES {
        for &l in &lambdas {
            for rep in 0..o.reps {
                let mut sp =
                    RunSpec::new(b, Target::Cpu, coop(8, &o.largest), o.budget, rep * 1000 + 7);
                sp.lambda = l;
                sp.search_threads = o.search_threads;
                specs.push(sp);
            }
        }
    }
    let results = coordinator::run_many(&specs, o.threads);
    let mut rates_out = String::new();
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        let mut row = vec![PAPER_BENCH_LABELS[bi].to_string()];
        for &l in &lambdas {
            let runs: Vec<&SearchResult> = specs
                .iter()
                .zip(&results)
                .filter(|(sp, _)| sp.workload == *bench && sp.lambda == l)
                .map(|(_, r)| r)
                .collect();
            row.push(format!("{:.2}", report::mean_speedup(&runs)));
            if (l - 0.5).abs() < 1e-9 {
                let rates = report::mean_invocation_rates(&runs);
                let largest_total: f64 = rates
                    .iter()
                    .filter(|(n, _, _)| n == &o.largest)
                    .map(|(_, r, c)| r + c)
                    .sum();
                rates_out.push_str(&format!(
                    "- {}: λ=0.5 largest-model total invocation {:.1}%\n",
                    PAPER_BENCH_LABELS[bi],
                    largest_total * 100.0
                ));
            }
        }
        t.row(row);
    }
    out.push_str(&t.to_markdown());
    out.push_str("\nTable 5 digest (invocation share of the largest model at λ=0.5):\n");
    out.push_str(&rates_out);
    report::emit("lambda", &out).unwrap();
}

// ------------------------------------------------------------ significance

fn significance(o: &Opts) {
    let reps = o.reps.max(10);
    let searchers = vec![
        Searcher::Single(o.largest.clone()),
        coop(8, &o.largest),
        coop(4, &o.largest),
        coop(2, &o.largest),
    ];
    let opts = Opts { reps, ..o.clone() };
    let specs = matrix(&BENCH_NAMES, &searchers, &[Target::Cpu], &opts);
    let results = coordinator::run_many(&specs, o.threads);
    let mut t = Table::new(
        "Table 6: Dunnett-adjusted one-sided tests vs single-largest control (CPU)",
        &["Benchmark", "Config", "ratio", "95% CI", "p-value"],
    );
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        let control: Vec<f64> = group(&specs, &results, bench, &searchers[0], Target::Cpu)
            .iter()
            .map(|r| r.best_speedup)
            .collect();
        for (si, label) in [
            (1usize, "LiteCoOp(8 LLMs)"),
            (2, "LiteCoOp(4 LLMs)"),
            (3, "LiteCoOp(2 LLMs)"),
        ] {
            let treat: Vec<f64> = group(&specs, &results, bench, &searchers[si], Target::Cpu)
                .iter()
                .map(|r| r.best_speedup)
                .collect();
            let res = stats::dunnett_test(&treat, &control, 3);
            t.row(vec![
                PAPER_BENCH_LABELS[bi].to_string(),
                label.to_string(),
                format!("{:.3}", res.ratio),
                format!("[{:.3}, {:.3}]", res.ci_low, res.ci_high),
                format!("{:.2e}", res.p_value),
            ]);
        }
    }
    report::emit("significance", &t.to_markdown()).unwrap();
}

// ------------------------------------------------------- course alteration

fn course_alteration(o: &Opts) {
    let settings: [(&str, Option<usize>); 3] = [
        ("No Course Alteration", None),
        ("Every 1 Small Model Regression", Some(1)),
        ("Every 2 Small Model Regressions", Some(2)),
    ];
    let mut specs = Vec::new();
    for b in &BENCH_NAMES {
        for (_, ca) in &settings {
            for rep in 0..o.reps {
                let mut sp =
                    RunSpec::new(b, Target::Cpu, coop(8, &o.largest), o.budget, rep * 1000 + 7);
                sp.ca_threshold = *ca;
                sp.search_threads = o.search_threads;
                specs.push(sp);
            }
        }
    }
    let results = coordinator::run_many(&specs, o.threads);
    let mut t = Table::new(
        "Appendix F (Tables 7–9): course-alteration ablation, LiteCoOp(8 LLMs), CPU",
        &["Benchmark", "Setting", "Speedup ×", "CA rate %", "Comp.Time s", "API Cost $"],
    );
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        for (label, ca) in &settings {
            let runs: Vec<&SearchResult> = specs
                .iter()
                .zip(&results)
                .filter(|(sp, _)| sp.workload == *bench && sp.ca_threshold == *ca)
                .map(|(_, r)| r)
                .collect();
            let ca_rate: f64 = runs
                .iter()
                .map(|r| {
                    let total: usize = r.call_counts.iter().map(|(_, a, b)| a + b).sum();
                    r.n_ca_events as f64 / total.max(1) as f64
                })
                .sum::<f64>()
                / runs.len() as f64;
            t.row(vec![
                PAPER_BENCH_LABELS[bi].to_string(),
                label.to_string(),
                format!("{:.2}", report::mean_speedup(&runs)),
                format!("{:.1}", ca_rate * 100.0),
                format!("{:.0}", report::mean_time(&runs)),
                format!("{:.3}", report::mean_cost(&runs)),
            ]);
        }
    }
    report::emit("course_alteration", &t.to_markdown()).unwrap();
}

// ----------------------------------------------------------- llm selection

fn llm_selection(o: &Opts) {
    let searchers = vec![
        coop(8, &o.largest),
        Searcher::RandomRouting {
            n: 8,
            largest: o.largest.clone(),
        },
        Searcher::RoundRobinRouting {
            n: 8,
            largest: o.largest.clone(),
        },
    ];
    let specs = matrix(&BENCH_NAMES, &searchers, &[Target::Cpu], o);
    let results = coordinator::run_many(&specs, o.threads);
    let mut t = Table::new(
        "Appendix G (Tables 10–12): routing ablation, 8-LLM pool, CPU",
        &["Benchmark", "Routing", "Speedup ×", "Comp.Time s", "API Cost $"],
    );
    for (bi, bench) in BENCH_NAMES.iter().enumerate() {
        for s in &searchers {
            let runs = group(&specs, &results, bench, s, Target::Cpu);
            t.row(vec![
                PAPER_BENCH_LABELS[bi].to_string(),
                s.label(),
                format!("{:.2}", report::mean_speedup(&runs)),
                format!("{:.0}", report::mean_time(&runs)),
                format!("{:.3}", report::mean_cost(&runs)),
            ]);
        }
    }
    report::emit("llm_selection", &t.to_markdown()).unwrap();
}

// ------------------------------------------------------------- call counts

fn call_counts(o: &Opts) {
    let searchers = [coop(8, &o.largest), coop(4, &o.largest), coop(2, &o.largest)];
    let mut out = format!(
        "# Appendix H (Tables 13–15): raw call counts per configuration (largest = {})\n\n",
        o.largest
    );
    for &tg in &[Target::Gpu, Target::Cpu] {
        let specs = matrix(&BENCH_NAMES, &searchers, &[tg], o);
        let results = coordinator::run_many(&specs, o.threads);
        out.push_str(&format!("## {} target\n\n", tg.name()));
        for (bi, bench) in BENCH_NAMES.iter().enumerate() {
            out.push_str(&format!("### {}\n", PAPER_BENCH_LABELS[bi]));
            for s in &searchers {
                let runs = group(&specs, &results, bench, s, tg);
                let r0 = runs[0];
                let counts: Vec<String> = r0
                    .call_counts
                    .iter()
                    .filter(|(_, a, b)| a + b > 0)
                    .map(|(n, a, b)| {
                        if *b > 0 {
                            format!("{n}: {a} reg + {b} CA")
                        } else {
                            format!("{n}: {a}")
                        }
                    })
                    .collect();
                out.push_str(&format!("- {}: {}\n", s.label(), counts.join(", ")));
            }
            out.push('\n');
        }
    }
    report::emit("call_counts", &out).unwrap();
}

// ------------------------------------------------------------------- sweep

fn sweep(o: &Opts, args: &Args) {
    use litecoop::mcts::evalcache::EvalCache;
    use litecoop::runtime::driver;
    use litecoop::workloads::scenarios::ScenarioGrid;

    let family = args.str_or("family", "gemm");
    let scenarios = ScenarioGrid::parse(&family, &args.str_or("grid", ""))
        .and_then(|g| g.expand())
        .unwrap_or_else(|e| {
            eprintln!("sweep: {e}");
            std::process::exit(2);
        });
    let targets: Vec<Target> = args
        .str_or("targets", "cpu")
        .split(',')
        .map(|t| match t.trim() {
            "gpu" => Target::Gpu,
            "cpu" => Target::Cpu,
            other => {
                eprintln!("sweep: unknown target {other:?} (expected cpu or gpu)");
                std::process::exit(2);
            }
        })
        .collect();
    let n_llms = args.usize_or("llms", 8);
    if args.usize_or("lanes", 0) > 0 {
        return sweep_lanes(o, args, &scenarios, &targets, n_llms);
    }
    let searcher = if n_llms <= 1 {
        Searcher::Single(o.largest.clone())
    } else {
        coop(n_llms, &o.largest)
    };
    let specs = coordinator::sweep_specs(
        &scenarios,
        &targets,
        &searcher,
        o.budget,
        args.u64_or("seed", 7),
        o.search_threads,
    );
    let cache_file = args.flag("cache-file");
    println!(
        "sweep: {} scenario(s) x {} target(s) = {} runs ({}, budget {})",
        scenarios.len(),
        targets.len(),
        specs.len(),
        searcher.label(),
        o.budget
    );

    let initial = match cache_file {
        Some(p) => EvalCache::load_file_or_cold(p),
        None => EvalCache::new(),
    };
    let loaded = initial.len();
    if let Some(p) = cache_file {
        println!("eval-cache warm start: {loaded} entries loaded from {p}");
    }
    let (results, warmed) = driver::run_specs_warm(&specs, o.threads, initial);
    if let Some(p) = cache_file {
        match warmed.save_file(p) {
            Ok(()) => println!("eval cache saved: {} entries -> {p}", warmed.len()),
            Err(e) => eprintln!("warning: failed to save eval cache: {e}"),
        }
    }

    let mut t = Table::new(
        &format!("Sweep: {family} scenario matrix ({})", searcher.label()),
        &["Scenario", "Target", "Speedup ×", "Samples", "Cache hit %"],
    );
    for (sp, r) in specs.iter().zip(&results) {
        t.row(vec![
            sp.workload.clone(),
            sp.target.name().to_string(),
            format!("{:.2}", r.best_speedup),
            format!("{}", r.n_samples),
            format!("{:.1}", r.eval_cache.hit_rate() * 100.0),
        ]);
    }
    let all: Vec<&SearchResult> = results.iter().collect();
    let agg = report::total_cache(&all);
    let mut out = t.to_markdown();
    out.push_str(&format!(
        "\nwarm start: {loaded} entries loaded; sweep total {} hits / {} misses ({:.1}% hit rate)\n{}\n",
        agg.hits,
        agg.misses,
        agg.hit_rate() * 100.0,
        report::lint_line(&all)
    ));
    print!("{out}");
    report::emit("sweep", &out).unwrap();
    // --expect-warm asserts a *replayed* sweep truly warm-started from
    // the file: entries were loaded, hits were reported, and — the
    // cross-process-specific signal in-search transposition hits can't
    // fake — the replay computed nothing new (every ground-truth key was
    // already in the file, so the saved cache didn't grow).
    if args.has("expect-warm") && (loaded == 0 || agg.hits == 0 || warmed.len() != loaded) {
        eprintln!(
            "sweep --expect-warm: expected a warm replay ({loaded} entries loaded, {} hits, \
             {} entries after the sweep)",
            agg.hits,
            warmed.len()
        );
        std::process::exit(3);
    }
}

/// `sweep --lanes N`: the fleet-driver path of the scenario sweep. One
/// root-parallel fleet per scenario × target, sharing the persistent
/// eval-cache file so fleet k+1 warm-starts from fleet k's ground
/// truth; merged trees land in `--registry-dir` when one is given.
fn sweep_lanes(
    o: &Opts,
    args: &Args,
    scenarios: &[litecoop::workloads::scenarios::ScenarioSpec],
    targets: &[Target],
    n_llms: usize,
) {
    use litecoop::coordinator::FleetOpts;

    let lanes = args.usize_or("lanes", 4);
    let names: Vec<String> = scenarios.iter().map(|s| s.name()).collect();
    println!(
        "sweep: {} scenario(s) x {} target(s), {lanes}-lane fleets (total budget {} each)",
        names.len(),
        targets.len(),
        o.budget
    );
    let mut t = Table::new(
        &format!("Sweep: {lanes}-lane root-parallel fleets (budget {} per fleet)", o.budget),
        &["Scenario", "Target", "Lanes merged", "Merged speedup ×", "Samples", "Nodes"],
    );
    let mut skipped: Vec<(String, String)> = Vec::new();
    for &target in targets {
        let base = FleetOpts {
            target,
            lanes,
            total_budget: o.budget,
            n_llms,
            largest: o.largest.clone(),
            base_seed: args.u64_or("seed", 7),
            search_threads: o.search_threads,
            threads: o.threads,
            registry_dir: args.flag("registry-dir").map(str::to_string),
            cache_file: args.flag("cache-file").map(str::to_string),
            keep_lane_files: args.has("keep-lane-files"),
            ..FleetOpts::default()
        };
        let results = coordinator::run_lanes(&base, &names).unwrap_or_else(|e| {
            eprintln!("sweep --lanes: {e}");
            std::process::exit(2);
        });
        for r in &results {
            t.row(vec![
                r.scenario.clone(),
                target.name().to_string(),
                format!("{}/{}", r.lanes_merged, r.lanes_run),
                format!("{:.2}", r.merged_speedup),
                format!("{}", r.merged_samples),
                format!("{}", r.merged_nodes),
            ]);
            for (what, why) in &r.skipped {
                skipped.push((format!("{} ({}) {what}", r.scenario, target.name()), why.clone()));
            }
        }
    }
    let mut out = t.to_markdown();
    for (what, why) in &skipped {
        out.push_str(&format!("- skipped: {what}: {why}\n"));
    }
    print!("{out}");
    report::emit("sweep", &out).unwrap();
}

/// CI gate for the root-parallel merge contract: run ONE scenario as a
/// 1-lane fleet and then a 4-lane fleet at the same total sample budget
/// against the same serve registry. The 4-lane fleet warm-starts its
/// lanes from the 1-lane fleet's persisted tree, so its merged incumbent
/// must be at least as good; a follow-up serve request against the same
/// registry must then resume the merged tree warm. Exit 7 on any miss.
fn lanes_smoke(o: &Opts, args: &Args) {
    use litecoop::coordinator::serve::{serve, ServeOpts};
    use litecoop::coordinator::FleetOpts;
    use std::io::Cursor;

    let scenario = args.str_or("scenario", "gemm");
    let seed = args.u64_or("seed", 7);
    let n_llms = args.usize_or("llms", 2);
    let dir = args.str_or(
        "registry-dir",
        &std::env::temp_dir()
            .join(format!("litecoop_lanes_smoke_{}", std::process::id()))
            .to_string_lossy(),
    );
    let _ = std::fs::remove_dir_all(&dir);

    let base = FleetOpts {
        scenario: scenario.clone(),
        lanes: 1,
        total_budget: o.budget,
        n_llms,
        largest: o.largest.clone(),
        base_seed: seed,
        search_threads: o.search_threads,
        threads: o.threads,
        registry_dir: Some(dir.clone()),
        ..FleetOpts::default()
    };
    let r1 = coordinator::run_fleet(&base).unwrap_or_else(|e| {
        eprintln!("lanes-smoke: 1-lane fleet failed: {e}");
        std::process::exit(7);
    });
    let r2 = coordinator::run_fleet(&FleetOpts { lanes: 4, ..base.clone() }).unwrap_or_else(|e| {
        eprintln!("lanes-smoke: 4-lane fleet failed: {e}");
        std::process::exit(7);
    });
    println!(
        "lanes-smoke: {scenario} budget {} — 1-lane speedup {:.4}, 4-lane merged {:.4} \
         ({} nodes, {} samples)",
        o.budget, r1.merged_speedup, r2.merged_speedup, r2.merged_nodes, r2.merged_samples
    );

    let mut failures = Vec::new();
    if r2.lanes_merged != r2.lanes_run {
        failures.push(format!(
            "only {} of {} lanes survived the merge: {:?}",
            r2.lanes_merged, r2.lanes_run, r2.skipped
        ));
    }
    if r2.merged_speedup < r1.merged_speedup {
        failures.push(format!(
            "4-lane merged speedup {:.6} regressed below the 1-lane speedup {:.6} at equal \
             total budget",
            r2.merged_speedup, r1.merged_speedup
        ));
    }

    // the merged tree must be servable: a follow-up daemon request on the
    // same registry resumes it warm rather than starting cold
    let serve_opts = ServeOpts {
        registry_dir: dir.clone(),
        budget_per_request: 16,
        n_llms,
        largest: o.largest.clone(),
        seed,
        ..ServeOpts::default()
    };
    let mut out = Vec::new();
    match serve(&serve_opts, Cursor::new(format!("{scenario}\n")), &mut out) {
        Ok(summary) => {
            let text = String::from_utf8_lossy(&out);
            print!("{text}");
            if summary.resumed != 1 || !text.contains("tree=resumed") {
                failures.push(format!(
                    "serve request did not resume the merged tree warm ({} of {} resumed)",
                    summary.resumed, summary.requests
                ));
            }
        }
        Err(e) => failures.push(format!("follow-up serve request failed: {e}")),
    }

    if failures.is_empty() {
        println!("  OK: merged fleet >= single lane and the merged tree serves warm");
        if !args.has("keep-registry") {
            let _ = std::fs::remove_dir_all(&dir);
        }
    } else {
        for f in &failures {
            eprintln!("lanes-smoke: {f}");
        }
        eprintln!("lanes-smoke: registry kept at {dir} for inspection");
        std::process::exit(7);
    }
}

/// CI gate for the fault-injection contract (see `litecoop::llm::faults`):
///
/// 1. **Passthrough**: a search with an explicit all-zero-rate
///    `FaultPlan` installed must be bit-identical (canonical snapshot
///    equality) to the same search with no plan at all.
/// 2. **Faulted resilience**: a fixed-seed search under nonzero rates is
///    bit-deterministic, completes with speedup >= 1, charges every
///    retry/backoff/fallback into the latency it reports, surfaces
///    injected faults in per-model error counters — and a mid-run
///    snapshot/resume round-trip (fault stream persisted in the tree
///    file) reproduces the uninterrupted faulted run bit-identically.
/// 3. **Supervised fleet**: a 4-lane fleet with one lane forced dead
///    merges the survivors into a tree bit-identical to a healthy
///    fleet's merge over the same lanes.
///
/// Exit 8 on any miss.
fn chaos_smoke(o: &Opts, args: &Args) {
    use litecoop::coordinator::FleetOpts;
    use litecoop::llm::faults::{FaultPlan, FaultRates};
    use litecoop::llm::registry::paper_config;
    use litecoop::llm::ModelSet;
    use litecoop::mcts::{treemerge, Mcts, SearchConfig};
    use litecoop::schedule::Schedule;
    use litecoop::sim::Simulator;
    use std::sync::Arc;

    let scenario = args.str_or("scenario", "gemm");
    let seed = args.u64_or("seed", 7);
    let n_llms = args.usize_or("llms", 2);
    let budget = o.budget;
    let mut failures: Vec<String> = Vec::new();

    let parts = || {
        let workload = workloads::resolve(&scenario).unwrap_or_else(|e| {
            eprintln!("chaos-smoke: unknown scenario {scenario}: {e}");
            std::process::exit(8);
        });
        (
            ModelSet::new(paper_config(n_llms, &o.largest)),
            Simulator::new(Target::Cpu),
            Schedule::initial(Arc::new(workload)),
        )
    };
    let build = |plan: Option<FaultPlan>| -> Mcts {
        let (mut models, sim, root) = parts();
        if let Some(p) = plan {
            models.set_fault_plan(p);
        }
        let cfg = SearchConfig {
            budget,
            seed,
            checkpoints: Vec::new(),
            ..SearchConfig::default()
        };
        Mcts::new(cfg, models, sim, root)
    };

    // ---- 1. zero-rate plan is a bit-identical passthrough --------------
    let clean = build(None).run_until(usize::MAX);
    let zeroed = build(Some(FaultPlan::uniform(n_llms, FaultRates::default(), seed ^ 0x5EED)))
        .run_until(usize::MAX);
    let snap_clean = format!("{}", clean.snapshot());
    if snap_clean != format!("{}", zeroed.snapshot()) {
        failures.push(
            "zero-rate FaultPlan perturbed the search: canonical snapshots differ".to_string(),
        );
    } else {
        println!(
            "chaos-smoke: passthrough OK — zero-rate plan bit-identical over {} samples",
            clean.samples()
        );
    }

    // ---- 2. faulted run: deterministic, resilient, fully accounted -----
    let plan = FaultPlan::uniform(n_llms, FaultRates::uniform(0.05), seed ^ 0x00C0_FFEE);
    let faulted = build(Some(plan.clone())).run_until(usize::MAX);
    let snap_faulted = format!("{}", faulted.snapshot());
    if snap_faulted != format!("{}", build(Some(plan.clone())).run_until(usize::MAX).snapshot()) {
        failures.push("faulted run is not bit-deterministic for a fixed (plan, seed)".to_string());
    }
    let report = faulted.models.fault_report.clone();
    if report.injected() == 0 {
        failures.push(format!(
            "no faults fired over {} samples at rate 0.05/class — raise --budget",
            faulted.samples()
        ));
    }
    if faulted.best_speedup() < 1.0 {
        failures.push(format!(
            "faulted search finished below baseline: speedup {:.4}",
            faulted.best_speedup()
        ));
    }
    let charged = report.fault_latency_s + report.backoff_latency_s;
    if report.injected() > 0 && (charged <= 0.0 || faulted.simulated_time_s() < charged) {
        failures.push(format!(
            "fault charges not accounted: {charged:.3}s of fault+backoff latency vs {:.3}s total",
            faulted.simulated_time_s()
        ));
    }
    if report.retries > 0 && report.backoff_latency_s <= 0.0 {
        failures.push(format!(
            "{} retries reported but no backoff latency charged",
            report.retries
        ));
    }
    let errors: usize = faulted.models.stats.iter().map(|s| s.errors).sum();
    if errors < report.injected() {
        failures.push(format!(
            "per-model error counters ({errors}) undercount injected faults ({})",
            report.injected()
        ));
    }
    println!(
        "chaos-smoke: faulted run speedup {:.4} — {}",
        faulted.best_speedup(),
        report.summary()
    );

    // mid-run snapshot/resume round-trip: the fault stream is persisted,
    // so the continuation must reproduce the uninterrupted run exactly
    let half = build(Some(plan)).run_until(budget / 2);
    let snap_half = half.snapshot();
    let (models, sim, root) = parts(); // note: NO plan — the snapshot's must win
    match Mcts::resume(&snap_half, models, sim, root) {
        Ok(resumed) => {
            let done = resumed.run_until(usize::MAX);
            if format!("{}", done.snapshot()) != snap_faulted {
                failures.push(
                    "faulted snapshot/resume round-trip diverged from the uninterrupted run"
                        .to_string(),
                );
            }
        }
        Err(e) => failures.push(format!("faulted snapshot failed to resume: {e}")),
    }

    // ---- 3. supervised fleet merge matches healthy-lanes-only merge ----
    let dir_f = std::env::temp_dir()
        .join(format!("litecoop_chaos_smoke_f_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let dir_h = std::env::temp_dir()
        .join(format!("litecoop_chaos_smoke_h_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    for d in [&dir_f, &dir_h] {
        let _ = std::fs::remove_dir_all(d);
    }
    let base = FleetOpts {
        scenario: scenario.clone(),
        lanes: 4,
        total_budget: budget,
        n_llms,
        largest: o.largest.clone(),
        base_seed: seed,
        search_threads: o.search_threads,
        threads: o.threads,
        keep_lane_files: true,
        ..FleetOpts::default()
    };
    let faulted_fleet = coordinator::run_fleet(&FleetOpts {
        fail_lanes: vec![2],
        registry_dir: Some(dir_f.clone()),
        ..base.clone()
    });
    let healthy_fleet = coordinator::run_fleet(&FleetOpts {
        registry_dir: Some(dir_h.clone()),
        ..base
    });
    match (faulted_fleet, healthy_fleet) {
        (Ok(rf), Ok(rh)) => {
            println!("chaos-smoke: {}", rf.health_summary());
            if rf.lanes_failed != 1 || rf.lanes_merged != 3 {
                failures.push(format!(
                    "supervisor miscounted the dead lane: {} failed / {} merged of {}",
                    rf.lanes_failed, rf.lanes_merged, rf.lanes_run
                ));
            }
            if rh.lanes_merged != 4 {
                failures.push(format!("healthy fleet lost lanes: {:?}", rh.skipped));
            }
            // merge the healthy fleet's lanes 0, 1, 3 by hand and compare
            // canonical bits with the supervised fleet's persisted merge
            let base_h = format!(
                "{dir_h}/{}",
                litecoop::coordinator::serve::tree_file_name(&scenario)
            );
            let survivors: Vec<String> =
                [0usize, 1, 3].iter().map(|l| format!("{base_h}.lane{l}")).collect();
            match treemerge::merge_snapshot_files(&survivors, parts) {
                Ok((manual, _)) => {
                    let persisted = rf
                        .tree_path
                        .as_ref()
                        .and_then(|p| std::fs::read_to_string(p).ok())
                        .unwrap_or_default();
                    if persisted.trim_end() != format!("{}", manual.snapshot()) {
                        failures.push(
                            "supervised fleet merge diverged from the healthy-lanes-only merge"
                                .to_string(),
                        );
                    } else {
                        println!(
                            "chaos-smoke: supervised merge OK — survivors match the \
                             healthy-lanes-only merge bit-for-bit"
                        );
                    }
                }
                Err(e) => failures.push(format!("manual survivor merge failed: {e}")),
            }
        }
        (Err(e), _) => failures.push(format!("supervised fleet failed outright: {e}")),
        (_, Err(e)) => failures.push(format!("healthy reference fleet failed: {e}")),
    }

    if failures.is_empty() {
        println!("chaos-smoke: OK — passthrough, faulted resilience, and supervised merge hold");
        for d in [&dir_f, &dir_h] {
            let _ = std::fs::remove_dir_all(d);
        }
    } else {
        for f in &failures {
            eprintln!("chaos-smoke: {f}");
        }
        eprintln!("chaos-smoke: fleet dirs kept at {dir_f} and {dir_h} for inspection");
        std::process::exit(8);
    }
}

/// CI gate for the legality-analyzer contract: storm every scenario
/// family on both targets through the Deny-gated `apply`, lint every
/// endpoint, and tabulate diagnostics per lint code. Reachable schedules
/// must carry zero Deny-level diagnostics (exit 5 otherwise); Warn-level
/// counts are the audit's payload — they show which degenerate-but-legal
/// states the search can actually visit.
fn lint_audit(o: &Opts, args: &Args) {
    use litecoop::analysis::{self, Lint, Severity};
    use litecoop::schedule::transforms::{apply, TransformKind};
    use litecoop::schedule::Schedule;
    use litecoop::util::rng::splitmix64;
    use litecoop::util::Rng;
    use litecoop::workloads::scenarios::{Family, ScenarioSpec};
    use std::collections::BTreeMap;
    use std::sync::Arc;

    let cases = args.usize_or("storm-cases", 200);
    let steps = args.usize_or("steps", 12);
    let base_seed = args.u64_or("seed", 7);
    let _ = o; // budget/reps knobs don't apply: the audit never searches

    // counts[code][family-column]; the last column aggregates everything
    let mut counts: BTreeMap<&'static str, Vec<u64>> = analysis::REGISTRY
        .iter()
        .map(|l| (l.code(), vec![0u64; Family::ALL.len() + 1]))
        .collect();
    let mut denies: Vec<String> = Vec::new();
    let mut endpoints = 0usize;
    let mut applied_total = 0usize;

    for (fi, &family) in Family::ALL.iter().enumerate() {
        let workload = ScenarioSpec::new(family).lower().unwrap_or_else(|e| {
            eprintln!("lint_audit: default {} scenario failed to lower: {e}", family.name());
            std::process::exit(5);
        });
        let base = Schedule::initial(Arc::new(workload));
        for gpu in [false, true] {
            let vocab = TransformKind::vocabulary(gpu);
            let mut stream = base_seed ^ ((fi as u64) << 32) ^ (gpu as u64);
            for _ in 0..cases {
                let mut rng = Rng::new(splitmix64(&mut stream));
                let mut s = base.clone();
                for _ in 0..steps {
                    if let Ok(next) = apply(&s, *rng.choice(&vocab), &mut rng, gpu) {
                        s = next;
                        applied_total += 1;
                    }
                }
                endpoints += 1;
                for d in analysis::analyze(&s, gpu) {
                    let row = counts.get_mut(d.code).expect("diagnostic code not in REGISTRY");
                    row[fi] += 1;
                    row[Family::ALL.len()] += 1;
                    if d.severity == Severity::Deny {
                        denies.push(format!("{} gpu={gpu}: {d}", family.name()));
                    }
                }
            }
        }
    }

    let mut header: Vec<String> = vec!["Lint code".into(), "Severity".into()];
    header.extend(Family::ALL.iter().map(|f| f.name().to_string()));
    header.push("total".into());
    let hdr_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!(
            "Lint audit: diagnostics over {cases} storm endpoints per family x target \
             ({steps}-step storms, seed {base_seed})"
        ),
        &hdr_refs,
    );
    for lint in analysis::REGISTRY.iter() {
        let row = &counts[lint.code()];
        let mut cells = vec![lint.code().to_string(), format!("{}", lint.severity())];
        cells.extend(row.iter().map(|c| c.to_string()));
        t.row(cells);
    }
    let mut out = t.to_markdown();
    out.push_str(&format!(
        "\n{endpoints} endpoints linted ({applied_total} transforms applied, \
         {} analyzer rejections); {} Deny-level diagnostics\n",
        analysis::lint_rejects(),
        denies.len()
    ));
    print!("{out}");
    report::emit("lint_audit", &out).unwrap();
    if !denies.is_empty() {
        for d in denies.iter().take(20) {
            eprintln!("lint_audit: DENY on reachable schedule: {d}");
        }
        eprintln!(
            "lint_audit: {} Deny-level diagnostics on reachable schedules — the \
             apply-time gate is broken",
            denies.len()
        );
        std::process::exit(5);
    }
}

/// CI gate for the incremental-evaluation contract: run ONE fixed-seed
/// search twice in-process. The first run starts with a cold per-block
/// simulation memo ([`litecoop::sim::blockcache`], thread-local — both
/// searches run on this thread) and fills it; the second run replays the
/// identical configuration against the warm memo. The reported speedups
/// must agree **bit for bit** (memoization is observationally
/// transparent) and the second run must have actually been served by the
/// memo (strictly fewer block-simulation misses) — otherwise exit 4.
fn blockmemo_smoke(o: &Opts, args: &Args) {
    use litecoop::sim::blockcache;

    let workload = args.str_or("workload", "llama_e2e");
    let seed = args.u64_or("seed", 7);
    let n_llms = args.usize_or("llms", 2);
    let spec = RunSpec::new(
        &workload,
        Target::Cpu,
        coop(n_llms, &o.largest),
        o.budget,
        seed,
    );

    blockcache::clear_thread();
    let cold = coordinator::run_one(&spec);
    let cold_stats = blockcache::thread_stats();
    blockcache::reset_thread_stats(); // zero counters, keep entries warm
    let warm = coordinator::run_one(&spec);
    let warm_stats = blockcache::thread_stats();

    println!(
        "blockmemo-smoke: {workload} seed {seed} budget {} ({} LLMs)",
        o.budget, n_llms
    );
    println!(
        "  cold run: speedup {:.4} (bits {:#018x}), block memo {} hits / {} misses",
        cold.best_speedup,
        cold.best_speedup.to_bits(),
        cold_stats.hits,
        cold_stats.misses
    );
    println!(
        "  warm run: speedup {:.4} (bits {:#018x}), block memo {} hits / {} misses",
        warm.best_speedup,
        warm.best_speedup.to_bits(),
        warm_stats.hits,
        warm_stats.misses
    );

    let mut failures = Vec::new();
    if cold.best_speedup.to_bits() != warm.best_speedup.to_bits() {
        failures.push(format!(
            "speedup bits diverged: cold {:#018x} vs warm {:#018x} — the block memo \
             is NOT observationally transparent",
            cold.best_speedup.to_bits(),
            warm.best_speedup.to_bits()
        ));
    }
    if cold.curve != warm.curve {
        failures.push("speedup curves diverged between cold and warm runs".into());
    }
    if warm_stats.misses >= cold_stats.misses {
        failures.push(format!(
            "warm run was not served by the memo ({} misses vs cold {}) — the smoke \
             gate lost its signal",
            warm_stats.misses, cold_stats.misses
        ));
    }
    if failures.is_empty() {
        println!(
            "  OK: bit-identical speedup; warm run skipped {} of {} block simulations",
            cold_stats.misses - warm_stats.misses,
            cold_stats.misses
        );
    } else {
        for f in &failures {
            eprintln!("blockmemo-smoke: {f}");
        }
        std::process::exit(4);
    }
}

/// CI perf gate: run the hot-path suite in-process and hold every
/// benchmark's median within `--tolerance` percent of the committed
/// baseline ([`litecoop::benchutil::compare_to_baseline`]). Exit 6 on
/// any regression (or an unreadable/disjoint baseline); a *missing*
/// baseline skips loudly with exit 0, so the gate can ship before the
/// first toolchain-bearing environment commits one with
/// `--write-baseline`.
fn perfgate(args: &Args) {
    use litecoop::benchutil::{self, hotpaths};

    let baseline_path = args.str_or("baseline", "BENCH_baseline.json");
    let tolerance = args.f64_or("tolerance", 25.0);
    let write = args.has("write-baseline");

    if !write && !std::path::Path::new(&baseline_path).exists() {
        println!(
            "perfgate: SKIPPED — no baseline at {baseline_path}. To arm the gate, run \
             `experiments perfgate --write-baseline` from a release build on a quiet \
             machine and commit the resulting {baseline_path}."
        );
        return;
    }

    let current = hotpaths::run_suite(None);

    if write {
        if let Err(e) = benchutil::write_json_report(&baseline_path, "hot_paths", &current) {
            eprintln!("perfgate: failed to write {baseline_path}: {e}");
            std::process::exit(6);
        }
        println!(
            "perfgate: baseline written to {baseline_path} ({} benchmarks) — commit it \
             to arm the CI gate",
            current.len()
        );
        return;
    }

    let baseline = benchutil::load_report(&baseline_path).unwrap_or_else(|e| {
        eprintln!("perfgate: unreadable baseline: {e}");
        std::process::exit(6);
    });
    let rows = benchutil::compare_to_baseline(&baseline, &current, tolerance);
    for r in &rows {
        println!("{}", r.line());
    }
    if rows.is_empty() {
        eprintln!(
            "perfgate: no benchmark names shared between {baseline_path} and the current \
             suite — stale baseline; refresh it with --write-baseline"
        );
        std::process::exit(6);
    }
    let regressed: Vec<&str> = rows
        .iter()
        .filter(|r| r.regressed)
        .map(|r| r.name.as_str())
        .collect();
    if !regressed.is_empty() {
        eprintln!(
            "perfgate: {} benchmark(s) regressed more than {tolerance}% vs \
             {baseline_path}: {}",
            regressed.len(),
            regressed.join(", ")
        );
        std::process::exit(6);
    }
    println!(
        "perfgate: OK — {} benchmarks within {tolerance}% of {baseline_path}",
        rows.len()
    );
}

fn main() {
    let args = Args::parse();
    let quick = args.has("quick");
    let o = Opts {
        budget: args.usize_or("budget", if quick { 120 } else { 300 }),
        reps: args.u64_or("reps", if quick { 2 } else { 3 }),
        threads: args.usize_or("threads", coordinator::default_threads()),
        search_threads: args.usize_or("search-threads", 1).max(1),
        largest: args.str_or("largest", "gpt-5.2"),
    };
    let cmd = args.subcommand.clone().unwrap_or_else(|| "all".into());
    let t0 = std::time::Instant::now();
    match cmd.as_str() {
        "fig2" => fig_speedup_curves(&o, "fig2"),
        "fig3" => {
            let o = Opts {
                largest: "Llama-3.3-70B-Instruct".into(),
                ..o
            };
            fig_speedup_curves(&o, "fig3");
        }
        "table1" => table1(&o),
        "table2" => table2(&o),
        "table3" => table3(&o),
        "lambda" => lambda_ablation(&o),
        "significance" => significance(&o),
        "course_alteration" => course_alteration(&o),
        "llm_selection" => llm_selection(&o),
        "call_counts" => call_counts(&o),
        "sample_efficiency" => table3(&o), // Table 16 is emitted with Table 3
        "sweep" => sweep(&o, &args),
        "lanes_smoke" => lanes_smoke(&o, &args),
        "chaos_smoke" => chaos_smoke(&o, &args),
        "blockmemo_smoke" => blockmemo_smoke(&o, &args),
        "lint_audit" => lint_audit(&o, &args),
        "perfgate" => perfgate(&args),
        "all" => {
            fig_speedup_curves(&o, "fig2");
            table1(&o);
            table2(&o);
            table3(&o);
            let o3 = Opts {
                largest: "Llama-3.3-70B-Instruct".into(),
                ..o.clone()
            };
            fig_speedup_curves(&o3, "fig3");
            lambda_ablation(&o);
            significance(&o);
            course_alteration(&o);
            llm_selection(&o);
            call_counts(&o);
        }
        other => {
            eprintln!("unknown experiment id: {other}");
            std::process::exit(2);
        }
    }
    eprintln!(
        "[experiments {cmd}] done in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

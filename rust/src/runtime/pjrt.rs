//! PJRT runtime: load the AOT artifacts produced by `make artifacts`
//! (python/compile/aot.py → HLO text) and execute them on the CPU PJRT
//! client — the Layer-2/Layer-1 executables on the rust request path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! Compiled only with the `pjrt` cargo feature (requires the vendored
//! `xla` crate in [dependencies]); see [`super::pjrt_stub`] for the
//! default build.

use crate::err;
use crate::util::error::Context;
use crate::util::{Json, Rng};
use crate::Result;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Input/output literal type — the same `runtime::Literal` name the stub
/// build exports, so callers can name it under either build.
pub use xla::Literal;

/// Shape+dtype of one executable argument (from the artifacts manifest).
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One loaded, compiled artifact.
pub struct Artifact {
    pub name: String,
    pub args: Vec<ArgSpec>,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed executor for all artifacts in a directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Parse the manifest written by aot.py.
    pub fn manifest(&self) -> Result<Vec<(String, Vec<ArgSpec>)>> {
        let text = std::fs::read_to_string(self.dir.join("manifest.json"))
            .context("reading artifacts/manifest.json — run `make artifacts` first")?;
        let json = Json::parse(&text).map_err(|e| err!("manifest parse: {e}"))?;
        let obj = json.as_obj().ok_or_else(|| err!("manifest not an object"))?;
        let mut out = Vec::new();
        for (name, entry) in obj {
            let args = entry
                .get("args")
                .and_then(|a| a.as_arr())
                .ok_or_else(|| err!("{name}: no args"))?
                .iter()
                .map(|a| {
                    let shape = a
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .map(|s| s.iter().filter_map(|d| d.as_f64()).map(|d| d as usize).collect())
                        .unwrap_or_default();
                    let dtype = a
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("float32")
                        .to_string();
                    ArgSpec { shape, dtype }
                })
                .collect();
            out.push((name.clone(), args));
        }
        Ok(out)
    }

    /// Load and compile one artifact by name.
    pub fn load(&self, name: &str) -> Result<Artifact> {
        let manifest = self.manifest()?;
        let (_, args) = manifest
            .iter()
            .find(|(n, _)| n == name)
            .ok_or_else(|| err!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("bad path"))?,
        )
        .map_err(|e| err!("hlo parse {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err!("compile {name}: {e:?}"))?;
        Ok(Artifact {
            name: name.to_string(),
            args,
            exe,
        })
    }

    /// Build deterministic random f32 inputs matching the arg specs.
    pub fn random_inputs(&self, art: &Artifact, seed: u64) -> Result<Vec<xla::Literal>> {
        let mut rng = Rng::new(seed);
        art.args
            .iter()
            .map(|spec| {
                let data: Vec<f32> = (0..spec.elems())
                    .map(|_| (rng.normal() * 0.1) as f32)
                    .collect();
                let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&data)
                    .reshape(&dims)
                    .map_err(|e| err!("reshape: {e:?}"))
            })
            .collect()
    }

    /// Execute once; returns the flattened f32 output.
    pub fn execute(&self, art: &Artifact, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = art
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| err!("execute {}: {e:?}", art.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        // aot.py wraps outputs in a 1-tuple (return_tuple=True)
        let out = result.to_tuple1().map_err(|e| err!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}"))
    }

    /// Measure mean wall-clock latency over `iters` runs (after 1 warmup).
    pub fn measure_latency(&self, art: &Artifact, inputs: &[xla::Literal], iters: usize) -> Result<f64> {
        self.execute(art, inputs)?; // warmup
        let t = Instant::now();
        for _ in 0..iters.max(1) {
            let bufs = art
                .exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| err!("execute: {e:?}"))?;
            // force completion
            let _ = bufs[0][0].to_literal_sync();
        }
        Ok(t.elapsed().as_secs_f64() / iters.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses_if_built() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let man = rt.manifest().unwrap();
        assert!(man.iter().any(|(n, _)| n == "llama4_mlp"));
        for (_, args) in &man {
            assert!(!args.is_empty());
        }
    }

    #[test]
    fn mlp_artifact_executes_with_finite_output() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let art = rt.load("llama4_mlp").unwrap();
        let inputs = rt.random_inputs(&art, 42).unwrap();
        let out = rt.execute(&art, &inputs).unwrap();
        assert!(!out.is_empty());
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn latency_measurement_positive() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = Runtime::new(artifacts_dir()).unwrap();
        let art = rt.load("flux_conv").unwrap();
        let inputs = rt.random_inputs(&art, 7).unwrap();
        let lat = rt.measure_latency(&art, &inputs, 2).unwrap();
        assert!(lat > 0.0 && lat < 60.0);
    }
}

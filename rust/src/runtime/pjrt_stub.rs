//! Stub PJRT runtime for builds without the `pjrt` cargo feature.
//!
//! Mirrors the API of [`super::pjrt`] exactly so that the CLI `runtime`
//! subcommand and the `e2e_llama` example compile unchanged; the
//! constructor reports that real execution is unavailable, and callers
//! degrade gracefully (the offline build environment has no vendored
//! `xla` crate to link against).

use crate::err;
use crate::Result;
use std::path::Path;

/// Shape+dtype of one executable argument (from the artifacts manifest).
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Placeholder for `xla::Literal` in the stub build.
#[derive(Clone, Debug, Default)]
pub struct Literal;

/// One loaded, compiled artifact (never constructed in the stub build).
pub struct Artifact {
    pub name: String,
    pub args: Vec<ArgSpec>,
}

/// The PJRT-backed executor; its constructor always errors in the stub
/// build.
pub struct Runtime {
    _private: (),
}

const UNAVAILABLE: &str =
    "PJRT runtime unavailable: rebuild with `--features pjrt` and the vendored `xla` crate";

impl Runtime {
    pub fn new<P: AsRef<Path>>(_artifacts_dir: P) -> Result<Runtime> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn manifest(&self) -> Result<Vec<(String, Vec<ArgSpec>)>> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn load(&self, _name: &str) -> Result<Artifact> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn random_inputs(&self, _art: &Artifact, _seed: u64) -> Result<Vec<Literal>> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn execute(&self, _art: &Artifact, _inputs: &[Literal]) -> Result<Vec<f32>> {
        Err(err!("{UNAVAILABLE}"))
    }

    pub fn measure_latency(
        &self,
        _art: &Artifact,
        _inputs: &[Literal],
        _iters: usize,
    ) -> Result<f64> {
        Err(err!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let e = Runtime::new("artifacts").err().unwrap();
        assert!(e.to_string().contains("pjrt"));
    }
}

//! Runtime layer: the parallel multi-workload search driver and the
//! (optional) PJRT executor for AOT artifacts.
//!
//! * [`driver`] — std-scoped-thread driver that searches many workloads
//!   concurrently with one deterministic RNG stream per workload and
//!   results merged back in workload order. This is what the experiment
//!   harness (`bin/experiments.rs`) and the examples fan out through.
//! * [`Runtime`] — PJRT execution of the AOT artifacts produced by
//!   `make artifacts` (python/compile/aot.py → HLO text). Real execution
//!   needs the vendored `xla` crate and is gated behind the `pjrt` cargo
//!   feature; the default build ships a stub with the identical API whose
//!   constructor reports the feature as unavailable, so every caller
//!   (CLI `runtime` subcommand, `e2e_llama` example) still compiles and
//!   degrades gracefully.

pub mod driver;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{ArgSpec, Artifact, Literal, Runtime};

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{ArgSpec, Artifact, Literal, Runtime};

//! Parallel multi-workload search driver.
//!
//! Searches many workloads (or whole experiment matrices of
//! [`RunSpec`]s) concurrently on std scoped threads. Determinism is
//! preserved by construction:
//!
//! * every run is a pure function of its spec — each search derives its
//!   own RNG streams from the spec's seed, and [`lane_seed`] gives each
//!   workload lane an independent deterministic stream regardless of how
//!   the OS schedules threads;
//! * workers pull work by atomic index and write into a per-spec result
//!   slot, so results come back **in spec order**, byte-identical to the
//!   serial path;
//! * the incremental simulator memo ([`crate::sim::blockcache`]) is
//!   **thread-local** — every driver worker (and every
//!   [`WorkerPool`] worker inside a tree-parallel search) warms its own,
//!   and served values are bit-identical to recomputation, so which
//!   thread a spec lands on can never change its result, only how much
//!   per-block simulation it skips.
//!
//! The experiment harness (`bin/experiments.rs`, via
//! [`crate::coordinator::run_many`]) and the `collab_search` example fan
//! out through this driver, which is how `table3_e2e`-style sweeps scale
//! with cores.

use crate::coordinator::{run_one, run_one_with_cache, RunSpec, Searcher};
use crate::mcts::evalcache::{CacheStats, EvalCache};
use crate::mcts::SearchResult;
use crate::sim::Target;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Default parallelism: one worker per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Independent deterministic seed for workload lane `lane` under
/// `base_seed` (one [`crate::util::rng::splitmix64`] step from a
/// lane-offset state — streams don't overlap and don't depend on thread
/// scheduling).
pub fn lane_seed(base_seed: u64, lane: u64) -> u64 {
    let mut state = base_seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(lane));
    crate::util::rng::splitmix64(&mut state)
}

/// Run independent jobs across up to `threads` scoped OS threads
/// (work-stealing by atomic index). Results come back in job order; since
/// every job is pure, the output is byte-identical to running the jobs
/// serially.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                *slots[i].lock().unwrap() = Some(job());
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job missing"))
        .collect()
}

/// A persistent pool of scoped worker threads processing index-tagged
/// jobs through one fixed worker function — the repeated-batch
/// complement of [`run_jobs`], which spawns (and joins) fresh threads per
/// call. When the same caller fans out many small batches (the
/// tree-parallel search engine evaluates a batch of candidate programs
/// *every round*), per-call thread spawn/join would dwarf the distributed
/// work; a `WorkerPool` pays the spawn cost once and a couple of channel
/// operations per job afterwards.
///
/// Jobs are submitted with a caller-chosen index and results come back
/// index-addressed ([`WorkerPool::collect`]), so batch outputs are in
/// submission order regardless of which worker finished first — the same
/// determinism contract as [`run_jobs`]. A panicking job is caught on
/// the worker and re-raised from [`WorkerPool::collect`] on the
/// coordinator (a swallowed panic would leave `collect` waiting forever
/// for the missing index). Dropping the pool shuts the workers down
/// (they drain in-flight jobs and exit before the owning
/// [`std::thread::scope`] joins).
pub struct WorkerPool<J, R> {
    job_tx: mpsc::Sender<(usize, J)>,
    res_rx: mpsc::Receiver<(usize, std::thread::Result<R>)>,
}

impl<J, R> WorkerPool<J, R> {
    /// Spawn `threads` workers (at least 1) on `scope`, each applying
    /// `work` to the jobs it dequeues.
    pub fn spawn<'scope, 'env, F>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        threads: usize,
        work: F,
    ) -> WorkerPool<J, R>
    where
        J: Send + 'env,
        R: Send + 'env,
        F: Fn(J) -> R + Send + Sync + 'env,
    {
        let (job_tx, job_rx) = mpsc::channel::<(usize, J)>();
        let (res_tx, res_rx) = mpsc::channel::<(usize, std::thread::Result<R>)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let work = Arc::new(work);
        for _ in 0..threads.max(1) {
            let job_rx = Arc::clone(&job_rx);
            let res_tx = res_tx.clone();
            let work = Arc::clone(&work);
            scope.spawn(move || loop {
                // hold the queue lock only to dequeue, never while working
                let msg = job_rx.lock().unwrap().recv();
                match msg {
                    Ok((i, job)) => {
                        // catch job panics and ship them to the collector
                        // (which re-raises); a worker that swallowed one
                        // would leave collect() short a result forever
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || work(job),
                        ));
                        let failed = out.is_err();
                        if res_tx.send((i, out)).is_err() || failed {
                            break;
                        }
                    }
                    Err(_) => break, // pool dropped: shut down
                }
            });
        }
        WorkerPool { job_tx, res_rx }
    }

    /// Submit one job under a caller-chosen result index.
    pub fn submit(&self, index: usize, job: J) {
        self.job_tx.send((index, job)).expect("worker pool alive");
    }

    /// Collect exactly `n` results, returned in index order (indices must
    /// be `0..n`, each submitted exactly once since the last collect).
    /// Re-raises the first job panic it receives.
    pub fn collect(&self, n: usize) -> Vec<R> {
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = self.res_rx.recv().expect("worker pool alive");
            match r {
                Ok(v) => {
                    assert!(out[i].is_none(), "worker pool index {i} submitted twice");
                    out[i] = Some(v);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        out.into_iter()
            .map(|r| r.expect("worker result missing"))
            .collect()
    }
}

/// Execute a matrix of runs across up to `threads` OS threads. Results are
/// returned in spec order and are byte-identical to running the specs
/// serially.
pub fn run_specs(specs: &[RunSpec], threads: usize) -> Vec<SearchResult> {
    run_jobs(specs.iter().map(|sp| move || run_one(sp)).collect(), threads)
}

/// Run a spec matrix with every search warm-started from `initial`'s
/// ground-truth entries (one `Arc`-shared snapshot; each search clones
/// the entries out, so lanes stay independent and every result is a
/// pure function of its spec plus the snapshot — byte-identical across
/// thread counts, and identical to a cold run except for the honestly
/// lower measurement time). Specs that already carry their own
/// [`RunSpec::warm_cache`] keep it.
///
/// Returns the results (spec order) plus the merged warmed cache:
/// `initial` ∪ every search's evaluations, in spec order, with stats
/// zeroed (counters are per-search, surfaced in each
/// [`SearchResult::eval_cache`]) — ready to persist with
/// [`EvalCache::save_file`].
pub fn run_specs_warm(
    specs: &[RunSpec],
    threads: usize,
    initial: EvalCache,
) -> (Vec<SearchResult>, EvalCache) {
    let warm = Arc::new(initial);
    let jobs: Vec<_> = specs
        .iter()
        .map(|sp| {
            let warm = Arc::clone(&warm);
            move || {
                let mut sp = sp.clone();
                if sp.warm_cache.is_none() {
                    sp.warm_cache = Some(warm);
                }
                run_one_with_cache(&sp)
            }
        })
        .collect();
    let outs = run_jobs(jobs, threads);
    let mut merged = Arc::try_unwrap(warm).unwrap_or_else(|shared| (*shared).clone());
    merged.reset_stats();
    let mut results = Vec::with_capacity(outs.len());
    for (r, cache) in outs {
        merged.absorb(cache);
        results.push(r);
    }
    (results, merged)
}

/// File-backed warm start around [`run_specs_warm`]: load `cache_file`
/// (a missing file is a silent cold start; a corrupt one degrades to
/// cold with a stderr warning), run the matrix seeded from it, and
/// atomically save the merged warmed cache back — so the next process
/// sweeping overlapping scenarios starts with every ground-truth
/// evaluation this one (and its predecessors) performed. `None` is
/// exactly [`run_specs`].
pub fn run_specs_cached(
    specs: &[RunSpec],
    threads: usize,
    cache_file: Option<&str>,
) -> Vec<SearchResult> {
    let Some(path) = cache_file else {
        return run_specs(specs, threads);
    };
    let initial = EvalCache::load_file_or_cold(path);
    let (results, warmed) = run_specs_warm(specs, threads, initial);
    if let Err(e) = warmed.save_file(path) {
        eprintln!("warning: failed to save eval cache: {e}");
    }
    results
}

/// Search many workloads concurrently with one searcher configuration:
/// workload lane `i` runs under the deterministic seed
/// `lane_seed(base_seed, i)`, and results come back in workload order.
pub fn search_workloads(
    workloads: &[&str],
    target: Target,
    searcher: &Searcher,
    budget: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<SearchResult> {
    search_workloads_threaded(workloads, target, searcher, budget, base_seed, threads, 1)
}

/// [`search_workloads`] with an explicit `--search-threads` knob: every
/// workload's search additionally runs tree-parallel across
/// `search_threads` workers ([`crate::mcts::Mcts::run_parallel`]).
/// `search_threads = 1` is the serial engine; each search stays
/// deterministic per (lane seed, search_threads), so the batch result is
/// still a pure function of the arguments.
#[allow(clippy::too_many_arguments)]
pub fn search_workloads_threaded(
    workloads: &[&str],
    target: Target,
    searcher: &Searcher,
    budget: usize,
    base_seed: u64,
    threads: usize,
    search_threads: usize,
) -> Vec<SearchResult> {
    let specs: Vec<RunSpec> = workloads
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let mut sp = RunSpec::new(
                w,
                target,
                searcher.clone(),
                budget,
                lane_seed(base_seed, i as u64),
            );
            sp.search_threads = search_threads.max(1);
            sp
        })
        .collect();
    run_specs(&specs, threads)
}

/// Aggregate eval-cache counters over a driver batch (the owned-slice
/// face of [`crate::coordinator::report::total_cache`]).
pub fn aggregate_cache(results: &[SearchResult]) -> CacheStats {
    crate::coordinator::report::total_cache(&results.iter().collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(n: u64) -> Vec<RunSpec> {
        (0..n)
            .map(|seed| {
                RunSpec::new(
                    "gemm",
                    Target::Cpu,
                    Searcher::Coop {
                        n: 2,
                        largest: "gpt-5.2".into(),
                    },
                    40,
                    seed,
                )
            })
            .collect()
    }

    #[test]
    fn parallel_results_byte_identical_to_serial() {
        let sp = specs(3);
        let par = run_specs(&sp, 3);
        let ser = run_specs(&sp, 1);
        assert_eq!(par.len(), ser.len());
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.workload, s.workload);
            assert_eq!(p.best_speedup, s.best_speedup);
            assert_eq!(p.best_latency_s, s.best_latency_s);
            assert_eq!(p.curve, s.curve);
            assert_eq!(p.api_cost_usd, s.api_cost_usd);
            assert_eq!(p.compile_time_s, s.compile_time_s);
            assert_eq!(p.n_samples, s.n_samples);
            assert_eq!(p.eval_cache, s.eval_cache);
        }
    }

    #[test]
    fn lane_seeds_deterministic_and_distinct() {
        let a: Vec<u64> = (0..16).map(|i| lane_seed(7, i)).collect();
        let b: Vec<u64> = (0..16).map(|i| lane_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len());
        assert_ne!(lane_seed(7, 0), lane_seed(8, 0));
    }

    #[test]
    fn search_workloads_returns_in_workload_order() {
        let searcher = Searcher::Coop {
            n: 2,
            largest: "gpt-5.2".into(),
        };
        let names = ["gemm", "llama4_mlp"];
        let rs = search_workloads(&names, Target::Cpu, &searcher, 30, 5, 2);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].workload, "gemm");
        assert_eq!(rs[1].workload, "llama4_mlp");
        // same call again is fully deterministic
        let rs2 = search_workloads(&names, Target::Cpu, &searcher, 30, 5, 1);
        for (a, b) in rs.iter().zip(&rs2) {
            assert_eq!(a.best_speedup, b.best_speedup);
            assert_eq!(a.eval_cache, b.eval_cache);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(run_specs(&[], 4).is_empty());
        assert_eq!(aggregate_cache(&[]), CacheStats::default());
        let (rs, cache) = run_specs_warm(&[], 4, EvalCache::new());
        assert!(rs.is_empty());
        assert!(cache.is_empty());
    }

    #[test]
    fn warm_start_is_transparent_and_reports_extra_hits() {
        let sp = specs(2);
        let cold = run_specs(&sp, 2);
        // seed a second batch from the first batch's merged cache
        let (_, warmed) = run_specs_warm(&sp, 2, EvalCache::new());
        assert!(!warmed.is_empty());
        let (warm, warmed2) = run_specs_warm(&sp, 2, warmed.clone());
        for (c, w) in cold.iter().zip(&warm) {
            // identical trajectory and outcome...
            assert_eq!(c.best_speedup, w.best_speedup);
            assert_eq!(c.best_latency_s, w.best_latency_s);
            assert_eq!(c.curve, w.curve);
            assert_eq!(c.api_cost_usd, w.api_cost_usd);
            assert_eq!(c.n_samples, w.n_samples);
            // ...but the warm run served ground truth from the snapshot
            assert!(w.eval_cache.hits > c.eval_cache.hits, "{:?} vs {:?}", w.eval_cache, c.eval_cache);
            assert!(w.eval_cache.misses < c.eval_cache.misses);
            // per-search lookup volume is unchanged (counters reset on adoption)
            assert_eq!(
                w.eval_cache.hits + w.eval_cache.misses,
                c.eval_cache.hits + c.eval_cache.misses
            );
            // warm runs charge measurement overhead only on real misses
            assert!(w.compile_time_s <= c.compile_time_s);
        }
        // a replayed sweep adds no new ground-truth entries
        assert_eq!(warmed2.len(), warmed.len());
        assert_eq!(warmed2.stats(), CacheStats::default());
    }

    #[test]
    fn run_specs_cached_persists_across_driver_invocations() {
        let path = std::env::temp_dir().join(format!(
            "litecoop_driver_cache_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let sp = specs(2);
        // invocation 1: cold (no file yet), saves the warmed cache
        let first = run_specs_cached(&sp, 2, Some(path.as_str()));
        let saved = EvalCache::load_file(&path).expect("cache file written");
        assert!(!saved.is_empty());
        // invocation 2: loads the file, must report strictly more hits
        // with byte-identical results
        let second = run_specs_cached(&sp, 2, Some(path.as_str()));
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.best_speedup, b.best_speedup);
            assert_eq!(a.curve, b.curve);
            assert!(b.eval_cache.hits > a.eval_cache.hits);
        }
        // None is exactly the plain path
        let plain = run_specs_cached(&sp, 2, None);
        for (a, p) in first.iter().zip(&plain) {
            assert_eq!(a.best_speedup, p.best_speedup);
            assert_eq!(a.eval_cache, p.eval_cache);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn worker_pool_returns_batches_in_index_order_across_rounds() {
        // one pool, many small batches: results always come back in
        // submission-index order, whatever the worker interleaving
        std::thread::scope(|scope| {
            let pool = WorkerPool::spawn(scope, 4, |x: u64| x * 2);
            for round in 0..5u64 {
                let n = 1 + (round as usize) * 7; // varying batch sizes
                for i in 0..n {
                    pool.submit(i, round * 1000 + i as u64);
                }
                let out = pool.collect(n);
                assert_eq!(out.len(), n);
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(*v, (round * 1000 + i as u64) * 2);
                }
            }
            // empty batch is a no-op
            let out: Vec<u64> = pool.collect(0);
            assert!(out.is_empty());
        });
    }

    #[test]
    fn worker_pool_propagates_job_panics_instead_of_hanging() {
        // a panicking job must re-raise on the coordinator, not leave
        // collect() waiting forever for the missing index
        let result = std::panic::catch_unwind(|| {
            std::thread::scope(|scope| {
                let pool = WorkerPool::spawn(scope, 4, |x: u64| {
                    assert!(x != 3, "job blew up");
                    x
                });
                for i in 0..8usize {
                    pool.submit(i, i as u64);
                }
                let _ = pool.collect(8);
            });
        });
        assert!(result.is_err(), "job panic must propagate to the collector");
    }
}

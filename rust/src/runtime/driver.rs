//! Parallel multi-workload search driver.
//!
//! Searches many workloads (or whole experiment matrices of
//! [`RunSpec`]s) concurrently on std scoped threads. Determinism is
//! preserved by construction:
//!
//! * every run is a pure function of its spec — each search derives its
//!   own RNG streams from the spec's seed, and [`lane_seed`] gives each
//!   workload lane an independent deterministic stream regardless of how
//!   the OS schedules threads;
//! * workers pull work by atomic index and write into a per-spec result
//!   slot, so results come back **in spec order**, byte-identical to the
//!   serial path.
//!
//! The experiment harness (`bin/experiments.rs`, via
//! [`crate::coordinator::run_many`]) and the `collab_search` example fan
//! out through this driver, which is how `table3_e2e`-style sweeps scale
//! with cores.

use crate::coordinator::{run_one, RunSpec, Searcher};
use crate::mcts::evalcache::CacheStats;
use crate::mcts::SearchResult;
use crate::sim::Target;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default parallelism: one worker per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Independent deterministic seed for workload lane `lane` under
/// `base_seed` (one [`crate::util::rng::splitmix64`] step from a
/// lane-offset state — streams don't overlap and don't depend on thread
/// scheduling).
pub fn lane_seed(base_seed: u64, lane: u64) -> u64 {
    let mut state = base_seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(lane));
    crate::util::rng::splitmix64(&mut state)
}

/// Run independent jobs across up to `threads` scoped OS threads
/// (work-stealing by atomic index). Results come back in job order; since
/// every job is pure, the output is byte-identical to running the jobs
/// serially.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return jobs.into_iter().map(|f| f()).collect();
    }
    let jobs: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|f| Mutex::new(Some(f))).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let job = jobs[i].lock().unwrap().take().expect("job taken twice");
                *slots[i].lock().unwrap() = Some(job());
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("job missing"))
        .collect()
}

/// Execute a matrix of runs across up to `threads` OS threads. Results are
/// returned in spec order and are byte-identical to running the specs
/// serially.
pub fn run_specs(specs: &[RunSpec], threads: usize) -> Vec<SearchResult> {
    run_jobs(specs.iter().map(|sp| move || run_one(sp)).collect(), threads)
}

/// Search many workloads concurrently with one searcher configuration:
/// workload lane `i` runs under the deterministic seed
/// `lane_seed(base_seed, i)`, and results come back in workload order.
pub fn search_workloads(
    workloads: &[&str],
    target: Target,
    searcher: &Searcher,
    budget: usize,
    base_seed: u64,
    threads: usize,
) -> Vec<SearchResult> {
    let specs: Vec<RunSpec> = workloads
        .iter()
        .enumerate()
        .map(|(i, w)| {
            RunSpec::new(
                w,
                target,
                searcher.clone(),
                budget,
                lane_seed(base_seed, i as u64),
            )
        })
        .collect();
    run_specs(&specs, threads)
}

/// Aggregate eval-cache counters over a driver batch (the owned-slice
/// face of [`crate::coordinator::report::total_cache`]).
pub fn aggregate_cache(results: &[SearchResult]) -> CacheStats {
    crate::coordinator::report::total_cache(&results.iter().collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(n: u64) -> Vec<RunSpec> {
        (0..n)
            .map(|seed| {
                RunSpec::new(
                    "gemm",
                    Target::Cpu,
                    Searcher::Coop {
                        n: 2,
                        largest: "gpt-5.2".into(),
                    },
                    40,
                    seed,
                )
            })
            .collect()
    }

    #[test]
    fn parallel_results_byte_identical_to_serial() {
        let sp = specs(3);
        let par = run_specs(&sp, 3);
        let ser = run_specs(&sp, 1);
        assert_eq!(par.len(), ser.len());
        for (p, s) in par.iter().zip(&ser) {
            assert_eq!(p.workload, s.workload);
            assert_eq!(p.best_speedup, s.best_speedup);
            assert_eq!(p.best_latency_s, s.best_latency_s);
            assert_eq!(p.curve, s.curve);
            assert_eq!(p.api_cost_usd, s.api_cost_usd);
            assert_eq!(p.compile_time_s, s.compile_time_s);
            assert_eq!(p.n_samples, s.n_samples);
            assert_eq!(p.eval_cache, s.eval_cache);
        }
    }

    #[test]
    fn lane_seeds_deterministic_and_distinct() {
        let a: Vec<u64> = (0..16).map(|i| lane_seed(7, i)).collect();
        let b: Vec<u64> = (0..16).map(|i| lane_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), a.len());
        assert_ne!(lane_seed(7, 0), lane_seed(8, 0));
    }

    #[test]
    fn search_workloads_returns_in_workload_order() {
        let searcher = Searcher::Coop {
            n: 2,
            largest: "gpt-5.2".into(),
        };
        let names = ["gemm", "llama4_mlp"];
        let rs = search_workloads(&names, Target::Cpu, &searcher, 30, 5, 2);
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].workload, "gemm");
        assert_eq!(rs[1].workload, "llama4_mlp");
        // same call again is fully deterministic
        let rs2 = search_workloads(&names, Target::Cpu, &searcher, 30, 5, 1);
        for (a, b) in rs.iter().zip(&rs2) {
            assert_eq!(a.best_speedup, b.best_speedup);
            assert_eq!(a.eval_cache, b.eval_cache);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(run_specs(&[], 4).is_empty());
        assert_eq!(aggregate_cache(&[]), CacheStats::default());
    }
}

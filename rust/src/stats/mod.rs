//! Statistical machinery for Appendix E: one-sided matched-block tests on
//! log speedup ratios with Dunnett adjustment for the three planned
//! comparisons (2/4/8-LLM configs) against the shared single-large-model
//! control.

use crate::util::Rng;

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len().max(1) as f64
}

/// Unbiased sample standard deviation.
pub fn sd(xs: &[f64]) -> f64 {
    let m = mean(xs);
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
}

/// Geometric mean (for the paper's aggregated ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.max(1e-12).ln()).sum::<f64>() / xs.len().max(1) as f64).exp()
}

/// Regularized incomplete beta function via continued fraction
/// (Lentz's algorithm) — the workhorse behind the t CDF.
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    let fpmin = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < fpmin {
        d = fpmin;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..200 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = 1.0 + aa / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < fpmin {
            d = fpmin;
        }
        c = 1.0 + aa / c;
        if c.abs() < fpmin {
            c = fpmin;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 3e-14 {
            break;
        }
    }
    h
}

fn ln_gamma(x: f64) -> f64 {
    // Lanczos approximation
    let g = [
        76.18009172947146,
        -86.50532032941677,
        24.01409824083091,
        -1.231739572450155,
        0.1208650973866179e-2,
        -0.5395239384953e-5,
    ];
    let mut y = x;
    let tmp = x + 5.5 - (x + 0.5) * (x + 5.5).ln();
    let mut ser = 1.000000000190015;
    for gi in g {
        y += 1.0;
        ser += gi / y;
    }
    -tmp + (2.5066282746310005 * ser / x).ln()
}

/// Regularized incomplete beta I_x(a, b).
pub fn inc_beta(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let bt = (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln()).exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * betacf(a, b, x) / a
    } else {
        1.0 - bt * betacf(b, a, 1.0 - x) / b
    }
}

/// Student-t CDF with `df` degrees of freedom.
pub fn t_cdf(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    let p = 0.5 * inc_beta(df / 2.0, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    // Abramowitz & Stegun 7.1.26
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Result of one matched-block comparison.
#[derive(Clone, Debug)]
pub struct TestResult {
    /// Geometric-mean speedup ratio (config / control).
    pub ratio: f64,
    /// 95% CI for the ratio (Dunnett-adjusted, one-sided construction
    /// reported as the paper's two-sided-style interval).
    pub ci_low: f64,
    pub ci_high: f64,
    /// Dunnett-adjusted one-sided p-value for ratio > 1.
    pub p_value: f64,
}

/// One-sided matched-block test on log speedup ratios, Dunnett-adjusted
/// for `k` planned comparisons against a shared control.
///
/// `treat[i]` and `control[i]` are speedups from the same block (seed).
/// Dunnett adjustment uses the exact equicorrelated (ρ = 0.5) multivariate
/// structure, evaluated by seeded Monte Carlo (200k draws) — deterministic
/// and accurate to ~3 decimal places, sufficient for the table.
pub fn dunnett_test(treat: &[f64], control: &[f64], k: usize) -> TestResult {
    assert_eq!(treat.len(), control.len());
    let n = treat.len();
    let d: Vec<f64> = treat
        .iter()
        .zip(control)
        .map(|(t, c)| (t / c).max(1e-12).ln())
        .collect();
    let m = mean(&d);
    let s = sd(&d).max(1e-9);
    let se = s / (n as f64).sqrt();
    let t_stat = m / se;
    let df = (n - 1) as f64;

    // raw one-sided p
    let p_raw = 1.0 - t_cdf(t_stat, df);
    // Dunnett step: P(max_j T_j >= t) under the global null with
    // equicorrelation 0.5 — Monte Carlo over the shared-control structure.
    let p_adj = dunnett_p(t_stat, df, k).max(p_raw).min(1.0);

    // Dunnett critical value for the 95% CI
    let crit = dunnett_quantile(0.05, df, k);
    TestResult {
        ratio: m.exp(),
        ci_low: (m - crit * se).exp(),
        ci_high: (m + crit * se).exp(),
        p_value: p_adj,
    }
}

/// Monte-Carlo P(max of k equicorrelated (ρ=0.5) t_df variables >= t).
fn dunnett_p(t: f64, df: f64, k: usize) -> f64 {
    let mut rng = Rng::new(0xD0_E77);
    let n = 200_000;
    let mut count = 0usize;
    for _ in 0..n {
        // chi-square_df via sum of squares (df is small: <= 30 here)
        let dfi = df.round() as usize;
        let mut chi = 0.0;
        for _ in 0..dfi.max(1) {
            let z = rng.normal();
            chi += z * z;
        }
        let scale = (chi / df).sqrt().max(1e-9);
        let z0 = rng.normal(); // shared control component (rho = 0.5)
        let mut max_t = f64::NEG_INFINITY;
        for _ in 0..k {
            let zi = rng.normal();
            let corr = (z0 + zi) / std::f64::consts::SQRT_2;
            max_t = max_t.max(corr / scale);
        }
        if max_t >= t {
            count += 1;
        }
    }
    count as f64 / n as f64
}

/// Dunnett one-sided critical value at level `alpha` (bisection on the
/// Monte-Carlo tail probability).
fn dunnett_quantile(alpha: f64, df: f64, k: usize) -> f64 {
    let (mut lo, mut hi) = (0.0, 8.0);
    for _ in 0..20 {
        let mid = (lo + hi) / 2.0;
        if dunnett_p(mid, df, k) > alpha {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (lo + hi) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn t_cdf_known_values() {
        // t=0 -> 0.5 for any df
        assert!((t_cdf(0.0, 9.0) - 0.5).abs() < 1e-9);
        // large df -> approaches normal: t=1.96, df=1e6 -> ~0.975
        assert!((t_cdf(1.96, 1e6) - 0.975).abs() < 2e-3);
        // t_0.975 for df=9 is 2.262
        assert!((t_cdf(2.262, 9.0) - 0.975).abs() < 2e-3);
    }

    #[test]
    fn norm_cdf_sane() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((norm_cdf(1.6449) - 0.95).abs() < 1e-3);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn dunnett_detects_real_improvement() {
        // treatment consistently ~20% better across 10 blocks
        let control: Vec<f64> = (0..10).map(|i| 10.0 + 0.3 * i as f64).collect();
        let treat: Vec<f64> = control.iter().map(|c| c * 1.2 * (1.0 + 0.01)).collect();
        let r = dunnett_test(&treat, &control, 3);
        assert!(r.p_value < 0.01, "p {}", r.p_value);
        assert!(r.ci_low > 1.0, "ci_low {}", r.ci_low);
        assert!((r.ratio - 1.212).abs() < 0.01);
    }

    #[test]
    fn dunnett_accepts_null() {
        // no real difference + noise
        let mut rng = Rng::new(3);
        let control: Vec<f64> = (0..10).map(|_| 10.0 + rng.normal()).collect();
        let treat: Vec<f64> = control.iter().map(|c| c * (1.0 + 0.002 * rng.normal())).collect();
        let r = dunnett_test(&treat, &control, 3);
        assert!(r.p_value > 0.05, "p {}", r.p_value);
    }

    #[test]
    fn dunnett_adjustment_is_conservative() {
        let control: Vec<f64> = (0..10).map(|i| 10.0 + 0.5 * (i % 3) as f64).collect();
        let treat: Vec<f64> = control.iter().enumerate()
            .map(|(i, c)| c * (1.05 + 0.02 * ((i * 7 % 5) as f64 / 5.0 - 0.4)))
            .collect();
        let r1 = dunnett_test(&treat, &control, 1);
        let r3 = dunnett_test(&treat, &control, 3);
        assert!(r3.p_value >= r1.p_value * 0.99, "{} vs {}", r3.p_value, r1.p_value);
    }
}

//! Gradient-boosted regression trees, from scratch — the XGBoost
//! substitute behind the cost model (DESIGN.md §Substitutions).
//!
//! Squared-error boosting with exact greedy splits on quantile-candidate
//! thresholds, depth-limited trees, shrinkage, and row subsampling. Sized
//! for cost-model workloads: hundreds-to-thousands of rows, ~26 features.
//!
//! # Inference storage: SoA-flattened forest
//!
//! Training builds ordinary per-tree node vectors, but the fitted
//! [`Gbt`] stores the whole forest as four contiguous parallel arrays
//! (`feature` / `threshold` / `left` / `right`, one slot per node across
//! all trees, plus per-tree root offsets). A node visit during
//! prediction touches two `u32`s and one `f64` in arrays that stay
//! resident in cache across rows, instead of chasing 24-byte enum nodes
//! tree by tree — and [`Gbt::predict_batch_into`] walks the rows of a
//! flat [`FeatureMatrix`] in [`Gbt::LANES`]-wide chunks with the tree
//! loop outer, so one tree's nodes are reused across a whole chunk of
//! candidates. Flattening and chunking are pure storage/loop-order
//! transforms: the traversal visits the same nodes and sums tree outputs
//! in the same order, so predictions are bit-identical to the per-tree
//! representation and to scalar [`Gbt::predict`] (asserted in tests).

use super::features::FeatureMatrix;
use crate::util::json::{f64_from_bits_json, f64_to_bits_json, json_bits_f64, json_u32_arr, json_usize};
use crate::util::{Json, Rng};

/// One node of a regression tree during **training** (per-tree vector
/// storage; flattened into the SoA arrays once the forest is fitted).
#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A depth-limited regression tree (training-time representation).
#[derive(Clone, Debug)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// Sentinel in [`Gbt::feature`] marking a leaf node (its value lives in
/// the `threshold` slot).
const LEAF: u32 = u32::MAX;

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct GbtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    pub min_samples_leaf: usize,
    pub subsample: f64,
    /// Number of candidate thresholds per feature.
    pub n_thresholds: usize,
}

impl Default for GbtParams {
    fn default() -> Self {
        // 48 trees / 10 candidate thresholds: within noise of the
        // 60/16 setting on the rank-agreement tests, ~2x cheaper to fit
        // (§Perf iteration 2).
        GbtParams {
            n_trees: 48,
            max_depth: 4,
            learning_rate: 0.18,
            min_samples_leaf: 3,
            subsample: 0.85,
            n_thresholds: 10,
        }
    }
}

/// The boosted ensemble, stored SoA-flattened for inference (see the
/// module docs).
#[derive(Clone, Debug)]
pub struct Gbt {
    pub params: GbtParams,
    base: f64,
    /// Index of each tree's root node in the flat arrays.
    roots: Vec<u32>,
    /// Split feature per node; [`LEAF`] marks a leaf.
    feature: Vec<u32>,
    /// Split threshold per node — or the leaf's value when
    /// `feature[i] == LEAF`.
    threshold: Vec<f64>,
    /// Left / right child indices (valid only for split nodes).
    left: Vec<u32>,
    right: Vec<u32>,
}

impl Gbt {
    /// Fit on rows `x` (each of equal length) with targets `y`.
    pub fn fit(params: GbtParams, x: &[Vec<f64>], y: &[f64], rng: &mut Rng) -> Gbt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let base = y.iter().sum::<f64>() / y.len() as f64;
        let mut residual: Vec<f64> = y.iter().map(|v| v - base).collect();
        let mut trees = Vec::with_capacity(params.n_trees);
        let n = x.len();

        for _ in 0..params.n_trees {
            // row subsample
            let rows: Vec<usize> = (0..n)
                .filter(|_| rng.chance(params.subsample))
                .collect();
            let rows = if rows.len() < params.min_samples_leaf * 2 {
                (0..n).collect()
            } else {
                rows
            };
            let tree = build_tree(&params, x, &residual, &rows, rng);
            for i in 0..n {
                residual[i] -= params.learning_rate * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        Gbt::flatten(params, base, &trees)
    }

    /// Flatten per-tree node vectors into the contiguous SoA arrays.
    /// Node order and child links are preserved verbatim (per-tree index
    /// + tree offset), so traversal visits exactly the nodes the tree
    /// representation would.
    fn flatten(params: GbtParams, base: f64, trees: &[Tree]) -> Gbt {
        let total: usize = trees.iter().map(|t| t.nodes.len()).sum();
        let mut g = Gbt {
            params,
            base,
            roots: Vec::with_capacity(trees.len()),
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
        };
        for t in trees {
            let off = g.feature.len() as u32;
            g.roots.push(off);
            for node in &t.nodes {
                match node {
                    Node::Leaf { value } => {
                        g.feature.push(LEAF);
                        g.threshold.push(*value);
                        g.left.push(0);
                        g.right.push(0);
                    }
                    Node::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => {
                        g.feature.push(*feature as u32);
                        g.threshold.push(*threshold);
                        g.left.push(off + *left as u32);
                        g.right.push(off + *right as u32);
                    }
                }
            }
        }
        g
    }

    /// Walk one tree (by root index) for one row.
    #[inline]
    fn walk(&self, root: u32, x: &[f64]) -> f64 {
        let mut i = root as usize;
        loop {
            let f = self.feature[i];
            let thr = self.threshold[i];
            if f == LEAF {
                return thr;
            }
            // NaN features take the right branch (NaN <= thr is false),
            // matching the tree representation's comparison exactly
            i = if x[f as usize] <= thr {
                self.left[i] as usize
            } else {
                self.right[i] as usize
            };
        }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base
            + self
                .roots
                .iter()
                .map(|&r| self.walk(r, x))
                .sum::<f64>()
                * self.params.learning_rate
    }

    /// Fixed lane width of the chunked batch walk: small enough that a
    /// chunk's accumulators live in registers / one cache line, wide
    /// enough that a tree's SoA node block is reused across several rows
    /// per pass.
    pub const LANES: usize = 8;

    /// Batched prediction over the rows of a flat [`FeatureMatrix`],
    /// appended to `out` (cleared first; allocation-free once `out` has
    /// warmed to the batch size). Bit-identical to mapping
    /// [`Gbt::predict`]: each row accumulates tree outputs from 0.0 in
    /// the same tree order, then applies `base + acc * learning_rate`.
    /// The walk is **chunked**: rows advance in [`Gbt::LANES`]-wide
    /// chunks with the tree loop outer and a branch-light lane loop
    /// inner, so one tree's SoA node block stays cache-resident across
    /// the chunk and the inner loop is auto-vectorization-friendly. This
    /// is the entry point the candidate-scoring path uses
    /// (`Evaluator::score_batch`).
    pub fn predict_batch_into(&self, m: &FeatureMatrix, out: &mut Vec<f64>) {
        out.clear();
        let n = m.n_rows();
        out.reserve(n);
        let mut i = 0;
        while i < n {
            let lanes = Self::LANES.min(n - i);
            let mut acc = [0.0f64; Self::LANES];
            for &r in &self.roots {
                for (l, a) in acc.iter_mut().enumerate().take(lanes) {
                    *a += self.walk(r, m.row(i + l));
                }
            }
            for &a in acc.iter().take(lanes) {
                out.push(self.base + a * self.params.learning_rate);
            }
            i += lanes;
        }
    }

    /// Batched prediction over slice-of-`Vec` rows — compat wrapper over
    /// [`Gbt::predict_batch_into`] (copies the rows into a transient
    /// [`FeatureMatrix`]; the hot path holds a reusable one instead).
    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        let mut m = FeatureMatrix::new();
        m.reset(xs.first().map_or(0, Vec::len));
        for x in xs {
            m.push_row(x);
        }
        let mut out = Vec::new();
        self.predict_batch_into(&m, &mut out);
        out
    }

    /// Serialize the fitted forest verbatim (tree snapshots). Refitting
    /// on load would consume a different RNG stream and diverge, so the
    /// flat SoA arrays are persisted exactly; floats go through the
    /// bits-string form so predictions round-trip bit-for-bit.
    pub fn to_json(&self) -> Json {
        let ints = |v: &[u32]| Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect());
        let mut j = Json::obj();
        j.set("n_trees", self.params.n_trees.into())
            .set("max_depth", self.params.max_depth.into())
            .set("learning_rate", f64_to_bits_json(self.params.learning_rate))
            .set("min_samples_leaf", self.params.min_samples_leaf.into())
            .set("subsample", f64_to_bits_json(self.params.subsample))
            .set("n_thresholds", self.params.n_thresholds.into())
            .set("base", f64_to_bits_json(self.base))
            .set("roots", ints(&self.roots))
            .set("feature", ints(&self.feature))
            .set(
                "threshold",
                Json::Arr(self.threshold.iter().map(|&t| f64_to_bits_json(t)).collect()),
            )
            .set("left", ints(&self.left))
            .set("right", ints(&self.right));
        j
    }

    /// Rebuild a forest from [`Gbt::to_json`] output, validating the
    /// layout so [`Gbt::walk`] can never panic or loop on a corrupt
    /// file: all four node arrays equal length, roots in bounds, split
    /// features below `n_features`, and children strictly forward
    /// (flattening emits children after their parent, so `left/right > i`
    /// also rules out traversal cycles).
    pub fn from_json(v: &Json, n_features: usize) -> Result<Gbt, String> {
        let params = GbtParams {
            n_trees: json_usize(v, "n_trees")?,
            max_depth: json_usize(v, "max_depth")?,
            learning_rate: json_bits_f64(v, "learning_rate")?,
            min_samples_leaf: json_usize(v, "min_samples_leaf")?,
            subsample: json_bits_f64(v, "subsample")?,
            n_thresholds: json_usize(v, "n_thresholds")?,
        };
        let base = json_bits_f64(v, "base")?;
        let roots = json_u32_arr(v, "roots")?;
        let feature = json_u32_arr(v, "feature")?;
        let left = json_u32_arr(v, "left")?;
        let right = json_u32_arr(v, "right")?;
        let threshold: Vec<f64> = v
            .get("threshold")
            .and_then(Json::as_arr)
            .ok_or("gbt threshold: expected array")?
            .iter()
            .map(f64_from_bits_json)
            .collect::<Result<_, _>>()?;
        let n = feature.len();
        if threshold.len() != n || left.len() != n || right.len() != n {
            return Err("gbt: node arrays disagree on length".into());
        }
        if roots.len() != params.n_trees {
            return Err(format!(
                "gbt: {} roots for {} trees",
                roots.len(),
                params.n_trees
            ));
        }
        for &r in &roots {
            if r as usize >= n {
                return Err(format!("gbt: root {r} out of bounds ({n} nodes)"));
            }
        }
        for i in 0..n {
            if feature[i] == LEAF {
                continue;
            }
            if (feature[i] as usize) >= n_features {
                return Err(format!("gbt: node {i} splits on feature {}", feature[i]));
            }
            if (left[i] as usize) >= n || (right[i] as usize) >= n {
                return Err(format!("gbt: node {i} child out of bounds"));
            }
            if (left[i] as usize) <= i || (right[i] as usize) <= i {
                return Err(format!("gbt: node {i} child not strictly forward"));
            }
        }
        Ok(Gbt {
            params,
            base,
            roots,
            feature,
            threshold,
            left,
            right,
        })
    }

    /// Training-set RMSE (diagnostic), via the batched path.
    pub fn rmse(&self, x: &[Vec<f64>], y: &[f64]) -> f64 {
        let se: f64 = self
            .predict_batch(x)
            .iter()
            .zip(y)
            .map(|(p, yi)| {
                let d = p - yi;
                d * d
            })
            .sum();
        (se / x.len() as f64).sqrt()
    }
}

fn build_tree(
    params: &GbtParams,
    x: &[Vec<f64>],
    target: &[f64],
    rows: &[usize],
    rng: &mut Rng,
) -> Tree {
    let mut nodes = Vec::new();
    split_node(params, x, target, rows, 0, &mut nodes, rng);
    Tree { nodes }
}

/// Recursively grow; returns the node index.
fn split_node(
    params: &GbtParams,
    x: &[Vec<f64>],
    target: &[f64],
    rows: &[usize],
    depth: usize,
    nodes: &mut Vec<Node>,
    rng: &mut Rng,
) -> usize {
    let mean = rows.iter().map(|&i| target[i]).sum::<f64>() / rows.len().max(1) as f64;
    if depth >= params.max_depth || rows.len() < params.min_samples_leaf * 2 {
        nodes.push(Node::Leaf { value: mean });
        return nodes.len() - 1;
    }

    let n_features = x[rows[0]].len();
    let total_sum: f64 = rows.iter().map(|&i| target[i]).sum();
    let total_cnt = rows.len() as f64;
    let parent_score = total_sum * total_sum / total_cnt;

    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, gain)
    for f in 0..n_features {
        // candidate thresholds: random quantiles of this feature.
        // total_cmp keeps this panic-free when a feature is NaN (NaNs sort
        // last and the min_samples_leaf guard discards their thresholds).
        let mut vals: Vec<f64> = rows.iter().map(|&i| x[i][f]).collect();
        vals.sort_by(|a, b| a.total_cmp(b));
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        for _ in 0..params.n_thresholds.min(vals.len() - 1) {
            let idx = rng.below(vals.len() - 1);
            let thr = (vals[idx] + vals[idx + 1]) / 2.0;
            let (mut ls, mut lc) = (0.0, 0.0);
            for &i in rows {
                if x[i][f] <= thr {
                    ls += target[i];
                    lc += 1.0;
                }
            }
            let rc = total_cnt - lc;
            if lc < params.min_samples_leaf as f64 || rc < params.min_samples_leaf as f64 {
                continue;
            }
            let rs = total_sum - ls;
            let gain = ls * ls / lc + rs * rs / rc - parent_score;
            if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                best = Some((f, thr, gain));
            }
        }
    }

    match best {
        None => {
            nodes.push(Node::Leaf { value: mean });
            nodes.len() - 1
        }
        Some((f, thr, _)) => {
            let (lrows, rrows): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&i| x[i][f] <= thr);
            let me = nodes.len();
            nodes.push(Node::Leaf { value: mean }); // placeholder
            let left = split_node(params, x, target, &lrows, depth + 1, nodes, rng);
            let right = split_node(params, x, target, &rrows, depth + 1, nodes, rng);
            nodes[me] = Node::Split {
                feature: f,
                threshold: thr,
                left,
                right,
            };
            me
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.f64() * 4.0;
            let b = rng.f64();
            let c = rng.f64();
            // nonlinear target with interaction
            let y = if b > 0.5 { a * 2.0 } else { -a } + c * 0.5;
            xs.push(vec![a, b, c]);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn fits_nonlinear_function() {
        let mut rng = Rng::new(1);
        let (x, y) = synth(600, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let rmse = model.rmse(&x, &y);
        let spread = {
            let m = y.iter().sum::<f64>() / y.len() as f64;
            (y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / y.len() as f64).sqrt()
        };
        assert!(rmse < spread * 0.35, "rmse {rmse} vs spread {spread}");
    }

    #[test]
    fn generalizes_to_held_out() {
        let mut rng = Rng::new(2);
        let (x, y) = synth(800, &mut rng);
        let (xt, yt) = synth(200, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let rmse = model.rmse(&xt, &yt);
        assert!(rmse < 1.0, "held-out rmse {rmse}");
    }

    #[test]
    fn constant_target_gives_constant_prediction() {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y = vec![3.5; 50];
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        assert!((model.predict(&[25.0]) - 3.5).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(200, &mut Rng::new(4));
        let m1 = Gbt::fit(GbtParams::default(), &x, &y, &mut Rng::new(5));
        let m2 = Gbt::fit(GbtParams::default(), &x, &y, &mut Rng::new(5));
        assert_eq!(m1.predict(&x[0]), m2.predict(&x[0]));
    }

    #[test]
    fn predict_batch_bit_identical_to_scalar_predict() {
        // the SoA batched walk must be a pure storage/loop-order change:
        // per-row accumulation happens in the same tree order, so every
        // prediction matches the scalar path bit for bit
        let mut rng = Rng::new(9);
        let (x, y) = synth(400, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let (xt, _) = synth(64, &mut rng);
        let batch = model.predict_batch(&xt);
        assert_eq!(batch.len(), xt.len());
        for (row, b) in xt.iter().zip(&batch) {
            assert_eq!(
                model.predict(row).to_bits(),
                b.to_bits(),
                "batch diverged from scalar on {row:?}"
            );
        }
        // empty batch is fine
        assert!(model.predict_batch(&[]).is_empty());
    }

    #[test]
    fn chunked_batch_bit_identical_on_every_remainder() {
        // the LANES-chunked walk must be exact for every partial final
        // chunk: sweep batch sizes across several chunk boundaries
        // (including 0, 1, LANES-1, LANES, LANES+1, and odd primes)
        let mut rng = Rng::new(11);
        let (x, y) = synth(300, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let (pool, _) = synth(41, &mut rng);
        for n in (0..=20).chain([Gbt::LANES * 3 + 5, 37, 41]) {
            let rows = &pool[..n];
            let batch = model.predict_batch(rows);
            assert_eq!(batch.len(), n);
            for (row, b) in rows.iter().zip(&batch) {
                assert_eq!(
                    model.predict(row).to_bits(),
                    b.to_bits(),
                    "chunked batch diverged from scalar at n={n}"
                );
            }
        }
    }

    #[test]
    fn predict_batch_into_reuses_buffers_and_matches_scalar() {
        let mut rng = Rng::new(12);
        let (x, y) = synth(250, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let (pool, _) = synth(19, &mut rng);
        let mut m = FeatureMatrix::new();
        let mut out = vec![f64::NAN; 3]; // stale contents must be cleared
        // two rounds through the same scratch: the second must not see
        // the first round's rows or predictions
        for round in 0..2 {
            let rows = if round == 0 { &pool[..19] } else { &pool[..7] };
            m.reset(3);
            for r in rows {
                m.push_row(r);
            }
            model.predict_batch_into(&m, &mut out);
            assert_eq!(out.len(), rows.len());
            for (row, b) in rows.iter().zip(&out) {
                assert_eq!(model.predict(row).to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn flattened_forest_has_consistent_layout() {
        let mut rng = Rng::new(10);
        let (x, y) = synth(200, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        assert_eq!(model.roots.len(), model.params.n_trees);
        let n = model.feature.len();
        assert_eq!(model.threshold.len(), n);
        assert_eq!(model.left.len(), n);
        assert_eq!(model.right.len(), n);
        for i in 0..n {
            if model.feature[i] != LEAF {
                assert!((model.feature[i] as usize) < x[0].len());
                assert!((model.left[i] as usize) < n);
                assert!((model.right[i] as usize) < n);
            }
        }
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let mut rng = Rng::new(13);
        let (x, y) = synth(300, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let text = model.to_json().to_string();
        let back = Gbt::from_json(&Json::parse(&text).unwrap(), x[0].len()).unwrap();
        for row in &x[..32] {
            assert_eq!(model.predict(row).to_bits(), back.predict(row).to_bits());
        }
        // corrupt layouts are rejected with an error, never walked
        let mut bad = Json::parse(&text).unwrap();
        bad.set("roots", Json::Arr(vec![Json::Num(1e9)]));
        assert!(Gbt::from_json(&bad, x[0].len()).is_err());
        let mut missing = Json::parse(&text).unwrap();
        if let Json::Obj(m) = &mut missing {
            m.remove("base");
        }
        assert!(Gbt::from_json(&missing, x[0].len()).is_err());
        // a back-edge child (traversal cycle) must fail validation
        let mut cyclic = Json::parse(&text).unwrap();
        let n = model.feature.len();
        cyclic.set(
            "left",
            Json::Arr((0..n).map(|_| Json::Num(0.0)).collect()),
        );
        assert!(Gbt::from_json(&cyclic, x[0].len()).is_err());
    }

    #[test]
    fn nan_features_do_not_panic() {
        // regression: threshold sorting used partial_cmp().unwrap(), which
        // panicked as soon as one row carried a NaN feature
        let mut rng = Rng::new(6);
        let (mut x, y) = synth(120, &mut rng);
        x[3][0] = f64::NAN;
        x[40][2] = f64::NAN;
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        // clean rows still get finite predictions
        assert!(model.predict(&x[0]).is_finite());
        // a NaN query routes through comparisons (NaN <= thr is false)
        // without panicking
        let p = model.predict(&[f64::NAN, 0.5, 0.5]);
        assert!(p.is_finite());
    }
}

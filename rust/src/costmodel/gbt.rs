//! Gradient-boosted regression trees, from scratch — the XGBoost
//! substitute behind the cost model (DESIGN.md §Substitutions).
//!
//! Squared-error boosting with exact greedy splits on quantile-candidate
//! thresholds, depth-limited trees, shrinkage, and row subsampling. Sized
//! for cost-model workloads: hundreds-to-thousands of rows, ~26 features.

use crate::util::Rng;

/// One node of a regression tree (flattened storage).
#[derive(Clone, Debug)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A depth-limited regression tree.
#[derive(Clone, Debug)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// Training hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct GbtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    pub min_samples_leaf: usize,
    pub subsample: f64,
    /// Number of candidate thresholds per feature.
    pub n_thresholds: usize,
}

impl Default for GbtParams {
    fn default() -> Self {
        // 48 trees / 10 candidate thresholds: within noise of the
        // 60/16 setting on the rank-agreement tests, ~2x cheaper to fit
        // (§Perf iteration 2).
        GbtParams {
            n_trees: 48,
            max_depth: 4,
            learning_rate: 0.18,
            min_samples_leaf: 3,
            subsample: 0.85,
            n_thresholds: 10,
        }
    }
}

/// The boosted ensemble.
#[derive(Clone, Debug)]
pub struct Gbt {
    pub params: GbtParams,
    base: f64,
    trees: Vec<Tree>,
}

impl Gbt {
    /// Fit on rows `x` (each of equal length) with targets `y`.
    pub fn fit(params: GbtParams, x: &[Vec<f64>], y: &[f64], rng: &mut Rng) -> Gbt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let base = y.iter().sum::<f64>() / y.len() as f64;
        let mut residual: Vec<f64> = y.iter().map(|v| v - base).collect();
        let mut trees = Vec::with_capacity(params.n_trees);
        let n = x.len();

        for _ in 0..params.n_trees {
            // row subsample
            let rows: Vec<usize> = (0..n)
                .filter(|_| rng.chance(params.subsample))
                .collect();
            let rows = if rows.len() < params.min_samples_leaf * 2 {
                (0..n).collect()
            } else {
                rows
            };
            let tree = build_tree(&params, x, &residual, &rows, rng);
            for i in 0..n {
                residual[i] -= params.learning_rate * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        Gbt { params, base, trees }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        self.base
            + self
                .trees
                .iter()
                .map(|t| t.predict(x))
                .sum::<f64>()
                * self.params.learning_rate
    }

    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Training-set RMSE (diagnostic).
    pub fn rmse(&self, x: &[Vec<f64>], y: &[f64]) -> f64 {
        let se: f64 = x
            .iter()
            .zip(y)
            .map(|(xi, yi)| {
                let d = self.predict(xi) - yi;
                d * d
            })
            .sum();
        (se / x.len() as f64).sqrt()
    }
}

fn build_tree(
    params: &GbtParams,
    x: &[Vec<f64>],
    target: &[f64],
    rows: &[usize],
    rng: &mut Rng,
) -> Tree {
    let mut nodes = Vec::new();
    split_node(params, x, target, rows, 0, &mut nodes, rng);
    Tree { nodes }
}

/// Recursively grow; returns the node index.
fn split_node(
    params: &GbtParams,
    x: &[Vec<f64>],
    target: &[f64],
    rows: &[usize],
    depth: usize,
    nodes: &mut Vec<Node>,
    rng: &mut Rng,
) -> usize {
    let mean = rows.iter().map(|&i| target[i]).sum::<f64>() / rows.len().max(1) as f64;
    if depth >= params.max_depth || rows.len() < params.min_samples_leaf * 2 {
        nodes.push(Node::Leaf { value: mean });
        return nodes.len() - 1;
    }

    let n_features = x[rows[0]].len();
    let total_sum: f64 = rows.iter().map(|&i| target[i]).sum();
    let total_cnt = rows.len() as f64;
    let parent_score = total_sum * total_sum / total_cnt;

    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, gain)
    for f in 0..n_features {
        // candidate thresholds: random quantiles of this feature.
        // total_cmp keeps this panic-free when a feature is NaN (NaNs sort
        // last and the min_samples_leaf guard discards their thresholds).
        let mut vals: Vec<f64> = rows.iter().map(|&i| x[i][f]).collect();
        vals.sort_by(|a, b| a.total_cmp(b));
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        for _ in 0..params.n_thresholds.min(vals.len() - 1) {
            let idx = rng.below(vals.len() - 1);
            let thr = (vals[idx] + vals[idx + 1]) / 2.0;
            let (mut ls, mut lc) = (0.0, 0.0);
            for &i in rows {
                if x[i][f] <= thr {
                    ls += target[i];
                    lc += 1.0;
                }
            }
            let rc = total_cnt - lc;
            if lc < params.min_samples_leaf as f64 || rc < params.min_samples_leaf as f64 {
                continue;
            }
            let rs = total_sum - ls;
            let gain = ls * ls / lc + rs * rs / rc - parent_score;
            if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                best = Some((f, thr, gain));
            }
        }
    }

    match best {
        None => {
            nodes.push(Node::Leaf { value: mean });
            nodes.len() - 1
        }
        Some((f, thr, _)) => {
            let (lrows, rrows): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&i| x[i][f] <= thr);
            let me = nodes.len();
            nodes.push(Node::Leaf { value: mean }); // placeholder
            let left = split_node(params, x, target, &lrows, depth + 1, nodes, rng);
            let right = split_node(params, x, target, &rrows, depth + 1, nodes, rng);
            nodes[me] = Node::Split {
                feature: f,
                threshold: thr,
                left,
                right,
            };
            me
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(n: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.f64() * 4.0;
            let b = rng.f64();
            let c = rng.f64();
            // nonlinear target with interaction
            let y = if b > 0.5 { a * 2.0 } else { -a } + c * 0.5;
            xs.push(vec![a, b, c]);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn fits_nonlinear_function() {
        let mut rng = Rng::new(1);
        let (x, y) = synth(600, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let rmse = model.rmse(&x, &y);
        let spread = {
            let m = y.iter().sum::<f64>() / y.len() as f64;
            (y.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / y.len() as f64).sqrt()
        };
        assert!(rmse < spread * 0.35, "rmse {rmse} vs spread {spread}");
    }

    #[test]
    fn generalizes_to_held_out() {
        let mut rng = Rng::new(2);
        let (x, y) = synth(800, &mut rng);
        let (xt, yt) = synth(200, &mut rng);
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        let rmse = model.rmse(&xt, &yt);
        assert!(rmse < 1.0, "held-out rmse {rmse}");
    }

    #[test]
    fn constant_target_gives_constant_prediction() {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y = vec![3.5; 50];
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        assert!((model.predict(&[25.0]) - 3.5).abs() < 1e-6);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(200, &mut Rng::new(4));
        let m1 = Gbt::fit(GbtParams::default(), &x, &y, &mut Rng::new(5));
        let m2 = Gbt::fit(GbtParams::default(), &x, &y, &mut Rng::new(5));
        assert_eq!(m1.predict(&x[0]), m2.predict(&x[0]));
    }

    #[test]
    fn nan_features_do_not_panic() {
        // regression: threshold sorting used partial_cmp().unwrap(), which
        // panicked as soon as one row carried a NaN feature
        let mut rng = Rng::new(6);
        let (mut x, y) = synth(120, &mut rng);
        x[3][0] = f64::NAN;
        x[40][2] = f64::NAN;
        let model = Gbt::fit(GbtParams::default(), &x, &y, &mut rng);
        // clean rows still get finite predictions
        assert!(model.predict(&x[0]).is_finite());
        // a NaN query routes through comparisons (NaN <= thr is false)
        // without panicking
        let p = model.predict(&[f64::NAN, 0.5, 0.5]);
        assert!(p.is_finite());
    }
}

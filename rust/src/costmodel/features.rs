//! Schedule featurization — the feature-extraction stage of the cost
//! model (the stand-in for TVM's per-buffer-access feature vectors fed to
//! XGBoost).
//!
//! Produces a fixed-length vector per schedule: per-block structural and
//! traffic features, FLOP-weighted across blocks, all magnitudes
//! log-compressed.

use crate::schedule::{LoopKind, Schedule};
use crate::sim::footprint;
use crate::sim::Target;

/// Number of features per schedule.
pub const N_FEATURES: usize = 26;

fn log1p(x: f64) -> f64 {
    (1.0 + x.max(0.0)).ln()
}

/// Extract the feature vector for one block.
fn block_features(s: &Schedule, b: usize, target: Target) -> [f64; N_FEATURES] {
    let blk = &s.workload.blocks[b];
    let bs = &s.blocks[b];
    let gpu = target.is_gpu();
    let nest = s.loop_nest(b, gpu);
    let (l1, l2) = if gpu {
        (32.0 * 1024.0, 5.5 * 1024.0 * 1024.0)
    } else {
        (48.0 * 1024.0, 2.0 * 1024.0 * 1024.0)
    };
    let traffic = footprint::analyze(s, b, &nest, l1, l2);

    let par = nest.parallel_extent() as f64;
    let threads = nest.thread_extent() as f64;
    let lanes = nest.vector_lanes() as f64;
    let unrolled = nest.unrolled_product() as f64;
    let flops = blk.flops();
    let inner_axis = nest.loops.last().map(|l| l.axis);
    let write_contig = inner_axis
        .map(|ax| blk.writes[0].axis_is_contiguous(ax))
        .unwrap_or(false);
    let reads_contig = inner_axis
        .map(|ax| {
            blk.reads
                .iter()
                .filter(|r| r.axis_is_contiguous(ax) || !r.uses_axis(ax))
                .count() as f64
                / blk.reads.len().max(1) as f64
        })
        .unwrap_or(0.0);
    let n_cached_reads = bs.cache_reads.iter().filter(|c| c.is_some()).count() as f64;
    let ai = flops / traffic.dram_bytes.max(1.0); // arithmetic intensity

    [
        log1p(flops),
        log1p(traffic.dram_bytes),
        log1p(traffic.l2_bytes),
        log1p(traffic.inner_tile_bytes),
        log1p(ai),
        log1p(par),
        log1p(threads),
        log1p(lanes),
        log1p(unrolled),
        f64::from(bs.vectorize),
        f64::from(write_contig),
        reads_contig,
        f64::from(bs.cache_write),
        n_cached_reads,
        f64::from(bs.decomposed),
        f64::from(bs.compute_at.is_some()),
        bs.compute_at.map(|d| d as f64).unwrap_or(0.0),
        log1p(nest.loops.len() as f64),
        log1p(nest.loops.iter().map(|l| l.extent as f64).product::<f64>()),
        // innermost serial extent (loop overhead proxy)
        log1p(
            nest.loops
                .iter()
                .rev()
                .find(|l| l.kind == LoopKind::Serial)
                .map(|l| l.extent as f64)
                .unwrap_or(1.0),
        ),
        match blk.body {
            crate::tir::BodyKind::Mac => 1.0,
            crate::tir::BodyKind::Elementwise => 2.0,
            crate::tir::BodyKind::Transcendental => 3.0,
            crate::tir::BodyKind::Reduce => 4.0,
            crate::tir::BodyKind::Copy => 5.0,
        },
        f64::from(blk.has_reduction()),
        log1p(blk.reduction_points() as f64),
        log1p(blk.spatial_points() as f64),
        f64::from(gpu),
        // occupancy-ish proxy: threads per block vs 1024
        (threads / 1024.0).min(1.0),
    ]
}

/// FLOP-weighted aggregate feature vector over all blocks, written into
/// a caller-provided row of length [`N_FEATURES`] — the allocation-free
/// entry the batched scoring path uses (rows live in a reusable
/// [`FeatureMatrix`] scratch instead of one heap `Vec` per candidate).
/// Bit-identical to [`featurize`]: same per-block extraction, same
/// weighted accumulation order.
pub fn featurize_into(s: &Schedule, target: Target, out: &mut [f64]) {
    debug_assert_eq!(out.len(), N_FEATURES);
    out.fill(0.0);
    let total_flops: f64 = s.workload.flops().max(1.0);
    for b in 0..s.workload.blocks.len() {
        let w = s.workload.blocks[b].flops().max(total_flops * 1e-4) / total_flops;
        let f = block_features(s, b, target);
        for (o, x) in out.iter_mut().zip(f.iter()) {
            *o += w * x;
        }
    }
}

/// FLOP-weighted aggregate feature vector over all blocks (allocating
/// convenience wrapper over [`featurize_into`]).
pub fn featurize(s: &Schedule, target: Target) -> Vec<f64> {
    let mut out = vec![0.0; N_FEATURES];
    featurize_into(s, target, &mut out);
    out
}

/// Row-major flat feature matrix: one contiguous `Vec<f64>` of
/// `n_rows × width` values plus the row width. This is the batch-scoring
/// scratch that replaces `&[Vec<f64>]` on the hot path: a lane of
/// candidates is featurized into one reusable buffer
/// ([`FeatureMatrix::push_row_with`] + [`featurize_into`]), so in steady
/// state scoring a round performs **zero per-row heap allocations** —
/// `reset` keeps the allocation and only clears the length.
#[derive(Clone, Debug, Default)]
pub struct FeatureMatrix {
    data: Vec<f64>,
    width: usize,
}

impl FeatureMatrix {
    pub fn new() -> FeatureMatrix {
        FeatureMatrix::default()
    }

    /// Drop all rows and set the row width. The backing allocation is
    /// kept — this is what makes a long-lived scratch allocation-free
    /// after warm-up.
    pub fn reset(&mut self, width: usize) {
        self.data.clear();
        self.width = width;
    }

    /// Row width (features per row).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of complete rows held.
    pub fn n_rows(&self) -> usize {
        if self.width == 0 {
            0
        } else {
            self.data.len() / self.width
        }
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Append one row by copying `row` (length must equal the width).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.width, "row length != matrix width");
        self.data.extend_from_slice(row);
    }

    /// Append one row written in place by `f` (handed a zeroed slice of
    /// the configured width) — the zero-copy entry for
    /// [`featurize_into`]-style writers.
    pub fn push_row_with(&mut self, f: impl FnOnce(&mut [f64])) {
        let start = self.data.len();
        self.data.resize(start + self.width, 0.0);
        f(&mut self.data[start..]);
    }

    /// Borrow row `i`.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    /// Iterate over the rows in order.
    pub fn rows(&self) -> impl Iterator<Item = &[f64]> {
        (0..self.n_rows()).map(|i| self.row(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::schedule::Schedule;
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    #[test]
    fn feature_length_fixed() {
        let s = Schedule::initial(Arc::new(gemm::gemm(64, 64, 64)));
        assert_eq!(featurize(&s, Target::Cpu).len(), N_FEATURES);
        assert_eq!(featurize(&s, Target::Gpu).len(), N_FEATURES);
    }

    #[test]
    fn features_respond_to_transforms() {
        let mut rng = Rng::new(1);
        let s0 = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let f0 = featurize(&s0, Target::Cpu);
        let s1 = apply(&s0, TransformKind::Vectorize, &mut rng, false).unwrap();
        let f1 = featurize(&s1, Target::Cpu);
        assert_ne!(f0, f1);
    }

    #[test]
    fn featurize_into_bit_identical_to_featurize() {
        let mut rng = Rng::new(5);
        let mut s = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let vocab = TransformKind::vocabulary(false);
        let mut row = [1.5; N_FEATURES]; // stale garbage must be overwritten
        for _ in 0..20 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), &mut rng, false) {
                s = n;
            }
            for target in [Target::Cpu, Target::Gpu] {
                let expect = featurize(&s, target);
                featurize_into(&s, target, &mut row);
                for (a, b) in expect.iter().zip(row.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn feature_matrix_layout_and_reuse() {
        let mut m = FeatureMatrix::new();
        assert_eq!(m.n_rows(), 0);
        assert!(m.is_empty());
        m.reset(3);
        m.push_row(&[1.0, 2.0, 3.0]);
        m.push_row_with(|r| {
            assert_eq!(r, &[0.0, 0.0, 0.0]);
            r[1] = 5.0;
        });
        assert_eq!(m.width(), 3);
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[0.0, 5.0, 0.0]);
        let rows: Vec<&[f64]> = m.rows().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], m.row(1));
        // reset clears rows (and may change width) but keeps the buffer
        m.reset(2);
        assert_eq!(m.n_rows(), 0);
        m.push_row(&[7.0, 8.0]);
        assert_eq!(m.row(0), &[7.0, 8.0]);
        // width 0 is inert, not a panic
        m.reset(0);
        assert_eq!(m.n_rows(), 0);
        assert_eq!(m.rows().count(), 0);
    }

    #[test]
    fn features_finite() {
        let mut rng = Rng::new(2);
        let mut s = Schedule::initial(Arc::new(crate::workloads::attention::small_attention(
            128, 4, 32, true,
        )));
        let vocab = TransformKind::vocabulary(true);
        for _ in 0..50 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), &mut rng, true) {
                s = n;
            }
        }
        for target in [Target::Cpu, Target::Gpu] {
            for f in featurize(&s, target) {
                assert!(f.is_finite());
            }
        }
    }
}

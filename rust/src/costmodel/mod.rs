//! The learned cost model: online-trained GBT over schedule features —
//! the drop-in for TVM MetaSchedule's XGBoost cost model (§2.2: "the
//! terminal program produced by the rollout is evaluated using a cost
//! model ... based on XGBoost").
//!
//! Scores are normalized throughput in (0, 1]: `score = min_lat /
//! pred_lat` against the best latency seen so far, which is exactly the
//! "predicted performance score" the paper's prompts show (e.g. 0.0739).

pub mod features;
pub mod gbt;

use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::util::json::{
    f64_from_bits_json, f64_to_bits_json, json_bits_f64, json_u64_str_arr, json_usize,
    u64_str_arr_json,
};
use crate::util::{Json, Rng};
use features::FeatureMatrix;
use gbt::{Gbt, GbtParams};

/// Reusable scratch for the batched scoring path: the flat feature
/// matrix the candidates are featurized into and the prediction output
/// buffer. One instance lives on each evaluator
/// ([`crate::mcts::evalcache::CachedEvaluator`]) and is threaded through
/// [`CostModel::predict_latency_batch_into`], so in steady state a
/// scoring round allocates **no** per-candidate feature rows — both
/// buffers are cleared, not dropped, between rounds.
#[derive(Debug, Default)]
pub struct ScoreScratch {
    pub feats: FeatureMatrix,
    pub preds: Vec<f64>,
}

/// Online cost model: predicts log-latency from schedule features,
/// retrained every `retrain_interval` measured samples.
pub struct CostModel {
    pub target: Target,
    /// Identity nonce unique to this model instance — keys this model's
    /// cached predictions in a shared
    /// [`EvalCache`](crate::mcts::evalcache::EvalCache) so another
    /// model's predictions (even one built from the same seed, whose
    /// training trajectory may differ) are never served in its place.
    pub salt: u64,
    params: GbtParams,
    model: Option<Gbt>,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>, // log-latency
    rng: Rng,
    pub retrain_interval: usize,
    since_train: usize,
    /// Best (lowest) measured latency so far — the score normalizer.
    pub best_latency: f64,
    /// Baseline (unoptimized) latency, for speedup accounting.
    pub baseline_latency: f64,
    pub n_measured: usize,
    pub n_trainings: usize,
}

impl CostModel {
    pub fn new(target: Target, seed: u64) -> CostModel {
        static NEXT_SALT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        CostModel {
            target,
            salt: NEXT_SALT.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            params: GbtParams::default(),
            model: None,
            xs: Vec::new(),
            ys: Vec::new(),
            rng: Rng::new(seed ^ 0xC057_40DE),
            retrain_interval: 16,
            since_train: 0,
            best_latency: f64::INFINITY,
            baseline_latency: f64::NAN,
            n_measured: 0,
            n_trainings: 0,
        }
    }

    /// Record a ground-truth measurement (the simulator run plays the
    /// paper's on-hardware measurement) and maybe retrain.
    pub fn observe(&mut self, s: &Schedule, measured_latency: f64) {
        let x = features::featurize(s, self.target);
        self.xs.push(x);
        self.ys.push(measured_latency.max(1e-12).ln());
        self.n_measured += 1;
        self.since_train += 1;
        if measured_latency < self.best_latency {
            self.best_latency = measured_latency;
        }
        if self.baseline_latency.is_nan() {
            self.baseline_latency = measured_latency;
        }
        if self.model.is_none() && self.xs.len() >= 8
            || self.since_train >= self.retrain_interval
        {
            self.retrain();
        }
    }

    fn retrain(&mut self) {
        if self.xs.len() < 8 {
            return;
        }
        // Sliding training window: unbounded datasets make each retrain
        // O(n · trees · thresholds) and the whole search O(n²). 512 recent
        // measurements keep the model current (recent candidates dominate
        // the region being searched) at bounded cost — §Perf iteration 1.
        const WINDOW: usize = 512;
        let start = self.xs.len().saturating_sub(WINDOW);
        self.model = Some(Gbt::fit(
            self.params,
            &self.xs[start..],
            &self.ys[start..],
            &mut self.rng,
        ));
        self.since_train = 0;
        self.n_trainings += 1;
    }

    /// Predicted latency (seconds). Before any training data exists,
    /// falls back to the latest observation scale (optimistic prior).
    pub fn predict_latency(&self, s: &Schedule) -> f64 {
        match &self.model {
            Some(m) => m.predict(&features::featurize(s, self.target)).exp(),
            None => self
                .ys
                .last()
                .map(|y| y.exp())
                .unwrap_or(1.0),
        }
    }

    /// Batched [`CostModel::predict_latency`] into a reusable
    /// [`ScoreScratch`]: featurizes every schedule into the scratch's
    /// flat [`FeatureMatrix`] ([`features::featurize_into`], no per-row
    /// `Vec`), runs one chunked SoA [`Gbt::predict_batch_into`] pass, and
    /// exponentiates in place — leaving one prediction per input schedule
    /// in `scratch.preds`. Bit-identical to mapping the scalar path (same
    /// featurization, same per-row tree-order accumulation, same `exp`).
    /// Used by `Evaluator::score_batch` on the candidate-scoring path,
    /// where a parallel round scores a whole lane of proposals at once;
    /// with a warmed scratch the whole pass performs zero heap
    /// allocations for feature rows.
    pub fn predict_latency_batch_into(&self, ss: &[&Schedule], scratch: &mut ScoreScratch) {
        match &self.model {
            Some(m) => {
                scratch.feats.reset(features::N_FEATURES);
                for s in ss {
                    scratch
                        .feats
                        .push_row_with(|row| features::featurize_into(s, self.target, row));
                }
                m.predict_batch_into(&scratch.feats, &mut scratch.preds);
                for p in &mut scratch.preds {
                    *p = p.exp();
                }
            }
            None => {
                scratch.preds.clear();
                scratch
                    .preds
                    .extend(ss.iter().map(|s| self.predict_latency(s)));
            }
        }
    }

    /// Batched [`CostModel::predict_latency`] (allocating compat wrapper
    /// over [`CostModel::predict_latency_batch_into`]).
    pub fn predict_latency_batch(&self, ss: &[&Schedule]) -> Vec<f64> {
        let mut scratch = ScoreScratch::default();
        self.predict_latency_batch_into(ss, &mut scratch);
        scratch.preds
    }

    /// Retraining generation, used to key cached predictions: `Some(n)`
    /// once a model is fitted (predictions are pure until the next
    /// retrain), `None` before the first fit (predictions track the latest
    /// observation and must not be cached).
    pub fn generation(&self) -> Option<usize> {
        self.model.as_ref().map(|_| self.n_trainings)
    }

    /// Normalized predicted performance score in (0, 1]: higher = better.
    /// This is the number shown in prompts and used for rewards.
    pub fn score(&self, s: &Schedule) -> f64 {
        self.score_of_prediction(self.predict_latency(s))
    }

    /// Score from an already-computed (possibly cached) predicted latency.
    pub fn score_of_prediction(&self, predicted_latency: f64) -> f64 {
        let pred = predicted_latency.max(1e-12);
        if self.best_latency.is_finite() {
            (self.best_latency / pred).clamp(0.0, 1.0)
        } else {
            0.5
        }
    }

    /// Convenience: measure on the simulator, record, return (latency,
    /// score-after-update).
    pub fn measure(&mut self, sim: &Simulator, s: &Schedule) -> f64 {
        let lat = sim.latency(s);
        self.observe(s, lat);
        lat
    }

    /// Prediction quality on the training set (diagnostic; NaN before fit).
    pub fn train_rmse(&self) -> f64 {
        match &self.model {
            Some(m) => m.rmse(&self.xs, &self.ys),
            None => f64::NAN,
        }
    }

    /// Serialize the full training trajectory (tree snapshots): hyper-
    /// params, the fitted forest verbatim, the observation history
    /// (retrains slide a window over it, so it must survive whole), the
    /// training RNG stream position, and the score normalizers — all
    /// floats in exact bits-string form. `salt` is deliberately NOT
    /// persisted: it is a per-process identity nonce, and
    /// [`CostModel::restore`] draws a fresh one.
    pub fn snapshot(&self) -> Json {
        let row = |r: &[f64]| Json::Arr(r.iter().map(|&x| f64_to_bits_json(x)).collect());
        let mut j = Json::obj();
        j.set("n_trees", self.params.n_trees.into())
            .set("max_depth", self.params.max_depth.into())
            .set("learning_rate", f64_to_bits_json(self.params.learning_rate))
            .set("min_samples_leaf", self.params.min_samples_leaf.into())
            .set("subsample", f64_to_bits_json(self.params.subsample))
            .set("n_thresholds", self.params.n_thresholds.into())
            .set(
                "model",
                match &self.model {
                    Some(m) => m.to_json(),
                    None => Json::Null,
                },
            )
            .set("xs", Json::Arr(self.xs.iter().map(|r| row(r)).collect()))
            .set("ys", row(&self.ys))
            .set("rng", u64_str_arr_json(&self.rng.state()))
            .set("retrain_interval", self.retrain_interval.into())
            .set("since_train", self.since_train.into())
            .set("best_latency", f64_to_bits_json(self.best_latency))
            .set("baseline_latency", f64_to_bits_json(self.baseline_latency))
            .set("n_measured", self.n_measured.into())
            .set("n_trainings", self.n_trainings.into());
        j
    }

    /// Rebuild a model from [`CostModel::snapshot`] output at an exact
    /// training-stream position, under a **fresh** per-process salt.
    /// Validates shapes (feature-row width, xs/ys agreement, forest
    /// layout via [`Gbt::from_json`]) so corrupt input degrades to `Err`,
    /// never a panic.
    pub fn restore(target: Target, v: &Json) -> Result<CostModel, String> {
        let mut cm = CostModel::new(target, 0); // draws the fresh salt
        cm.params = GbtParams {
            n_trees: json_usize(v, "n_trees")?,
            max_depth: json_usize(v, "max_depth")?,
            learning_rate: json_bits_f64(v, "learning_rate")?,
            min_samples_leaf: json_usize(v, "min_samples_leaf")?,
            subsample: json_bits_f64(v, "subsample")?,
            n_thresholds: json_usize(v, "n_thresholds")?,
        };
        cm.model = match v.get("model") {
            Some(Json::Null) => None,
            Some(m) => Some(Gbt::from_json(m, features::N_FEATURES)?),
            None => return Err("missing field \"model\"".into()),
        };
        let xs_arr = v
            .get("xs")
            .and_then(Json::as_arr)
            .ok_or("missing array \"xs\"")?;
        cm.xs = xs_arr
            .iter()
            .map(|r| {
                let row = r.as_arr().ok_or("cost-model xs: non-array row")?;
                if row.len() != features::N_FEATURES {
                    return Err(format!(
                        "cost-model xs: row of {} features (want {})",
                        row.len(),
                        features::N_FEATURES
                    ));
                }
                row.iter().map(f64_from_bits_json).collect()
            })
            .collect::<Result<_, String>>()?;
        cm.ys = v
            .get("ys")
            .and_then(Json::as_arr)
            .ok_or("missing array \"ys\"")?
            .iter()
            .map(f64_from_bits_json)
            .collect::<Result<_, _>>()?;
        if cm.ys.len() != cm.xs.len() {
            return Err(format!(
                "cost-model: {} targets for {} feature rows",
                cm.ys.len(),
                cm.xs.len()
            ));
        }
        if let Some(y) = cm.ys.iter().find(|y| !y.is_finite()) {
            return Err(format!("cost-model: non-finite training target {y}"));
        }
        let rng = json_u64_str_arr(v, "rng")?;
        let rng: [u64; 4] = rng
            .try_into()
            .map_err(|_| "cost-model: rng state is not 4 words".to_string())?;
        cm.rng = Rng::from_state(rng);
        cm.retrain_interval = json_usize(v, "retrain_interval")?;
        cm.since_train = json_usize(v, "since_train")?;
        cm.best_latency = json_bits_f64(v, "best_latency")?;
        cm.baseline_latency = json_bits_f64(v, "baseline_latency")?;
        cm.n_measured = json_usize(v, "n_measured")?;
        cm.n_trainings = json_usize(v, "n_trainings")?;
        Ok(cm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply_sequence, TransformKind};
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn random_variants(n: usize, seed: u64) -> Vec<Schedule> {
        let base = Schedule::initial(Arc::new(gemm::gemm(512, 512, 512)));
        let mut rng = Rng::new(seed);
        let vocab = TransformKind::vocabulary(false);
        let mut out = vec![base.clone()];
        while out.len() < n {
            let seq: Vec<_> = (0..3).map(|_| *rng.choice(&vocab)).collect();
            if let Ok(s) = apply_sequence(&base, &seq, &mut rng, false) {
                out.push(s);
            }
        }
        out
    }

    #[test]
    fn learns_to_rank_schedules() {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, 7);
        let train = random_variants(120, 1);
        for s in &train {
            cm.measure(&sim, s);
        }
        assert!(cm.n_trainings > 0);

        // rank correlation on held-out variants
        let test = random_variants(40, 2);
        let mut pairs: Vec<(f64, f64)> = test
            .iter()
            .map(|s| (cm.predict_latency(s), sim.latency(s)))
            .collect();
        // Spearman-ish: count concordant pairs
        let mut conc = 0;
        let mut total = 0;
        for i in 0..pairs.len() {
            for j in (i + 1)..pairs.len() {
                if (pairs[i].1 - pairs[j].1).abs() < 1e-15 {
                    continue;
                }
                total += 1;
                if (pairs[i].0 < pairs[j].0) == (pairs[i].1 < pairs[j].1) {
                    conc += 1;
                }
            }
        }
        let frac = conc as f64 / total.max(1) as f64;
        assert!(frac > 0.65, "rank agreement only {frac}");
        pairs.sort_by(|a, b| a.1.total_cmp(&b.1));
    }

    #[test]
    fn score_normalized() {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, 8);
        for s in random_variants(40, 3) {
            cm.measure(&sim, &s);
        }
        for s in random_variants(10, 4) {
            let sc = cm.score(&s);
            assert!((0.0..=1.0).contains(&sc), "{sc}");
        }
    }

    #[test]
    fn predict_latency_batch_matches_scalar_bitwise() {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, 11);
        // pre-fit: both paths fall back to the latest-observation prior
        let variants = random_variants(24, 6);
        let refs: Vec<&Schedule> = variants.iter().collect();
        for (s, b) in refs.iter().zip(cm.predict_latency_batch(&refs)) {
            assert_eq!(cm.predict_latency(s).to_bits(), b.to_bits());
        }
        // post-fit: the batched SoA walk must agree bit for bit
        for s in &variants {
            cm.measure(&sim, s);
        }
        assert!(cm.generation().is_some());
        for (s, b) in refs.iter().zip(cm.predict_latency_batch(&refs)) {
            assert_eq!(cm.predict_latency(s).to_bits(), b.to_bits());
        }
        assert!(cm.predict_latency_batch(&[]).is_empty());
    }

    #[test]
    fn predict_latency_batch_into_reuses_scratch_bitwise() {
        // the allocation-free path: one scratch serves rounds of varying
        // size (crossing GBT chunk boundaries) and every prediction stays
        // bit-identical to the scalar path
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, 13);
        let variants = random_variants(20, 7);
        for s in &variants {
            cm.measure(&sim, s);
        }
        assert!(cm.generation().is_some());
        let mut scratch = ScoreScratch::default();
        for round in [20usize, 5, 13, 0, 1] {
            let refs: Vec<&Schedule> = variants.iter().take(round).collect();
            cm.predict_latency_batch_into(&refs, &mut scratch);
            assert_eq!(scratch.preds.len(), refs.len());
            for (s, p) in refs.iter().zip(&scratch.preds) {
                assert_eq!(cm.predict_latency(s).to_bits(), p.to_bits());
            }
        }
    }

    #[test]
    fn snapshot_restore_continues_training_bitwise() {
        let sim = Simulator::new(Target::Cpu);
        let mut a = CostModel::new(Target::Cpu, 21);
        let variants = random_variants(60, 8);
        let (first, rest) = variants.split_at(25);
        for s in first {
            a.measure(&sim, s);
        }
        let snap = a.snapshot();
        let mut b = CostModel::restore(Target::Cpu, &Json::parse(&snap.to_string()).unwrap())
            .expect("restore");
        assert_ne!(a.salt, b.salt, "restore must draw a fresh salt");
        // both models now see the same continuation: predictions, retrain
        // points, and normalizers must stay bit-identical
        for s in rest {
            assert_eq!(a.predict_latency(s).to_bits(), b.predict_latency(s).to_bits());
            a.measure(&sim, s);
            b.measure(&sim, s);
            assert_eq!(a.n_trainings, b.n_trainings);
            assert_eq!(a.best_latency.to_bits(), b.best_latency.to_bits());
        }
        assert_eq!(a.generation(), b.generation());
        // corruption degrades to Err, never a panic
        let mut bad = snap.clone();
        bad.set("ys", Json::Arr(vec![]));
        assert!(CostModel::restore(Target::Cpu, &bad).is_err());
        let mut bad = snap.clone();
        if let Json::Obj(m) = &mut bad {
            m.remove("rng");
        }
        assert!(CostModel::restore(Target::Cpu, &bad).is_err());
    }

    #[test]
    fn best_latency_tracks_minimum() {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, 9);
        let mut min = f64::INFINITY;
        for s in random_variants(30, 5) {
            let l = cm.measure(&sim, &s);
            min = min.min(l);
        }
        assert_eq!(cm.best_latency, min);
    }
}

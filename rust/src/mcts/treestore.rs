//! Versioned persistence for the MCTS search tree.
//!
//! A snapshot captures **everything** the engine needs to continue a
//! search bit-identically: the node arena (schedules delta-encoded
//! against their parents), the engine RNG stream position, the
//! course-alteration and routing state, the incumbent, the measurement
//! queue, the trained cost model (forest weights verbatim — refitting
//! would consume RNG draws and diverge), and the full evaluation cache
//! including prediction entries and hit/miss counters.
//!
//! The resume-equivalence contract: a search snapshotted at sample `k`
//! ([`Mcts::run_until`] / [`Mcts::run_parallel_until`]) and resumed with
//! [`Mcts::resume`] — possibly in another process — then run to its
//! budget `N` reports results bit-identical to an uninterrupted
//! `N`-sample run: same speedup, same incumbent trace hash, same sample
//! and cache counters. `rust/tests/tree_persist.rs` and the
//! `prop_tree_roundtrip_preserves_search` property test enforce it.
//!
//! What is deliberately **not** serialized:
//! * the lazy prompt renderings (`code`, `trace_tail`) — re-rendered on
//!   first prompt use after resume, which draws no randomness and so
//!   cannot perturb the search;
//! * node depths and children lists — recomputed from the parent links;
//! * the cost model's identity salt — a restored model draws a fresh
//!   process-local nonce and its cached predictions are re-keyed under
//!   it (see [`crate::costmodel::CostModel::restore`]).
//!
//! Like the eval-cache store, saves are atomic (write to a pid-suffixed
//! temp file, then rename) and loads degrade: a missing file starts
//! cold silently, a corrupt or version-mismatched file starts cold with
//! a stderr warning — never a panic. [`validate`] re-checks the whole
//! arena on load (parent links acyclic and backward-pointing, model
//! indices in range, statistics finite), so a truncated or hand-edited
//! file is rejected as a clean `Err`, not an index panic deep in the
//! engine.

use super::evalcache::{CachedEvaluator, EvalCache};
use super::{Mcts, Node, Routing, SearchConfig};
use crate::costmodel::{CostModel, ScoreScratch};
use crate::llm::faults::{FaultPlan, FaultRates, FaultReport};
use crate::llm::{CallKind, ModelSet, ModelStats};
use crate::schedule::Schedule;
use crate::sim::Simulator;
use crate::util::json::{
    f64_to_bits_json, json_bits_f64, json_u64_str, json_usize, u64_str_arr_json,
};
use crate::util::{Json, Rng};
use std::sync::{Arc, OnceLock};

/// Bump on any incompatible change to the snapshot layout. Loads of any
/// other version degrade to a cold tree (with a warning), never to a
/// misinterpreted one.
pub const TREE_FORMAT_VERSION: f64 = 1.0;

// ---------------------------------------------------------------------
// small field helpers (local conventions: usizes as JSON numbers, u64s
// as decimal strings, f64 engine state as to_bits strings)
// ---------------------------------------------------------------------

fn opt_usize_json(x: Option<usize>) -> Json {
    match x {
        Some(v) => v.into(),
        None => Json::Null,
    }
}

fn num_usize(v: &Json, what: &str) -> Result<usize, String> {
    match v {
        Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9e15 => Ok(*n as usize),
        _ => Err(format!("tree file: {what} must be a non-negative integer")),
    }
}

fn num_i64(v: &Json, what: &str) -> Result<i64, String> {
    match v {
        Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Ok(*n as i64),
        _ => Err(format!("tree file: {what} must be an integer")),
    }
}

fn json_opt_usize(v: &Json, key: &str) -> Result<Option<usize>, String> {
    match v.get(key) {
        Some(Json::Null) => Ok(None),
        Some(n) => Ok(Some(num_usize(n, key)?)),
        None => Err(format!("tree file: missing field {key}")),
    }
}

fn json_bool(v: &Json, key: &str) -> Result<bool, String> {
    match v.get(key) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(format!("tree file: missing or non-boolean field {key}")),
    }
}

fn usize_arr_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn json_usize_arr(v: &Json, key: &str) -> Result<Vec<usize>, String> {
    v.get(key)
        .and_then(Json::as_arr)
        .ok_or(format!("tree file: missing array field {key}"))?
        .iter()
        .map(|e| num_usize(e, key))
        .collect()
}

// ---------------------------------------------------------------------
// search configuration
// ---------------------------------------------------------------------

fn routing_name(r: Routing) -> &'static str {
    match r {
        Routing::Endogenous => "endogenous",
        Routing::Random => "random",
        Routing::RoundRobin => "round_robin",
    }
}

fn cfg_to_json(cfg: &SearchConfig) -> Json {
    let mut j = Json::obj();
    j.set("lambda", f64_to_bits_json(cfg.lambda))
        .set("exploration_c", f64_to_bits_json(cfg.exploration_c))
        .set("measure_overhead_s", f64_to_bits_json(cfg.measure_overhead_s))
        .set("branching", cfg.branching.into())
        .set("budget", cfg.budget.into())
        .set("rollout_depth", cfg.rollout_depth.into())
        .set("measure_interval", cfg.measure_interval.into())
        .set("measure_top_k", cfg.measure_top_k.into())
        .set("search_threads", cfg.search_threads.into())
        .set("ca_threshold", opt_usize_json(cfg.ca_threshold))
        .set("routing", routing_name(cfg.routing).into())
        .set("seed", Json::Str(cfg.seed.to_string()))
        .set("checkpoints", usize_arr_json(&cfg.checkpoints));
    j
}

fn cfg_from_json(v: &Json) -> Result<SearchConfig, String> {
    let routing = match v.get("routing").and_then(Json::as_str) {
        Some("endogenous") => Routing::Endogenous,
        Some("random") => Routing::Random,
        Some("round_robin") => Routing::RoundRobin,
        other => return Err(format!("tree file: unknown routing policy {other:?}")),
    };
    Ok(SearchConfig {
        lambda: json_bits_f64(v, "lambda")?,
        exploration_c: json_bits_f64(v, "exploration_c")?,
        measure_overhead_s: json_bits_f64(v, "measure_overhead_s")?,
        branching: json_usize(v, "branching")?,
        budget: json_usize(v, "budget")?,
        rollout_depth: json_usize(v, "rollout_depth")?,
        measure_interval: json_usize(v, "measure_interval")?.max(1),
        measure_top_k: json_usize(v, "measure_top_k")?,
        search_threads: json_usize(v, "search_threads")?,
        ca_threshold: json_opt_usize(v, "ca_threshold")?,
        routing,
        seed: json_u64_str(v, "seed")?,
        checkpoints: json_usize_arr(v, "checkpoints")?,
        warm_cache: None,
    })
}

// ---------------------------------------------------------------------
// model accounting
// ---------------------------------------------------------------------

fn models_to_json(models: &ModelSet) -> Json {
    Json::Arr(
        models
            .specs
            .iter()
            .zip(&models.stats)
            .map(|(spec, st)| {
                let mut j = Json::obj();
                j.set("name", spec.name.into())
                    .set("regular_calls", st.regular_calls.into())
                    .set("regular_hits", st.regular_hits.into())
                    .set("ca_calls", st.ca_calls.into())
                    .set("ca_hits", st.ca_hits.into())
                    .set("errors", st.errors.into())
                    .set("total_cost_usd", f64_to_bits_json(st.total_cost_usd))
                    .set("total_latency_s", f64_to_bits_json(st.total_latency_s))
                    .set("tokens_in", f64_to_bits_json(st.tokens_in))
                    .set("tokens_out", f64_to_bits_json(st.tokens_out));
                j
            })
            .collect(),
    )
}

/// Restore per-model accounting into a freshly built model set. The
/// snapshot's spec list must match the caller's exactly (same models in
/// the same order) — a tree saved under one model roster cannot silently
/// continue under another.
fn restore_model_stats(models: &mut ModelSet, v: &Json) -> Result<(), String> {
    let arr = v.as_arr().ok_or("tree file: models must be an array")?;
    if arr.len() != models.specs.len() {
        return Err(format!(
            "tree file: {} models persisted, {} configured",
            arr.len(),
            models.specs.len()
        ));
    }
    for (i, mj) in arr.iter().enumerate() {
        let name = mj
            .get("name")
            .and_then(Json::as_str)
            .ok_or(format!("tree file: model {i}: missing name"))?;
        if name != models.specs[i].name {
            return Err(format!(
                "tree file: model {i} is {name}, configured set has {}",
                models.specs[i].name
            ));
        }
        models.stats[i] = ModelStats {
            regular_calls: json_usize(mj, "regular_calls")?,
            regular_hits: json_usize(mj, "regular_hits")?,
            ca_calls: json_usize(mj, "ca_calls")?,
            ca_hits: json_usize(mj, "ca_hits")?,
            errors: json_usize(mj, "errors")?,
            total_cost_usd: json_bits_f64(mj, "total_cost_usd")?,
            total_latency_s: json_bits_f64(mj, "total_latency_s")?,
            tokens_in: json_bits_f64(mj, "tokens_in")?,
            tokens_out: json_bits_f64(mj, "tokens_out")?,
        };
    }
    Ok(())
}

// ---------------------------------------------------------------------
// fault injection state (optional keys: a zero plan and an empty report
// are omitted entirely, so fault-free snapshots are byte-identical to
// snapshots written before fault injection existed)
// ---------------------------------------------------------------------

fn fault_plan_to_json(p: &FaultPlan) -> Json {
    let mut j = Json::obj();
    j.set(
        "rates",
        Json::Arr(
            p.rates
                .iter()
                .map(|r| {
                    Json::Arr(vec![
                        f64_to_bits_json(r.timeout),
                        f64_to_bits_json(r.rate_limit),
                        f64_to_bits_json(r.transient),
                        f64_to_bits_json(r.malformed),
                    ])
                })
                .collect(),
        ),
    )
    .set("stream", Json::Str(p.stream.to_string()))
    .set("max_retries", p.max_retries.into())
    .set("backoff_base_s", f64_to_bits_json(p.backoff_base_s))
    .set("timeout_s", f64_to_bits_json(p.timeout_s));
    j
}

fn fault_plan_from_json(v: &Json) -> Result<FaultPlan, String> {
    let rates = v
        .get("rates")
        .and_then(Json::as_arr)
        .ok_or("tree file: fault_plan missing rates")?
        .iter()
        .map(|r| {
            let quad = r
                .as_arr()
                .filter(|a| a.len() == 4)
                .ok_or("tree file: fault rates must be 4-element arrays".to_string())?;
            let bit = |j: &Json| {
                crate::util::json::f64_from_bits_json(j)
                    .map_err(|e| format!("tree file: fault rate: {e}"))
            };
            Ok(FaultRates {
                timeout: bit(&quad[0])?,
                rate_limit: bit(&quad[1])?,
                transient: bit(&quad[2])?,
                malformed: bit(&quad[3])?,
            })
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(FaultPlan {
        rates,
        stream: json_u64_str(v, "stream")?,
        max_retries: json_usize(v, "max_retries")?,
        backoff_base_s: json_bits_f64(v, "backoff_base_s")?,
        timeout_s: json_bits_f64(v, "timeout_s")?,
    })
}

fn fault_report_to_json(r: &FaultReport) -> Json {
    let mut j = Json::obj();
    j.set("timeouts", r.timeouts.into())
        .set("rate_limits", r.rate_limits.into())
        .set("transients", r.transients.into())
        .set("malformed", r.malformed.into())
        .set("retries", r.retries.into())
        .set("fallbacks", r.fallbacks.into())
        .set("forced", r.forced.into())
        .set("backoff_latency_s", f64_to_bits_json(r.backoff_latency_s))
        .set("fault_latency_s", f64_to_bits_json(r.fault_latency_s))
        .set("fault_cost_usd", f64_to_bits_json(r.fault_cost_usd));
    j
}

fn fault_report_from_json(v: &Json) -> Result<FaultReport, String> {
    Ok(FaultReport {
        timeouts: json_usize(v, "timeouts")?,
        rate_limits: json_usize(v, "rate_limits")?,
        transients: json_usize(v, "transients")?,
        malformed: json_usize(v, "malformed")?,
        retries: json_usize(v, "retries")?,
        fallbacks: json_usize(v, "fallbacks")?,
        forced: json_usize(v, "forced")?,
        backoff_latency_s: json_bits_f64(v, "backoff_latency_s")?,
        fault_latency_s: json_bits_f64(v, "fault_latency_s")?,
        fault_cost_usd: json_bits_f64(v, "fault_cost_usd")?,
    })
}

// ---------------------------------------------------------------------
// node arena: schedules delta-encoded against the parent
// ---------------------------------------------------------------------

/// Serialize one block's full schedule state (emitted only for blocks
/// that differ from the parent node's schedule).
fn block_to_json(b: usize, blk: &crate::schedule::BlockSched) -> Json {
    let mut j = Json::obj();
    j.set("block", b.into())
        .set(
            "tiles",
            Json::Arr(
                blk.tiles
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&t| Json::Num(t as f64)).collect()))
                    .collect(),
            ),
        )
        .set(
            "order",
            Json::Arr(
                blk.order
                    .iter()
                    .map(|&(a, l)| Json::Arr(vec![Json::Num(a as f64), Json::Num(l as f64)]))
                    .collect(),
            ),
        )
        .set("parallel", blk.parallel.into())
        .set("thread_tiles", blk.thread_tiles.into())
        .set("vectorize", blk.vectorize.into())
        .set("unroll", blk.unroll.into())
        .set("cache_write", blk.cache_write.into())
        .set(
            "cache_reads",
            Json::Arr(blk.cache_reads.iter().map(|&r| opt_usize_json(r)).collect()),
        )
        .set("compute_at", opt_usize_json(blk.compute_at))
        .set("decomposed", blk.decomposed.into());
    j
}

/// Apply one persisted block delta to a schedule under rebuild. Shape is
/// validated against the workload **before** any mutation (axis/read
/// counts are workload invariants), and the mutated block is re-checked
/// by the static structural lint, so a corrupt delta yields `Err` — not
/// a panic inside the simulator.
fn apply_block_delta(sched: &mut Schedule, v: &Json) -> Result<(), String> {
    let b = json_usize(v, "block")?;
    if b >= sched.blocks.len() {
        return Err(format!(
            "tree file: block delta index {b} out of range ({} blocks)",
            sched.blocks.len()
        ));
    }
    let n_axes = sched.blocks[b].tiles.len();
    let n_reads = sched.blocks[b].cache_reads.len();

    let tiles: Vec<Vec<i64>> = v
        .get("tiles")
        .and_then(Json::as_arr)
        .ok_or("tree file: block delta missing tiles")?
        .iter()
        .map(|row| {
            row.as_arr()
                .ok_or("tree file: tiles row must be an array".to_string())?
                .iter()
                .map(|t| num_i64(t, "tile factor"))
                .collect::<Result<Vec<i64>, String>>()
        })
        .collect::<Result<_, _>>()?;
    if tiles.len() != n_axes || tiles.iter().any(|row| row.is_empty()) {
        return Err(format!(
            "tree file: block {b}: tiles shape mismatch ({} axes persisted, {n_axes} in workload)",
            tiles.len()
        ));
    }
    let order: Vec<(usize, usize)> = v
        .get("order")
        .and_then(Json::as_arr)
        .ok_or("tree file: block delta missing order")?
        .iter()
        .map(|p| {
            let pair = p
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or("tree file: order entry must be an [axis, level] pair".to_string())?;
            Ok((num_usize(&pair[0], "order axis")?, num_usize(&pair[1], "order level")?))
        })
        .collect::<Result<_, String>>()?;
    for &(a, l) in &order {
        if a >= tiles.len() || l >= tiles[a].len() {
            return Err(format!("tree file: block {b}: order entry ({a}, {l}) out of range"));
        }
    }
    let cache_reads: Vec<Option<usize>> = v
        .get("cache_reads")
        .and_then(Json::as_arr)
        .ok_or("tree file: block delta missing cache_reads")?
        .iter()
        .map(|e| match e {
            Json::Null => Ok(None),
            n => num_usize(n, "cache_reads depth").map(Some),
        })
        .collect::<Result<_, String>>()?;
    if cache_reads.len() != n_reads {
        return Err(format!(
            "tree file: block {b}: {} cache_reads persisted, {n_reads} reads in workload",
            cache_reads.len()
        ));
    }
    let parallel = json_usize(v, "parallel")?;
    let thread_tiles = json_usize(v, "thread_tiles")?;
    let unroll = json_usize(v, "unroll")?;
    let vectorize = json_bool(v, "vectorize")?;
    let cache_write = json_bool(v, "cache_write")?;
    let decomposed = json_bool(v, "decomposed")?;
    let compute_at = json_opt_usize(v, "compute_at")?;

    let bs = sched.block_mut(b);
    bs.tiles = tiles;
    bs.order = order;
    bs.parallel = parallel;
    bs.thread_tiles = thread_tiles;
    bs.vectorize = vectorize;
    bs.unroll = unroll;
    bs.cache_write = cache_write;
    bs.cache_reads = cache_reads;
    bs.compute_at = compute_at;
    bs.decomposed = decomposed;
    let workload = Arc::clone(&sched.workload);
    sched.blocks[b]
        .validate(&workload, b)
        .map_err(|e| format!("tree file: block {b}: structurally invalid after delta: {e}"))
}

/// Serialize node `i`. The schedule is delta-encoded: the trace steps
/// beyond the parent's trace length (a child's trace always extends its
/// parent's — schedules are built by applying transforms to the parent
/// program), and only the per-block states whose `Arc` differs from the
/// parent's (copy-on-write: untouched blocks share the allocation). The
/// root is delta-encoded against the workload's initial schedule.
fn node_to_json(nodes: &[Node], i: usize, initial: &Schedule) -> Json {
    let n = &nodes[i];
    let mut j = Json::obj();
    j.set("parent", opt_usize_json(n.parent))
        .set("llm", n.llm.into())
        .set("visits", f64_to_bits_json(n.visits))
        .set("reward_sum", f64_to_bits_json(n.reward_sum))
        .set("predicted_score", f64_to_bits_json(n.predicted_score))
        .set(
            "expanded_by",
            match n.expanded_by {
                None => Json::Null,
                Some((m, k)) => Json::Arr(vec![
                    Json::Num(m as f64),
                    Json::Num(match k {
                        CallKind::Regular => 0.0,
                        CallKind::CourseAlteration => 1.0,
                    }),
                ]),
            },
        )
        .set("regression_chain", n.regression_chain.into())
        .set("pruned", n.pruned.into())
        .set("measured", n.measured.into());

    let base_sched: &Schedule = match n.parent {
        Some(p) => &nodes[p].schedule,
        None => initial,
    };
    let base_len = base_sched.trace.len();
    let steps = n.schedule.trace.steps();
    debug_assert!(steps.len() >= base_len, "child trace must extend its parent's");
    j.set(
        "trace_delta",
        Json::Arr(
            steps[base_len..]
                .iter()
                .map(|s| {
                    Json::Arr(vec![
                        s.name.as_ref().into(),
                        s.block.as_ref().into(),
                        s.detail.as_str().into(),
                    ])
                })
                .collect(),
        ),
    );
    let mut blocks = Vec::new();
    for (b, blk) in n.schedule.blocks.iter().enumerate() {
        let changed = match n.parent {
            // CoW: a block untouched since the parent shares its Arc
            Some(p) => !Arc::ptr_eq(blk, &nodes[p].schedule.blocks[b]),
            None => **blk != *initial.blocks[b],
        };
        if changed {
            blocks.push(block_to_json(b, blk));
        }
    }
    j.set("blocks_delta", Json::Arr(blocks));
    j
}

/// Explicit post-load arena check: every structural invariant the engine
/// assumes but never re-checks on its hot paths. Rejecting here turns a
/// corrupt file into a cold-start warning instead of an index panic.
fn validate(nodes: &[Node], n_models: usize) -> Result<(), String> {
    if nodes.is_empty() {
        return Err("tree file: empty node arena".to_string());
    }
    if nodes[0].parent.is_some() {
        return Err("tree file: root node has a parent".to_string());
    }
    for (i, n) in nodes.iter().enumerate() {
        match n.parent {
            None if i > 0 => {
                return Err(format!("tree file: non-root node {i} has no parent"));
            }
            Some(p) if p >= i => {
                return Err(format!(
                    "tree file: node {i} has dangling parent index {p} (must be < {i})"
                ));
            }
            _ => {}
        }
        if n.llm >= n_models {
            return Err(format!(
                "tree file: node {i} assigned to model {} of {n_models}",
                n.llm
            ));
        }
        if let Some((m, _)) = n.expanded_by {
            if m >= n_models {
                return Err(format!(
                    "tree file: node {i} expanded by model {m} of {n_models}"
                ));
            }
        }
        if !n.visits.is_finite() || !n.reward_sum.is_finite() || !n.predicted_score.is_finite() {
            return Err(format!(
                "tree file: node {i} has non-finite statistics (visits {}, reward {}, score {})",
                n.visits, n.reward_sum, n.predicted_score
            ));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------
// the engine snapshot itself
// ---------------------------------------------------------------------

impl Mcts {
    /// Serialize the complete search state to a version-tagged JSON
    /// value. Only valid between samples (never mid-round): tree-parallel
    /// in-flight marks must be clear, which [`Mcts::run_until`] /
    /// [`Mcts::run_parallel_until`] guarantee at their return points.
    pub fn snapshot(&self) -> Json {
        debug_assert!(
            self.nodes
                .iter()
                .all(|n| n.virtual_loss == 0.0 && n.pending_children == 0),
            "snapshot taken mid-round: in-flight marks present"
        );
        let initial = Schedule::initial(Arc::clone(&self.nodes[0].schedule.workload));
        let best_node = self
            .nodes
            .iter()
            .position(|n| Arc::ptr_eq(&n.schedule, &self.best_schedule))
            .unwrap_or(0);
        let mut j = Json::obj();
        j.set("version", TREE_FORMAT_VERSION.into())
            .set("workload", self.nodes[0].schedule.workload.name.as_str().into())
            .set("target", self.eval.sim.target().name().into())
            .set("cfg", cfg_to_json(&self.cfg))
            .set("models", models_to_json(&self.models))
            .set(
                "nodes",
                Json::Arr(
                    (0..self.nodes.len())
                        .map(|i| node_to_json(&self.nodes, i, &initial))
                        .collect(),
                ),
            )
            .set("rng", u64_str_arr_json(&self.rng.state()))
            .set("rr_ptr", self.rr_ptr.into())
            .set("samples", self.samples.into())
            .set("measure_time_s", f64_to_bits_json(self.measure_time_s))
            .set("n_ca_events", self.n_ca_events.into())
            .set("n_errors", self.n_errors.into())
            .set("best_latency", f64_to_bits_json(self.best_latency))
            .set("best_node", best_node.into())
            .set("baseline_latency", f64_to_bits_json(self.baseline_latency))
            .set("unmeasured", usize_arr_json(&self.unmeasured))
            .set(
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|&(s, v)| Json::Arr(vec![Json::Num(s as f64), f64_to_bits_json(v)]))
                        .collect(),
                ),
            )
            .set("checkpoint_cursor", self.checkpoint_cursor.into())
            .set("max_depth", self.max_depth.into())
            .set("round", Json::Str(self.round.to_string()))
            .set(
                "lint_rejects",
                Json::Str(
                    (self.lint_rejects_base
                        + crate::analysis::lint_rejects()
                            .saturating_sub(self.lint_rejects_at_start))
                    .to_string(),
                ),
            )
            .set("cost_model", self.eval.cost.snapshot())
            .set("eval_cache", self.eval.cache.snapshot_full(self.eval.cost.salt));
        // optional keys: omitted when inert, so fault-free snapshots are
        // byte-identical to pre-fault-injection ones
        if !self.models.faults.is_zero() {
            j.set("fault_plan", fault_plan_to_json(&self.models.faults));
        }
        if !self.models.fault_report.is_empty() {
            j.set("fault_report", fault_report_to_json(&self.models.fault_report));
        }
        j
    }

    /// Rebuild a resumable engine from a snapshot. The caller supplies
    /// the process-local pieces a snapshot cannot carry — a fresh model
    /// set (specs validated by name against the persisted roster), the
    /// simulator, and the workload's **initial** schedule (trace must be
    /// empty) — and gets back an engine that continues the persisted
    /// search exactly where it stood. The persisted configuration wins:
    /// the search continues under the config it was started with (the
    /// serve loop then grows the budget per request with
    /// [`Mcts::extend_budget`]).
    pub fn resume(
        v: &Json,
        models: ModelSet,
        sim: Simulator,
        root: Schedule,
    ) -> Result<Mcts, String> {
        let version = v
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("tree file: missing version tag")?;
        if version != TREE_FORMAT_VERSION {
            return Err(format!(
                "tree file: unsupported version {version} (this build reads {TREE_FORMAT_VERSION})"
            ));
        }
        let wname = v
            .get("workload")
            .and_then(Json::as_str)
            .ok_or("tree file: missing workload name")?;
        if wname != root.workload.name {
            return Err(format!(
                "tree file: persisted for workload {wname}, resuming {}",
                root.workload.name
            ));
        }
        let tname = v
            .get("target")
            .and_then(Json::as_str)
            .ok_or("tree file: missing target name")?;
        if tname != sim.target().name() {
            return Err(format!(
                "tree file: persisted for target {tname}, resuming {}",
                sim.target().name()
            ));
        }
        if !root.trace.is_empty() {
            return Err("tree file: resume root must be an initial (untraced) schedule".to_string());
        }
        let cfg = cfg_from_json(v.get("cfg").ok_or("tree file: missing cfg")?)?;
        let mut models = models;
        restore_model_stats(&mut models, v.get("models").ok_or("tree file: missing models")?)?;
        // the persisted fault schedule wins over whatever the caller's
        // fresh model set carries: resume must continue the exact stream
        if let Some(fp) = v.get("fault_plan") {
            models.faults = fault_plan_from_json(fp)?;
        }
        if let Some(fr) = v.get("fault_report") {
            models.fault_report = fault_report_from_json(fr)?;
        }

        // ---- node arena: rebuild schedules parent-first ----------------
        let nodes_json = v
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or("tree file: missing nodes")?;
        let initial = Schedule::initial(Arc::clone(&root.workload));
        let mut nodes: Vec<Node> = Vec::with_capacity(nodes_json.len());
        for (i, nj) in nodes_json.iter().enumerate() {
            let parent = json_opt_usize(nj, "parent").map_err(|e| format!("node {i}: {e}"))?;
            if let Some(p) = parent {
                if p >= i {
                    return Err(format!(
                        "tree file: node {i} has dangling parent index {p} (must be < {i})"
                    ));
                }
            }
            let mut sched = match parent {
                Some(p) => (*nodes[p].schedule).clone(),
                None => initial.clone(),
            };
            let steps = nj
                .get("trace_delta")
                .and_then(Json::as_arr)
                .ok_or(format!("tree file: node {i}: missing trace_delta"))?;
            for step in steps {
                let parts = step
                    .as_arr()
                    .filter(|a| a.len() == 3)
                    .ok_or(format!("tree file: node {i}: malformed trace step"))?;
                match (parts[0].as_str(), parts[1].as_str(), parts[2].as_str()) {
                    (Some(name), Some(block), Some(detail)) => {
                        sched.trace.push(name, block, detail.to_string());
                    }
                    _ => return Err(format!("tree file: node {i}: malformed trace step")),
                }
            }
            let deltas = nj
                .get("blocks_delta")
                .and_then(Json::as_arr)
                .ok_or(format!("tree file: node {i}: missing blocks_delta"))?;
            for bd in deltas {
                apply_block_delta(&mut sched, bd).map_err(|e| format!("node {i}: {e}"))?;
            }
            let expanded_by = match nj.get("expanded_by") {
                Some(Json::Null) => None,
                Some(Json::Arr(a)) if a.len() == 2 => {
                    let m = num_usize(&a[0], "expanded_by model")?;
                    let k = match a[1].as_f64() {
                        Some(x) if x == 0.0 => CallKind::Regular,
                        Some(x) if x == 1.0 => CallKind::CourseAlteration,
                        _ => {
                            return Err(format!("tree file: node {i}: unknown call kind"));
                        }
                    };
                    Some((m, k))
                }
                _ => return Err(format!("tree file: node {i}: malformed expanded_by")),
            };
            let depth = parent.map_or(0, |p| nodes[p].depth + 1);
            nodes.push(Node {
                parent,
                children: Vec::new(),
                schedule: Arc::new(sched),
                code: OnceLock::new(),
                trace_tail: OnceLock::new(),
                llm: json_usize(nj, "llm").map_err(|e| format!("node {i}: {e}"))?,
                visits: json_bits_f64(nj, "visits").map_err(|e| format!("node {i}: {e}"))?,
                reward_sum: json_bits_f64(nj, "reward_sum")
                    .map_err(|e| format!("node {i}: {e}"))?,
                predicted_score: json_bits_f64(nj, "predicted_score")
                    .map_err(|e| format!("node {i}: {e}"))?,
                expanded_by,
                depth,
                regression_chain: json_usize(nj, "regression_chain")
                    .map_err(|e| format!("node {i}: {e}"))?,
                pruned: json_bool(nj, "pruned").map_err(|e| format!("node {i}: {e}"))?,
                measured: json_bool(nj, "measured").map_err(|e| format!("node {i}: {e}"))?,
                virtual_loss: 0.0,
                pending_children: 0,
            });
        }
        validate(&nodes, models.len())?;
        // children rebuild from parent links: insertion allocates node
        // indices in order and appends to the parent's list at the same
        // moment, so index order IS the historical child order
        for i in 1..nodes.len() {
            let p = nodes[i].parent.expect("validated above");
            nodes[p].children.push(i);
        }

        // ---- scalar engine state ---------------------------------------
        let rng_state: [u64; 4] = crate::util::json::json_u64_str_arr(v, "rng")?
            .try_into()
            .map_err(|_| "tree file: rng state must be exactly 4 words".to_string())?;
        let samples = json_usize(v, "samples")?;
        let best_node = json_usize(v, "best_node")?;
        if best_node >= nodes.len() {
            return Err(format!(
                "tree file: best_node {best_node} out of range ({} nodes)",
                nodes.len()
            ));
        }
        let unmeasured = json_usize_arr(v, "unmeasured")?;
        if let Some(&bad) = unmeasured.iter().find(|&&u| u >= nodes.len()) {
            return Err(format!(
                "tree file: unmeasured index {bad} out of range ({} nodes)",
                nodes.len()
            ));
        }
        let curve: Vec<(usize, f64)> = v
            .get("curve")
            .and_then(Json::as_arr)
            .ok_or("tree file: missing curve")?
            .iter()
            .map(|p| {
                let pair = p
                    .as_arr()
                    .filter(|a| a.len() == 2)
                    .ok_or("tree file: malformed curve point".to_string())?;
                Ok((
                    num_usize(&pair[0], "curve samples")?,
                    crate::util::json::f64_from_bits_json(&pair[1])
                        .map_err(|e| format!("curve point: {e}"))?,
                ))
            })
            .collect::<Result<_, String>>()?;
        let mut checkpoints_sorted = cfg.checkpoints.clone();
        checkpoints_sorted.sort_unstable();
        checkpoints_sorted.dedup();
        let checkpoint_cursor = json_usize(v, "checkpoint_cursor")?;
        if checkpoint_cursor > checkpoints_sorted.len() {
            return Err(format!(
                "tree file: checkpoint cursor {checkpoint_cursor} past {} checkpoints",
                checkpoints_sorted.len()
            ));
        }
        let best_latency = json_bits_f64(v, "best_latency")?;
        let baseline_latency = json_bits_f64(v, "baseline_latency")?;
        if !best_latency.is_finite() || !baseline_latency.is_finite() {
            return Err("tree file: non-finite incumbent/baseline latency".to_string());
        }

        let cost = CostModel::restore(
            sim.target(),
            v.get("cost_model").ok_or("tree file: missing cost_model")?,
        )?;
        let cache = EvalCache::restore_full(
            v.get("eval_cache").ok_or("tree file: missing eval_cache")?,
            cost.salt,
        )?;
        let best_schedule = Arc::clone(&nodes[best_node].schedule);
        Ok(Mcts {
            cfg,
            models,
            eval: CachedEvaluator {
                cost,
                sim,
                cache,
                scratch: ScoreScratch::default(),
            },
            nodes,
            rng: Rng::from_state(rng_state),
            rr_ptr: json_usize(v, "rr_ptr")?,
            samples,
            measure_time_s: json_bits_f64(v, "measure_time_s")?,
            n_ca_events: json_usize(v, "n_ca_events")?,
            n_errors: json_usize(v, "n_errors")?,
            best_latency,
            best_schedule,
            baseline_latency,
            unmeasured,
            curve,
            max_depth: json_usize(v, "max_depth")?.max(1),
            checkpoints_sorted,
            checkpoint_cursor,
            sel_children: Vec::new(),
            sel_stats: Vec::new(),
            sel_path: Vec::new(),
            lint_rejects_at_start: crate::analysis::lint_rejects(),
            lint_rejects_base: json_u64_str(v, "lint_rejects")?,
            round: json_u64_str(v, "round")?,
        })
    }

    /// Lint every schedule in the tree through the static legality
    /// analyzer, returning the first Deny-level diagnostic (as `(node
    /// index, rendered diagnostic)`) or `None` when the whole tree is
    /// clean. Every node a search inserts passes the apply-time Deny
    /// gate, so a live tree is clean by construction — this is the
    /// trust-but-verify check for trees rebuilt from disk, where a
    /// hand-edited or subtly corrupt file could smuggle in a schedule
    /// the gate never saw.
    pub fn first_tree_deny(&self) -> Option<(usize, String)> {
        let gpu = self.eval.sim.target().is_gpu();
        self.nodes.iter().enumerate().find_map(|(i, n)| {
            crate::analysis::first_deny(&n.schedule, gpu).map(|d| (i, d.to_string()))
        })
    }

    /// Atomic snapshot-to-disk: write to a pid-suffixed temp file in the
    /// same directory, then rename over the target — a crash mid-write
    /// leaves the previous snapshot intact, and a reader never sees a
    /// half-written file.
    pub fn save_file(&self, path: &str) -> Result<(), String> {
        let tmp = format!("{path}.tmp.{}", std::process::id());
        std::fs::write(&tmp, format!("{}\n", self.snapshot()))
            .map_err(|e| format!("{tmp}: {e}"))?;
        std::fs::rename(&tmp, path).map_err(|e| format!("{path}: {e}"))
    }

    /// Strict load: parse + [`Mcts::resume`], errors surfaced.
    pub fn load_file(
        path: &str,
        models: ModelSet,
        sim: Simulator,
        root: Schedule,
    ) -> Result<Mcts, String> {
        Mcts::resume(&Json::parse_file(path)?, models, sim, root)
    }

    /// Degrading load for long-lived serve loops: a missing file starts a
    /// cold search silently (the normal first-request path); an
    /// unreadable, corrupt, or version-mismatched file starts cold with a
    /// stderr warning — persistence failures must never take the daemon
    /// down. Returns whether a persisted tree was actually resumed.
    pub fn resume_file_or_cold(
        path: &str,
        cfg: SearchConfig,
        models: ModelSet,
        sim: Simulator,
        root: Schedule,
    ) -> (Mcts, bool) {
        if !std::path::Path::new(path).exists() {
            return (Mcts::new(cfg, models, sim, root), false);
        }
        match Mcts::load_file(path, models.clone(), sim.clone(), root.clone()) {
            Ok(engine) => (engine, true),
            Err(e) => {
                eprintln!("warning: tree file {e}; starting cold");
                (Mcts::new(cfg, models, sim, root), false)
            }
        }
    }
}

//! Shared evaluation cache + the [`Evaluator`] abstraction the search
//! engine talks to.
//!
//! COLT's shared tree lets many LLMs extend each other's transformation
//! prefixes — but that only pays off at the systems level if re-visiting a
//! prefix is cheap. This module makes prefix reuse real: a
//! transposition-style cache keyed by a canonical hash of the schedule's
//! transform trace (computed in **O(1) per lookup** from the trace's
//! incrementally maintained running hash and the schedule's cached
//! structural fingerprint — see [`trace_key`]) memoizes every
//! ground-truth simulator evaluation
//! (shared across everything, including repeated searches over one
//! cache) and every cost-model prediction (keyed per model instance and
//! retraining generation — shared within a search, never leaked between
//! different models' training trajectories). Identical candidate
//! programs — re-proposed by different LLMs, re-scored during
//! course-alteration re-expansion, or re-searched across repeated runs —
//! are evaluated exactly once.
//!
//! # The `Evaluator` trait
//!
//! [`Evaluator`] is the single surface through which the MCTS engine
//! ([`crate::mcts::Mcts`]) reaches the cost model and the hardware
//! simulator:
//!
//! * [`Evaluator::measure`] — ground-truth evaluation that also trains the
//!   learned cost model and advances the incumbent (the paper's
//!   on-hardware measurement step),
//! * [`Evaluator::true_latency`] — ground-truth latency *without*
//!   training (the oracle blended into expansion scoring),
//! * [`Evaluator::score`] — the normalized predicted performance score
//!   from the learned cost model.
//!
//! [`CachedEvaluator`] is the production implementation: a
//! [`CostModel`] + [`Simulator`] pair fronted by an [`EvalCache`]. All
//! cached values are pure functions of their key (the simulator is
//! deterministic; predictions are memoized per retraining generation and
//! per cost-model identity), so enabling the cache never changes a search
//! result — it only removes redundant evaluation work.
//!
//! # Cache knobs
//!
//! * capacity — [`EvalCache::with_capacity`] bounds the number of entries
//!   per map (default [`EvalCache::DEFAULT_CAPACITY`]); once full, new
//!   values are still computed and returned but not inserted.
//! * sharing — an [`EvalCache`] can be built externally and passed to
//!   [`crate::mcts::Mcts::with_cache`] to persist ground-truth hits
//!   across repeated searches of the same workload; retrieve the warm
//!   cache afterwards from [`crate::mcts::Mcts::run_with_cache`].
//! * counters — [`CacheStats`] hit/miss counters are surfaced in
//!   [`crate::mcts::SearchResult::eval_cache`] and aggregated by the
//!   parallel driver ([`crate::runtime::driver`]).

use crate::costmodel::{CostModel, ScoreScratch};
use crate::schedule::trace::{fnv_str, fnv_u64};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Hit/miss counters for one cache (or an aggregate over many).
#[must_use]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache. A counter that was never
    /// consulted (zero hits *and* zero misses — e.g. the merge of an empty
    /// driver batch) reports 0.0, never NaN.
    #[must_use]
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fold another counter into this one (driver-level aggregation).
    /// `hit_rate` on the merged counter divides by the combined lookup
    /// count, and stays 0.0 when both sides were empty.
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

/// Canonical 64-bit key of a scheduled program on a target.
///
/// Mixes the trace's **cached running hash** (which already folds in every
/// transform-trace step: name, block, and the sampled decision string —
/// the trace records every decision, so it replays to exactly one
/// program), the workload identity, the target, and the schedule's
/// **lazily cached** structural fingerprint (which disambiguates the rare
/// trace renderings that don't pin the structure, e.g. two reads of the
/// same buffer).
///
/// # O(1) contract
///
/// This function is O(1) in trace depth and (amortized) in program size:
/// the per-step hashing happened incrementally at
/// [`Trace::push_step`](crate::schedule::trace::Trace::push_step) time and
/// the fingerprint is computed at most once per schedule instance
/// ([`Schedule::fingerprint`]), so a lookup touches two cached u64s plus
/// the workload and target names. Nothing here iterates over trace steps
/// — keep it that way: the search performs several key computations per
/// MCTS iteration, and O(depth) keys make aggregate work along a path
/// quadratic.
pub fn trace_key(s: &Schedule, target: Target) -> u64 {
    let mut h = s.trace.running_hash();
    h = fnv_str(h, &s.workload.name);
    h = fnv_str(h, target.name());
    fnv_u64(h, s.fingerprint())
}

/// Key of one cost-model prediction: program key + cost-model identity
/// (its seed salt) + retraining generation. Predictions are pure between
/// retrains, so this triple fully determines the value.
pub type PredKey = (u64, u64, usize);

/// Bounded transposition cache over ground-truth latencies and cost-model
/// predictions. See the module docs for the soundness argument and knobs.
///
/// # Nonce invariant (predictions vs. ground truth)
///
/// Ground-truth latency entries are pure functions of their trace key
/// and may be shared across searches, threads, and **processes** (they
/// are what [`EvalCache::to_json`] persists for `--cache-file` warm
/// starts). Prediction entries are NOT: their key embeds the owning
/// cost model's identity nonce ([`CostModel::salt`]), which is drawn
/// from a **per-process** atomic counter — a salt from one process can
/// collide with an unrelated model's salt in another process, so a
/// prediction entry is only meaningful inside the process that created
/// it. Two mechanisms enforce this:
///
/// * within a process, [`EvalCache::retain_predictions_of`] prunes
///   other models' (unreachable) entries when a shared cache is adopted
///   by a new search;
/// * across processes, the load path drops predictions explicitly:
///   [`EvalCache::to_json`] never serializes them and
///   [`EvalCache::from_json`] starts with an empty prediction map
///   regardless of input — relying on post-load pruning would be
///   pointless, since a colliding foreign salt would survive it.
#[derive(Clone, Debug)]
pub struct EvalCache {
    lat: HashMap<u64, f64>,
    pred: HashMap<PredKey, f64>,
    stats: CacheStats,
    max_entries: usize,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl EvalCache {
    /// Default per-map entry bound: generous for multi-thousand-sample
    /// searches, small next to the tree itself. An entry is a u64 (or
    /// `PredKey` triple) key plus an f64 value — roughly 16–32 B of
    /// payload, which `HashMap`'s open-addressing table grows to ~1.5–2×
    /// with control bytes and load-factor slack — so a full latency map
    /// at this bound costs on the order of 10 MB, not the "~16 B/entry"
    /// naive figure.
    pub const DEFAULT_CAPACITY: usize = 1 << 18;

    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Cache with an explicit per-map entry bound. Once a map is full, new
    /// values are computed and returned but not inserted.
    pub fn with_capacity(max_entries: usize) -> EvalCache {
        EvalCache {
            lat: HashMap::new(),
            pred: HashMap::new(),
            stats: CacheStats::default(),
            max_entries,
        }
    }

    /// Total entries currently held (both maps).
    pub fn len(&self) -> usize {
        self.lat.len() + self.pred.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lat.is_empty() && self.pred.is_empty()
    }

    #[must_use]
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset the hit/miss counters (entries are kept) — used when one
    /// shared cache serves several searches that each report their own
    /// stats.
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Drop prediction entries not belonging to the cost model with the
    /// given identity `salt`. Prediction keys are per model instance, so
    /// when a shared cache is adopted by a new search, prior searches'
    /// entries are unreachable — pruning them keeps the map from filling
    /// up with dead entries (which would eventually block inserts).
    ///
    /// This is the *within-process* half of the nonce invariant (see the
    /// type docs); deserialized caches never contain predictions in the
    /// first place, by construction of [`EvalCache::from_json`].
    pub fn retain_predictions_of(&mut self, salt: u64) {
        self.pred.retain(|k, _| k.1 == salt);
    }

    /// Configured per-map entry bound (see [`EvalCache::with_capacity`]).
    pub fn capacity(&self) -> usize {
        self.max_entries
    }

    /// Union `other`'s ground-truth entries into this cache (the
    /// driver-side merge after a warm sweep). Values are pure functions
    /// of their keys, so colliding inserts agree; this cache's entry
    /// bound is respected (*which* surplus entries are dropped when the
    /// bound bites is unspecified — cache contents never affect search
    /// results, only hit rates). Prediction entries and counters are not
    /// merged (per-model / per-search by design).
    pub fn absorb(&mut self, other: EvalCache) {
        for (k, v) in other.lat {
            if self.lat.len() >= self.max_entries && !self.lat.contains_key(&k) {
                continue;
            }
            self.lat.insert(k, v);
        }
    }

    /// [`EvalCache::absorb`] plus counter federation: the other cache's
    /// hit/miss totals are summed into this one's before its ground-truth
    /// entries are unioned in. This is the root-parallel lane merge
    /// ([`crate::mcts::treemerge`]): the merged tree's cache must report
    /// the fleet's cumulative lookup counters, not one lane's. Prediction
    /// entries still follow the absorb rule (dropped — they are keyed per
    /// cost-model instance and only the surviving model's are valid).
    pub fn federate(&mut self, other: EvalCache) {
        self.stats.merge(&other.stats);
        self.absorb(other);
    }

    /// Ground-truth latency for `key`, computing (and caching) via `f` on
    /// a miss.
    pub fn latency_or(&mut self, key: u64, f: impl FnOnce() -> f64) -> f64 {
        self.latency_or_served(key, f).0
    }

    /// Like [`EvalCache::latency_or`], but also reports whether the value
    /// was served from the cache (`true` = hit, `f` never ran). This is
    /// the authoritative hit signal for callers that account for the cost
    /// of running `f` — it is returned from the lookup itself rather than
    /// inferred from counter deltas, so it stays correct no matter how
    /// many other cache interactions surround the call.
    pub fn latency_or_served(&mut self, key: u64, f: impl FnOnce() -> f64) -> (f64, bool) {
        if let Some(&v) = self.lat.get(&key) {
            self.stats.hits += 1;
            return (v, true);
        }
        self.stats.misses += 1;
        let v = f();
        if self.lat.len() < self.max_entries {
            self.lat.insert(key, v);
        }
        (v, false)
    }

    /// Cost-model predicted latency for `key`, computing (and caching) via
    /// `f` on a miss.
    pub fn prediction_or(&mut self, key: PredKey, f: impl FnOnce() -> f64) -> f64 {
        if let Some(&v) = self.pred.get(&key) {
            self.stats.hits += 1;
            return v;
        }
        self.stats.misses += 1;
        let v = f();
        if self.pred.len() < self.max_entries {
            self.pred.insert(key, v);
        }
        v
    }
}

/// The prediction-map face the batched scoring path
/// (`Evaluator::score_batch`) talks to, implemented by both the serial
/// [`EvalCache`] and the concurrent [`SharedEvalCache`]. The three
/// operations decompose [`EvalCache::prediction_or`] so a batch can peek
/// all keys first (uncounted), run one SoA `predict_batch` over the
/// misses, and then charge hits/misses **in item order** — keeping the
/// counters byte-identical to looping `prediction_or` per item.
pub trait PredStore {
    /// Uncounted lookup (the batch's planning pass).
    fn pred_peek(&self, key: PredKey) -> Option<f64>;
    /// Charge one hit for `key` (the batch's charging pass, for items the
    /// planning pass — or an earlier item of this batch — found present).
    fn pred_charge_hit(&mut self, key: PredKey);
    /// Charge one miss for `key` and insert `v` (skipped when the map is
    /// at capacity, exactly like [`EvalCache::prediction_or`]'s miss arm).
    fn pred_charge_miss_insert(&mut self, key: PredKey, v: f64);
}

impl PredStore for EvalCache {
    fn pred_peek(&self, key: PredKey) -> Option<f64> {
        self.pred.get(&key).copied()
    }
    fn pred_charge_hit(&mut self, _key: PredKey) {
        self.stats.hits += 1;
    }
    fn pred_charge_miss_insert(&mut self, key: PredKey, v: f64) {
        self.stats.misses += 1;
        if self.pred.len() < self.max_entries {
            self.pred.insert(key, v);
        }
    }
}

// ------------------------------------------------------------------------
// Persistence (warm start across processes)
// ------------------------------------------------------------------------

/// Cache-file format version. Bump whenever the [`trace_key`] formula
/// changes (v1 → v2: the schedule fingerprint became a fold of per-block
/// fingerprints when block-level memoization landed), so a file of keys
/// computed under an old formula is rejected (and
/// [`EvalCache::load_file_or_cold`] degrades to a cold start) instead of
/// sitting in the map as unreachable-at-best entries.
pub const CACHE_FORMAT_VERSION: f64 = 2.0;

impl EvalCache {
    /// Serialize for cross-process warm start: the ground-truth latency
    /// map (keys as decimal strings — u64 keys don't survive JSON's f64
    /// numbers) plus the configured entry bound, under a format version
    /// ([`CACHE_FORMAT_VERSION`], tied to the [`trace_key`] formula).
    /// Prediction entries are deliberately omitted (the nonce invariant,
    /// see the type docs) and counters are not persisted (stats are
    /// per-search, zeroed on load). Latency values round-trip exactly:
    /// the writer emits Rust's shortest-round-trip `f64` rendering.
    /// Non-finite values — which valid simulator output never produces —
    /// are skipped, since JSON cannot represent them.
    pub fn to_json(&self) -> Json {
        let mut lat = Json::obj();
        for (k, v) in &self.lat {
            if v.is_finite() {
                lat.set(&k.to_string(), (*v).into());
            }
        }
        let mut root = Json::obj();
        root.set("version", CACHE_FORMAT_VERSION.into())
            .set("max_entries", self.max_entries.to_string().into())
            .set("lat", lat);
        root
    }

    /// Inverse of [`EvalCache::to_json`]. The loaded cache starts with
    /// zeroed counters and an **empty prediction map** — any `pred` key
    /// in the input is ignored by design (the load path drops
    /// predictions explicitly rather than trusting
    /// [`EvalCache::retain_predictions_of`] to prune foreign-process
    /// salts, which could collide with a live one).
    pub fn from_json(j: &Json) -> Result<EvalCache, String> {
        let version = j
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("cache file: missing version")?;
        if version != CACHE_FORMAT_VERSION {
            return Err(format!("cache file: unsupported version {version}"));
        }
        let max_entries: usize = j
            .get("max_entries")
            .and_then(Json::as_str)
            .ok_or("cache file: missing max_entries")?
            .parse()
            .map_err(|_| "cache file: bad max_entries".to_string())?;
        let lat_obj = j
            .get("lat")
            .and_then(Json::as_obj)
            .ok_or("cache file: missing lat map")?;
        let mut lat = HashMap::with_capacity(lat_obj.len());
        for (k, v) in lat_obj {
            let key: u64 = k
                .parse()
                .map_err(|_| format!("cache file: bad entry key {k:?}"))?;
            let val = v
                .as_f64()
                .filter(|x| x.is_finite())
                .ok_or_else(|| format!("cache file: bad latency for key {k}"))?;
            lat.insert(key, val);
        }
        Ok(EvalCache {
            lat,
            pred: HashMap::new(),
            stats: CacheStats::default(),
            max_entries,
        })
    }

    /// Atomically write the serialized cache to `path` (temp file in the
    /// same directory + rename, so a concurrent loader never observes a
    /// torn file).
    pub fn save_file(&self, path: &str) -> Result<(), String> {
        let tmp = format!("{path}.tmp.{}", std::process::id());
        std::fs::write(&tmp, format!("{}\n", self.to_json())).map_err(|e| format!("{tmp}: {e}"))?;
        std::fs::rename(&tmp, path).map_err(|e| format!("{path}: {e}"))
    }

    /// Load a cache saved by [`EvalCache::save_file`]. Also sweeps any
    /// orphaned `<path>.tmp.<pid>` siblings a crashed writer left behind
    /// (warned per file on stderr) — the load is the natural hygiene
    /// point, since it runs once per process before any save.
    pub fn load_file(path: &str) -> Result<EvalCache, String> {
        crate::util::fsx::sweep_orphan_tmp(path);
        EvalCache::from_json(&Json::parse_file(path)?)
    }

    /// Warm-start load that never fails: a missing file is a normal cold
    /// start (the first run of a sweep), returned silently; a corrupt,
    /// truncated, or unreadable file degrades to a cold cache with a
    /// warning on stderr. Never panics, never aborts the run.
    pub fn load_file_or_cold(path: &str) -> EvalCache {
        // hygiene even on cold starts: a crashed writer may have left a
        // temp file without ever completing a final one
        crate::util::fsx::sweep_orphan_tmp(path);
        if !std::path::Path::new(path).exists() {
            return EvalCache::default();
        }
        match EvalCache::load_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("warning: eval-cache file {e}; starting cold");
                EvalCache::default()
            }
        }
    }

    /// Full-state snapshot for mid-search checkpoints (tree snapshots,
    /// [`crate::mcts::Mcts::snapshot`]) — unlike the cross-process
    /// [`EvalCache::to_json`] warm-start format, this keeps everything
    /// resume equivalence needs: prediction entries (stored **salt-free**
    /// as `tracekey:generation`, since the salt is a per-process nonce
    /// the restoring process re-draws) and the live hit/miss counters.
    /// Only predictions owned by `salt` (the snapshotting cost model) are
    /// included; values use the exact bits-string form.
    pub fn snapshot_full(&self, salt: u64) -> Json {
        use crate::util::json::f64_to_bits_json;
        let mut lat = Json::obj();
        for (k, v) in &self.lat {
            lat.set(&k.to_string(), f64_to_bits_json(*v));
        }
        let mut pred = Json::obj();
        for (k, v) in &self.pred {
            if k.1 == salt {
                pred.set(&format!("{}:{}", k.0, k.2), f64_to_bits_json(*v));
            }
        }
        let mut root = Json::obj();
        root.set("max_entries", self.max_entries.into())
            .set("hits", self.stats.hits.to_string().into())
            .set("misses", self.stats.misses.to_string().into())
            .set("lat", lat)
            .set("pred", pred);
        root
    }

    /// Inverse of [`EvalCache::snapshot_full`]: rebuild the full cache
    /// state, re-keying every prediction entry under the restoring cost
    /// model's fresh `salt`. Corrupt input degrades to `Err`, never a
    /// panic.
    pub fn restore_full(v: &Json, salt: u64) -> Result<EvalCache, String> {
        use crate::util::json::{f64_from_bits_json, json_u64_str, json_usize};
        let max_entries = json_usize(v, "max_entries")?;
        let stats = CacheStats {
            hits: json_u64_str(v, "hits")?,
            misses: json_u64_str(v, "misses")?,
        };
        let lat_obj = v
            .get("lat")
            .and_then(Json::as_obj)
            .ok_or("cache snapshot: missing lat map")?;
        let mut lat = HashMap::with_capacity(lat_obj.len());
        for (k, val) in lat_obj {
            let key: u64 = k
                .parse()
                .map_err(|_| format!("cache snapshot: bad lat key {k:?}"))?;
            lat.insert(key, f64_from_bits_json(val)?);
        }
        let pred_obj = v
            .get("pred")
            .and_then(Json::as_obj)
            .ok_or("cache snapshot: missing pred map")?;
        let mut pred = HashMap::with_capacity(pred_obj.len());
        for (k, val) in pred_obj {
            let (tk, gen) = k
                .split_once(':')
                .ok_or_else(|| format!("cache snapshot: bad pred key {k:?}"))?;
            let tk: u64 = tk
                .parse()
                .map_err(|_| format!("cache snapshot: bad pred key {k:?}"))?;
            let gen: usize = gen
                .parse()
                .map_err(|_| format!("cache snapshot: bad pred key {k:?}"))?;
            pred.insert((tk, salt, gen), f64_from_bits_json(val)?);
        }
        Ok(EvalCache {
            lat,
            pred,
            stats,
            max_entries,
        })
    }
}

/// Outcome of one ground-truth measurement: the latency plus whether the
/// shared cache served it. When `cache_hit` is true no simulator (i.e.
/// simulated compile-and-run harness) invocation happened, so callers
/// accounting for harness wall-clock must not charge measurement overhead
/// for it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measured {
    pub latency_s: f64,
    pub cache_hit: bool,
}

/// The single surface through which the search engine evaluates programs.
/// See the module docs.
pub trait Evaluator {
    /// Ground-truth measurement: evaluate on the hardware model, feed the
    /// learned cost model, advance the incumbent. Reports the latency (s)
    /// and whether the cache served it (see [`Measured`]) — the cost
    /// model is fed either way, the harness overhead only on a miss.
    fn measure(&mut self, s: &Schedule) -> Measured;

    /// Ground-truth latency *without* training — the deterministic oracle
    /// used in expansion and rollout scoring, served through the cache.
    fn true_latency(&mut self, s: &Schedule) -> f64;

    /// Normalized predicted performance score in [0, 1] from the learned
    /// cost model (higher = better), with per-generation prediction
    /// caching.
    fn score(&mut self, s: &Schedule) -> f64;

    /// Batched [`Evaluator::score`]: scores, values served, and cache
    /// counters must all be exactly what calling `score` per item in
    /// order would produce. The default does exactly that; the production
    /// evaluators override it to run cache misses through one SoA
    /// [`CostModel::predict_latency_batch`] pass (the candidate-scoring
    /// hot path of a parallel round).
    fn score_batch(&mut self, ss: &[&Schedule]) -> Vec<f64> {
        ss.iter().map(|s| self.score(s)).collect()
    }

    /// Best (lowest) measured latency seen so far.
    fn best_latency(&self) -> f64;

    /// The evaluation target.
    fn target(&self) -> Target;

    /// Cache hit/miss counters accumulated so far.
    #[must_use]
    fn cache_stats(&self) -> CacheStats;
}

/// Production [`Evaluator`]: learned cost model + hardware simulator,
/// fronted by an [`EvalCache`].
///
/// Evaluation is cached at **two layers**: this transposition cache
/// dedups whole programs (same trace key ⇒ same latency, simulator never
/// consulted), and beneath it every simulator invocation —
/// [`Simulator::latency`] on a transposition miss — is itself
/// incremental, serving unchanged blocks from the thread-local per-block
/// memo ([`crate::sim::blockcache`]). So a transposition miss on a
/// program that shares all-but-one block with anything previously
/// evaluated on this thread still costs only one block simulation. The
/// block memo is per-thread (each driver lane / tree-parallel worker
/// warms its own) and bit-transparent, so it composes with every
/// determinism contract this module documents.
pub struct CachedEvaluator {
    pub cost: CostModel,
    pub sim: Simulator,
    pub cache: EvalCache,
    /// Reusable batch-scoring buffers (feature matrix + predictions) —
    /// cleared, never dropped, between `score_batch` rounds, so lane
    /// scoring performs zero per-candidate feature-row allocations.
    pub scratch: ScoreScratch,
}

impl CachedEvaluator {
    pub fn new(cost: CostModel, sim: Simulator) -> CachedEvaluator {
        CachedEvaluator::with_cache(cost, sim, EvalCache::default())
    }

    /// Use an externally owned cache (shared across searches). Stale
    /// prediction entries from other cost-model instances are pruned and
    /// the hit/miss counters reset — entries persist across searches, but
    /// each search reports only its own counters; ground-truth latency
    /// entries — the shareable part — are kept.
    pub fn with_cache(cost: CostModel, sim: Simulator, mut cache: EvalCache) -> CachedEvaluator {
        cache.retain_predictions_of(cost.salt);
        cache.reset_stats();
        CachedEvaluator {
            cost,
            sim,
            cache,
            scratch: ScoreScratch::default(),
        }
    }

    /// Hand the cache back (e.g. to reuse it for a follow-up search).
    pub fn into_cache(self) -> EvalCache {
        self.cache
    }
}

impl Evaluator for CachedEvaluator {
    fn measure(&mut self, s: &Schedule) -> Measured {
        let key = trace_key(s, self.sim.target());
        let sim = &self.sim;
        let (lat, cache_hit) = self.cache.latency_or_served(key, || sim.latency(s));
        self.cost.observe(s, lat);
        Measured {
            latency_s: lat,
            cache_hit,
        }
    }

    fn true_latency(&mut self, s: &Schedule) -> f64 {
        let key = trace_key(s, self.sim.target());
        let sim = &self.sim;
        self.cache.latency_or(key, || sim.latency(s))
    }

    fn score(&mut self, s: &Schedule) -> f64 {
        let pred = match self.cost.generation() {
            Some(gen) => {
                let key = (trace_key(s, self.sim.target()), self.cost.salt, gen);
                let cost = &self.cost;
                self.cache.prediction_or(key, || cost.predict_latency(s))
            }
            // before the first fit, predictions track the latest
            // observation and aren't pure — don't cache them
            None => self.cost.predict_latency(s),
        };
        self.cost.score_of_prediction(pred)
    }

    fn score_batch(&mut self, ss: &[&Schedule]) -> Vec<f64> {
        let preds = match self.cost.generation() {
            Some(gen) => batched_predictions(
                &self.cost,
                gen,
                self.sim.target(),
                &mut self.cache,
                &mut self.scratch,
                ss,
            ),
            // pre-fit predictions aren't pure and aren't cached — same
            // fallback as the scalar path, item by item
            None => self.cost.predict_latency_batch(ss),
        };
        preds
            .into_iter()
            .map(|p| self.cost.score_of_prediction(p))
            .collect()
    }

    fn best_latency(&self) -> f64 {
        self.cost.best_latency
    }

    fn target(&self) -> Target {
        self.sim.target()
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

// ------------------------------------------------------------------------
// Concurrent sharded view (tree-parallel search)
// ------------------------------------------------------------------------

/// One shard of a [`SharedEvalCache`]: a plain [`EvalCache`] behind an
/// `RwLock`, with the hit/miss counters lifted out into atomics so the
/// read path never needs the write lock.
#[derive(Debug)]
struct Shard {
    cache: RwLock<EvalCache>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Concurrent N-way sharded view over the evaluation cache, shared by the
/// tree-parallel search workers ([`crate::mcts::Mcts::run_parallel`]).
///
/// A key lives in shard `key % N` (`PredKey`s shard by their trace-key
/// component), so concurrent lookups of different programs almost never
/// contend, and each shard is an ordinary [`EvalCache`] behind an
/// `RwLock`. The lookup protocol is double-checked:
///
/// 1. read lock → present? count a hit, return;
/// 2. write lock → re-check (a racer may have filled it) → still absent?
///    compute **under the shard write lock**, insert, count a miss.
///
/// Computing under the write lock serializes same-shard misses, but buys
/// the invariant the harness-time accounting depends on: **every key is
/// computed and charged as a miss exactly once**, no matter how many
/// threads race on it (while the shard has insert capacity). Values are
/// pure functions of their keys, so the cache contents — and, because of
/// the exactly-once protocol, the aggregate [`CacheStats`] — are
/// deterministic regardless of thread scheduling.
///
/// Per-shard counters are atomics and merge into one [`CacheStats`] via
/// [`SharedEvalCache::stats`]; stats carried in by
/// [`SharedEvalCache::from_cache`] are preserved in a base counter so a
/// search that converts its warm [`EvalCache`] keeps honest totals.
#[derive(Debug)]
pub struct SharedEvalCache {
    shards: Vec<Shard>,
    base_hits: AtomicU64,
    base_misses: AtomicU64,
    /// The source cache's configured entry bound, preserved verbatim so a
    /// serial↔parallel round-trip ([`SharedEvalCache::from_cache`] →
    /// [`SharedEvalCache::into_cache`]) hands back the bound the caller
    /// set, not the rounding of the per-shard split.
    total_capacity: usize,
}

impl Default for SharedEvalCache {
    fn default() -> Self {
        SharedEvalCache::new(Self::DEFAULT_SHARDS)
    }
}

impl SharedEvalCache {
    /// Default shard count: enough that 8–16 workers rarely collide on a
    /// shard lock, small enough that merging/draining stays trivial.
    pub const DEFAULT_SHARDS: usize = 16;

    /// Empty sharded cache with the default per-map capacity split evenly
    /// across `n_shards` (clamped to at least 1).
    pub fn new(n_shards: usize) -> SharedEvalCache {
        SharedEvalCache::from_cache(EvalCache::default(), n_shards)
    }

    /// Shard an existing cache: entries are distributed by `key % N`, the
    /// entry bound is split evenly across shards (ceiling division, so a
    /// configured bound is never truncated — though per-shard enforcement
    /// means the *effective* bound is approximate: a tiny bound can admit
    /// up to `n_shards` entries, one per shard), and the source's
    /// hit/miss counters are preserved (reported by
    /// [`SharedEvalCache::stats`] alongside the per-shard counters).
    /// Seeding ignores the per-shard bound — only post-construction
    /// inserts are bounded. [`SharedEvalCache::into_cache`] restores the
    /// source's configured bound verbatim.
    pub fn from_cache(cache: EvalCache, n_shards: usize) -> SharedEvalCache {
        let n = n_shards.max(1);
        let EvalCache {
            lat,
            pred,
            stats,
            max_entries,
        } = cache;
        // ceiling split, except a zero bound stays zero (capacity 0 means
        // "never insert", and that contract must survive sharding)
        let per_shard = max_entries.div_ceil(n);
        let mut shards: Vec<EvalCache> = (0..n)
            .map(|_| EvalCache::with_capacity(per_shard))
            .collect();
        for (k, v) in lat {
            shards[(k % n as u64) as usize].lat.insert(k, v);
        }
        for (k, v) in pred {
            shards[(k.0 % n as u64) as usize].pred.insert(k, v);
        }
        SharedEvalCache {
            shards: shards
                .into_iter()
                .map(|cache| Shard {
                    cache: RwLock::new(cache),
                    hits: AtomicU64::new(0),
                    misses: AtomicU64::new(0),
                })
                .collect(),
            base_hits: AtomicU64::new(stats.hits),
            base_misses: AtomicU64::new(stats.misses),
            total_capacity: max_entries,
        }
    }

    /// Drain the shards back into one owned [`EvalCache`] (entries
    /// unioned, counters merged, and the source cache's configured entry
    /// bound restored verbatim).
    pub fn into_cache(self) -> EvalCache {
        let stats = self.stats();
        let max_entries = self.total_capacity;
        let mut lat = HashMap::new();
        let mut pred = HashMap::new();
        for sh in self.shards {
            let c = sh.cache.into_inner().unwrap();
            lat.extend(c.lat);
            pred.extend(c.pred);
        }
        EvalCache {
            lat,
            pred,
            stats,
            max_entries,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, key: u64) -> &Shard {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    /// Concurrent [`EvalCache::latency_or_served`]: `&self`, safe to call
    /// from many workers at once. See the type docs for the exactly-once
    /// miss protocol.
    pub fn latency_or_served(&self, key: u64, f: impl FnOnce() -> f64) -> (f64, bool) {
        let sh = self.shard(key);
        if let Some(&v) = sh.cache.read().unwrap().lat.get(&key) {
            sh.hits.fetch_add(1, Ordering::Relaxed);
            return (v, true);
        }
        let mut w = sh.cache.write().unwrap();
        if let Some(&v) = w.lat.get(&key) {
            sh.hits.fetch_add(1, Ordering::Relaxed);
            return (v, true);
        }
        // compute under the shard write lock: a racing worker waits and
        // then hits, so the simulator runs (and the miss is charged)
        // exactly once per key
        let v = f();
        if w.lat.len() < w.max_entries {
            w.lat.insert(key, v);
        }
        sh.misses.fetch_add(1, Ordering::Relaxed);
        (v, false)
    }

    /// Concurrent ground-truth lookup without the served flag.
    pub fn latency_or(&self, key: u64, f: impl FnOnce() -> f64) -> f64 {
        self.latency_or_served(key, f).0
    }

    /// Concurrent [`EvalCache::prediction_or`] (same protocol, prediction
    /// map, sharded by the key's trace-key component).
    pub fn prediction_or(&self, key: PredKey, f: impl FnOnce() -> f64) -> f64 {
        let sh = self.shard(key.0);
        if let Some(&v) = sh.cache.read().unwrap().pred.get(&key) {
            sh.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        let mut w = sh.cache.write().unwrap();
        if let Some(&v) = w.pred.get(&key) {
            sh.hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        let v = f();
        if w.pred.len() < w.max_entries {
            w.pred.insert(key, v);
        }
        sh.misses.fetch_add(1, Ordering::Relaxed);
        v
    }

    /// Merged hit/miss counters: the base counters carried in by
    /// [`SharedEvalCache::from_cache`] plus every shard's counters.
    #[must_use]
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats {
            hits: self.base_hits.load(Ordering::Relaxed),
            misses: self.base_misses.load(Ordering::Relaxed),
        };
        for sh in &self.shards {
            s.merge(&CacheStats {
                hits: sh.hits.load(Ordering::Relaxed),
                misses: sh.misses.load(Ordering::Relaxed),
            });
        }
        s
    }

    /// Zero every counter (entries are kept).
    pub fn reset_stats(&self) {
        self.base_hits.store(0, Ordering::Relaxed);
        self.base_misses.store(0, Ordering::Relaxed);
        for sh in &self.shards {
            sh.hits.store(0, Ordering::Relaxed);
            sh.misses.store(0, Ordering::Relaxed);
        }
    }

    /// Total entries currently held across all shards (both maps).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|sh| sh.cache.read().unwrap().len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// [`PredStore`] over a borrowed shared cache (the batched scoring path
/// runs on the tree-parallel coordinator thread). Charging a miss is
/// defensive against a concurrent insert: under the shard write lock a
/// key that turned up in the meantime is charged as a hit instead —
/// values are pure functions of their keys, so either outcome returns the
/// same number and the exactly-once compute accounting holds.
impl PredStore for &SharedEvalCache {
    fn pred_peek(&self, key: PredKey) -> Option<f64> {
        self.shard(key.0).cache.read().unwrap().pred.get(&key).copied()
    }
    fn pred_charge_hit(&mut self, key: PredKey) {
        self.shard(key.0).hits.fetch_add(1, Ordering::Relaxed);
    }
    fn pred_charge_miss_insert(&mut self, key: PredKey, v: f64) {
        let sh = self.shard(key.0);
        let mut w = sh.cache.write().unwrap();
        if w.pred.contains_key(&key) {
            sh.hits.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if w.pred.len() < w.max_entries {
            w.pred.insert(key, v);
        }
        sh.misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Batched prediction scoring shared by both evaluators' `score_batch`:
/// peek every key (uncounted), run **one** chunked SoA
/// [`CostModel::predict_latency_batch_into`] over the first occurrence of
/// each missing key (feature rows land in the evaluator's reusable
/// [`ScoreScratch`] — no per-candidate row allocations), then walk the
/// items in order charging hits/misses — so values *and* counters are
/// exactly what looping `Evaluator::score` per item would have produced,
/// while the cost-model inference runs as one contiguous batch.
fn batched_predictions<P: PredStore>(
    cost: &CostModel,
    generation: usize,
    target: Target,
    store: &mut P,
    scratch: &mut ScoreScratch,
    ss: &[&Schedule],
) -> Vec<f64> {
    let keys: Vec<PredKey> = ss
        .iter()
        .map(|s| (trace_key(s, target), cost.salt, generation))
        .collect();
    // plan: first occurrence of every key absent from the store
    let mut fresh_keys: Vec<PredKey> = Vec::new();
    let mut fresh_rows: Vec<&Schedule> = Vec::new();
    let mut seen: std::collections::HashSet<PredKey> = std::collections::HashSet::new();
    for (&k, &s) in keys.iter().zip(ss) {
        if store.pred_peek(k).is_none() && seen.insert(k) {
            fresh_keys.push(k);
            fresh_rows.push(s);
        }
    }
    // one batched chunked-SoA inference pass over the misses
    cost.predict_latency_batch_into(&fresh_rows, scratch);
    let fresh: HashMap<PredKey, f64> = fresh_keys
        .into_iter()
        .zip(scratch.preds.iter().copied())
        .collect();
    // charge in item order: first occurrence of a fresh key is the miss,
    // later occurrences (now inserted) and pre-existing keys are hits —
    // the same ledger as the sequential loop
    keys.into_iter()
        .map(|k| {
            if let Some(v) = store.pred_peek(k) {
                store.pred_charge_hit(k);
                v
            } else {
                let v = fresh[&k];
                store.pred_charge_miss_insert(k, v);
                v
            }
        })
        .collect()
}

/// [`Evaluator`] over a **borrowed** [`SharedEvalCache`]: the cost model
/// and simulator are owned (per search), the transposition cache is the
/// shared concurrent view. This is what the tree-parallel engine
/// ([`crate::mcts::Mcts::run_parallel`]) drives on the coordinator thread
/// while its workers hit the same `&SharedEvalCache` directly.
pub struct SharedCachedEvaluator<'a> {
    pub cost: CostModel,
    pub sim: Simulator,
    pub cache: &'a SharedEvalCache,
    /// Reusable batch-scoring buffers, same role as
    /// [`CachedEvaluator::scratch`] (the coordinator thread owns it; the
    /// shared part is only the cache).
    pub scratch: ScoreScratch,
}

impl Evaluator for SharedCachedEvaluator<'_> {
    fn measure(&mut self, s: &Schedule) -> Measured {
        let key = trace_key(s, self.sim.target());
        let sim = &self.sim;
        let (lat, cache_hit) = self.cache.latency_or_served(key, || sim.latency(s));
        self.cost.observe(s, lat);
        Measured {
            latency_s: lat,
            cache_hit,
        }
    }

    fn true_latency(&mut self, s: &Schedule) -> f64 {
        let key = trace_key(s, self.sim.target());
        let sim = &self.sim;
        self.cache.latency_or(key, || sim.latency(s))
    }

    fn score(&mut self, s: &Schedule) -> f64 {
        let pred = match self.cost.generation() {
            Some(gen) => {
                let key = (trace_key(s, self.sim.target()), self.cost.salt, gen);
                let cost = &self.cost;
                self.cache.prediction_or(key, || cost.predict_latency(s))
            }
            None => self.cost.predict_latency(s),
        };
        self.cost.score_of_prediction(pred)
    }

    fn score_batch(&mut self, ss: &[&Schedule]) -> Vec<f64> {
        let preds = match self.cost.generation() {
            Some(gen) => {
                let mut store = self.cache;
                batched_predictions(
                    &self.cost,
                    gen,
                    self.sim.target(),
                    &mut store,
                    &mut self.scratch,
                    ss,
                )
            }
            None => self.cost.predict_latency_batch(ss),
        };
        preds
            .into_iter()
            .map(|p| self.cost.score_of_prediction(p))
            .collect()
    }

    fn best_latency(&self) -> f64 {
        self.cost.best_latency
    }

    fn target(&self) -> Target {
        self.sim.target()
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn base() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)))
    }

    #[test]
    fn key_is_stable_across_calls_and_clones() {
        let mut rng = Rng::new(1);
        let s = apply(&base(), TransformKind::TileSize, &mut rng, false).unwrap();
        let k1 = trace_key(&s, Target::Cpu);
        let k2 = trace_key(&s, Target::Cpu);
        let k3 = trace_key(&s.clone(), Target::Cpu);
        assert_eq!(k1, k2);
        assert_eq!(k1, k3);
    }

    #[test]
    fn key_distinguishes_targets_and_traces() {
        let mut rng = Rng::new(2);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::Vectorize, &mut rng, false).unwrap();
        assert_ne!(trace_key(&s0, Target::Cpu), trace_key(&s0, Target::Gpu));
        assert_ne!(trace_key(&s0, Target::Cpu), trace_key(&s1, Target::Cpu));
    }

    #[test]
    fn hit_on_identical_trace() {
        let mut rng = Rng::new(3);
        let s = apply(&base(), TransformKind::Parallel, &mut rng, false).unwrap();
        let mut ev = CachedEvaluator::new(
            CostModel::new(Target::Cpu, 7),
            Simulator::new(Target::Cpu),
        );
        let a = ev.true_latency(&s);
        let b = ev.true_latency(&s.clone());
        assert_eq!(a, b);
        let stats = ev.cache_stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn miss_on_divergent_trace() {
        let mut rng = Rng::new(4);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::Unroll, &mut rng, false).unwrap();
        let s2 = apply(&s1, TransformKind::Vectorize, &mut rng, false).unwrap();
        let mut ev = CachedEvaluator::new(
            CostModel::new(Target::Cpu, 8),
            Simulator::new(Target::Cpu),
        );
        ev.true_latency(&s0);
        ev.true_latency(&s1);
        ev.true_latency(&s2);
        let stats = ev.cache_stats();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 3);
    }

    #[test]
    fn measure_trains_but_caches_ground_truth() {
        let s = base();
        let sim = Simulator::new(Target::Cpu);
        let expect = sim.latency(&s);
        let mut ev = CachedEvaluator::new(CostModel::new(Target::Cpu, 9), sim);
        let a = ev.measure(&s);
        let b = ev.measure(&s);
        assert_eq!(a.latency_s, expect);
        assert_eq!(b.latency_s, expect);
        // measure reports what actually happened at the harness level:
        // the first run hit the simulator, the repeat was cache-served
        assert!(!a.cache_hit);
        assert!(b.cache_hit);
        // both measures still fed the cost model, only the sim run was
        // deduplicated
        assert_eq!(ev.cost.n_measured, 2);
        assert_eq!(ev.cache_stats().hits, 1);
    }

    #[test]
    fn trace_key_reads_cached_hashes() {
        // trace_key must be a pure function of (running trace hash,
        // workload, target, fingerprint) — recomputing it on a clone that
        // shares the trace nodes gives the identical key, and a trace
        // rebuilt from the same decisions (fresh nodes, same strings) too.
        let mut rng_a = Rng::new(12);
        let mut rng_b = Rng::new(12);
        let a = apply(&base(), TransformKind::TileSize, &mut rng_a, false).unwrap();
        let b = apply(&base(), TransformKind::TileSize, &mut rng_b, false).unwrap();
        assert_eq!(a.trace.running_hash(), b.trace.running_hash());
        assert_eq!(trace_key(&a, Target::Cpu), trace_key(&b, Target::Cpu));
        // a divergent decision changes the running hash and therefore the
        // key — built deterministically so the assertion always runs
        let mut c = a.clone();
        c.trace.push("sample_perfect_tile", "matmul", "loop=i, decision=[2, 128]".into());
        assert_ne!(c.trace.running_hash(), a.trace.running_hash());
        assert_ne!(trace_key(&a, Target::Cpu), trace_key(&c, Target::Cpu));
    }

    #[test]
    fn capacity_zero_disables_insertion_but_not_correctness() {
        let s = base();
        let sim = Simulator::new(Target::Cpu);
        let mut ev = CachedEvaluator::with_cache(
            CostModel::new(Target::Cpu, 10),
            sim,
            EvalCache::with_capacity(0),
        );
        let a = ev.true_latency(&s);
        let b = ev.true_latency(&s);
        assert_eq!(a, b);
        assert_eq!(ev.cache_stats().hits, 0);
        assert_eq!(ev.cache_stats().misses, 2);
        assert!(ev.cache.is_empty());
    }

    #[test]
    fn stats_merge_and_reset() {
        let mut a = CacheStats { hits: 2, misses: 3 };
        let b = CacheStats { hits: 1, misses: 0 };
        a.merge(&b);
        assert_eq!(a, CacheStats { hits: 3, misses: 3 });
        let mut c = EvalCache::new();
        c.latency_or(1, || 1.0);
        c.reset_stats();
        assert_eq!(c.stats(), CacheStats::default());
        assert!(!c.is_empty());
    }

    #[test]
    fn merged_empty_stats_hit_rate_is_zero_not_nan() {
        // the zero-lookup edge of driver-level aggregation: merging any
        // number of empty counters must report 0.0, never NaN
        let mut merged = CacheStats::default();
        for _ in 0..4 {
            merged.merge(&CacheStats::default());
        }
        assert_eq!(merged, CacheStats::default());
        assert_eq!(merged.hit_rate(), 0.0);
        assert!(!merged.hit_rate().is_nan());
    }

    #[test]
    fn shared_cache_roundtrips_and_merges_stats() {
        let mut base = EvalCache::new();
        base.latency_or(1, || 1.5);
        base.latency_or(1, || unreachable!("cached"));
        base.prediction_or((2, 9, 0), || 0.25);
        let base_stats = base.stats();
        assert_eq!(base_stats, CacheStats { hits: 1, misses: 2 });

        let shared = SharedEvalCache::from_cache(base, 4);
        assert_eq!(shared.n_shards(), 4);
        assert_eq!(shared.len(), 2);
        // carried-in stats are preserved and new lookups merge on top
        assert_eq!(shared.stats(), base_stats);
        let (v, served) = shared.latency_or_served(1, || unreachable!("cached"));
        assert_eq!(v, 1.5);
        assert!(served);
        assert_eq!(shared.latency_or(17, || 3.25), 3.25);
        assert_eq!(shared.prediction_or((2, 9, 0), || unreachable!("cached")), 0.25);
        assert_eq!(shared.stats(), CacheStats { hits: 3, misses: 3 });

        let back = shared.into_cache();
        assert_eq!(back.len(), 3);
        assert_eq!(back.stats(), CacheStats { hits: 3, misses: 3 });
        let mut back = back;
        assert_eq!(back.latency_or(17, || unreachable!("cached")), 3.25);
    }

    #[test]
    fn shared_cache_round_trip_preserves_configured_capacity() {
        // the per-shard split must not leak into the bound the caller
        // configured: with_capacity(100) → 16 shards → back to 100, not
        // 16 * (100 / 16) = 96
        let shared = SharedEvalCache::from_cache(EvalCache::with_capacity(100), 16);
        assert_eq!(shared.into_cache().max_entries, 100);
        // tiny bounds don't inflate either (4 → 16 shards → back to 4)
        let shared = SharedEvalCache::from_cache(EvalCache::with_capacity(4), 16);
        assert_eq!(shared.into_cache().max_entries, 4);
    }

    #[test]
    fn shared_cache_reset_stats_keeps_entries() {
        let shared = SharedEvalCache::new(2);
        shared.latency_or(5, || 2.0);
        shared.reset_stats();
        assert_eq!(shared.stats(), CacheStats::default());
        assert_eq!(shared.len(), 1);
        assert!(!shared.is_empty());
    }

    #[test]
    fn shared_cache_hammered_by_8_threads_loses_nothing_and_charges_once() {
        use std::sync::atomic::{AtomicU64, Ordering};
        // 8 threads insert/read the same 64 keys over and over; the cache
        // must (a) never lose a ground-truth entry, (b) report every
        // value correctly, and (c) charge each key's computation exactly
        // once — the `served=false` outcomes callers use to charge
        // measure_overhead_s must total one per key, never two.
        const THREADS: usize = 8;
        const REPS: usize = 50;
        let keys: Vec<u64> = (0..64u64).map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15)).collect();
        let shared = SharedEvalCache::new(8);
        let computed = AtomicU64::new(0);
        let charged = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..THREADS {
                scope.spawn(|| {
                    for _ in 0..REPS {
                        for &k in &keys {
                            let (v, served) = shared.latency_or_served(k, || {
                                computed.fetch_add(1, Ordering::Relaxed);
                                k as f64 * 0.5
                            });
                            assert_eq!(v, k as f64 * 0.5);
                            if !served {
                                charged.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                });
            }
        });
        // exactly one compute + one overhead charge per key
        assert_eq!(computed.load(Ordering::Relaxed), keys.len() as u64);
        assert_eq!(charged.load(Ordering::Relaxed), keys.len() as u64);
        let stats = shared.stats();
        assert_eq!(stats.misses, keys.len() as u64);
        assert_eq!(
            stats.hits + stats.misses,
            (THREADS * REPS * keys.len()) as u64
        );
        // no entry was lost: every key drains back out with its value
        let mut cache = shared.into_cache();
        for &k in &keys {
            assert_eq!(cache.latency_or(k, || unreachable!("lost entry")), k as f64 * 0.5);
        }
    }

    #[test]
    fn json_roundtrip_is_lossless_and_drops_predictions() {
        let mut c = EvalCache::with_capacity(12345);
        // awkward values: shortest-round-trip rendering must reproduce
        // every bit pattern
        c.latency_or(0, || 0.1 + 0.2);
        c.latency_or(u64::MAX, || 1.5e-300);
        c.latency_or(42, || 5e-324); // subnormal
        c.latency_or(7, || 3.0);
        c.prediction_or((9, 1, 0), || 0.5); // must not survive the round trip
        let back = EvalCache::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.capacity(), 12345);
        assert_eq!(back.stats(), CacheStats::default());
        assert_eq!(back.len(), 4); // lat only, pred dropped
        let mut back = back;
        for (k, v) in [
            (0u64, 0.1 + 0.2),
            (u64::MAX, 1.5e-300),
            (42, 5e-324),
            (7, 3.0),
        ] {
            let got = back.latency_or(k, || unreachable!("entry {k} lost"));
            assert_eq!(got.to_bits(), v.to_bits(), "key {k}");
        }
        // the prediction was dropped: looking it up recomputes
        assert_eq!(back.prediction_or((9, 1, 0), || 0.25), 0.25);
    }

    #[test]
    fn from_json_rejects_malformed_documents() {
        for bad in [
            "null",
            "{}",
            // v1 files carry keys from the pre-block-fingerprint trace_key
            // formula and must be rejected, not absorbed
            r#"{"version": 1, "max_entries": "4", "lat": {}}"#,
            r#"{"version": 3, "max_entries": "4", "lat": {}}"#,
            r#"{"version": 2, "lat": {}}"#,
            r#"{"version": 2, "max_entries": "x", "lat": {}}"#,
            r#"{"version": 2, "max_entries": "4"}"#,
            r#"{"version": 2, "max_entries": "4", "lat": {"abc": 1.0}}"#,
            r#"{"version": 2, "max_entries": "4", "lat": {"1": "nope"}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(EvalCache::from_json(&j).is_err(), "accepted {bad}");
        }
        // the current version with a well-formed body parses
        let ok = r#"{"version": 2, "max_entries": "4", "lat": {"1": 0.5}}"#;
        assert_eq!(
            EvalCache::from_json(&Json::parse(ok).unwrap()).unwrap().len(),
            1
        );
    }

    #[test]
    fn absorb_unions_entries_and_respects_bound() {
        let mut a = EvalCache::with_capacity(3);
        a.latency_or(1, || 1.0);
        let mut b = EvalCache::new();
        b.latency_or(1, || 1.0);
        b.latency_or(2, || 2.0);
        b.latency_or(3, || 3.0);
        b.latency_or(4, || 4.0);
        a.absorb(b);
        // bound 3: the overlapping key plus at most two new ones
        assert!(a.len() <= 3, "bound violated: {}", a.len());
        assert_eq!(a.latency_or(1, || unreachable!("lost")), 1.0);
        // counters were not merged: a's original miss plus the hit above
        assert_eq!(a.stats(), CacheStats { hits: 1, misses: 1 });
    }

    #[test]
    fn save_load_file_roundtrip_and_corrupt_degrades_cold() {
        let path = std::env::temp_dir().join(format!(
            "litecoop_evalcache_unit_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let mut c = EvalCache::with_capacity(99);
        c.latency_or(11, || 0.125);
        c.save_file(&path).unwrap();
        let loaded = EvalCache::load_file(&path).unwrap();
        assert_eq!(loaded.capacity(), 99);
        assert_eq!(loaded.len(), 1);
        // corrupt the file: load_file errs, load_file_or_cold degrades
        std::fs::write(&path, "{\"version\": 1, trunca").unwrap();
        assert!(EvalCache::load_file(&path).is_err());
        let cold = EvalCache::load_file_or_cold(&path);
        assert!(cold.is_empty());
        let _ = std::fs::remove_file(&path);
        // missing file is a silent cold start
        assert!(EvalCache::load_file_or_cold(&path).is_empty());
    }

    #[test]
    fn score_batch_matches_scalar_score_values_and_counters() {
        // both evaluators, pre-fit and post-fit, duplicates included: the
        // batched scoring path must reproduce the scalar path's values
        // AND its hit/miss ledger exactly
        let mut rng = Rng::new(41);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::TileSize, &mut rng, false).unwrap();
        let s2 = apply(&s1, TransformKind::Vectorize, &mut rng, false).unwrap();
        let items: Vec<&Schedule> = vec![&s0, &s1, &s1, &s2, &s0];

        let mk_serial = || {
            CachedEvaluator::new(CostModel::new(Target::Cpu, 91), Simulator::new(Target::Cpu))
        };
        let train = |ev: &mut dyn Evaluator| {
            // enough successful measurements to fit a model (>= 8 rows)
            let mut r = Rng::new(5);
            let vocab = TransformKind::vocabulary(false);
            let mut measured = 0;
            while measured < 10 {
                let seq: Vec<_> = (0..2).map(|_| *r.choice(&vocab)).collect();
                if let Ok(s) = crate::schedule::transforms::apply_sequence(&s0, &seq, &mut r, false)
                {
                    ev.measure(&s);
                    measured += 1;
                }
            }
        };

        // pre-fit parity (uncached fallback path)
        let mut a = mk_serial();
        let mut b = mk_serial();
        let scalar: Vec<f64> = items.iter().map(|s| a.score(s)).collect();
        let batch = b.score_batch(&items);
        assert_eq!(scalar, batch);
        assert_eq!(a.cache_stats(), b.cache_stats());

        // post-fit parity on the serial evaluator (identical twin models:
        // same seed => same training trajectory modulo salt, so compare
        // each evaluator against ITS OWN scalar replay instead)
        let mut ev = mk_serial();
        train(&mut ev);
        let before = ev.cache_stats();
        let batch = ev.score_batch(&items);
        // replay scalar on a fresh evaluator twin trained identically:
        // values must match (salt only keys the cache, not the value)
        let mut twin = mk_serial();
        train(&mut twin);
        let twin_before = twin.cache_stats();
        let scalar: Vec<f64> = items.iter().map(|s| twin.score(s)).collect();
        assert_eq!(scalar, batch);
        let delta = |s: CacheStats, b: CacheStats| CacheStats {
            hits: s.hits - b.hits,
            misses: s.misses - b.misses,
        };
        assert_eq!(
            delta(ev.cache_stats(), before),
            delta(twin.cache_stats(), twin_before),
            "batched ledger must equal the scalar ledger"
        );
        // a repeat batch is all hits, same values
        let mid = ev.cache_stats();
        assert_eq!(ev.score_batch(&items), batch);
        let d = delta(ev.cache_stats(), mid);
        assert_eq!(d.misses, 0);
        assert_eq!(d.hits, items.len() as u64);

        // shared evaluator: same contract through the sharded store
        let shared = SharedEvalCache::new(4);
        let mut conc = SharedCachedEvaluator {
            cost: CostModel::new(Target::Cpu, 91),
            sim: Simulator::new(Target::Cpu),
            cache: &shared,
            scratch: ScoreScratch::default(),
        };
        train(&mut conc);
        let before = conc.cache_stats();
        let cb = conc.score_batch(&items);
        assert_eq!(cb.len(), items.len());
        let d = delta(conc.cache_stats(), before);
        // 5 lookups over 3 unique programs: one miss per unique key, the
        // in-batch duplicate occurrences (s1, s0 again) are hits
        assert_eq!(d.hits + d.misses, items.len() as u64);
        assert_eq!(d.misses, 3, "3 unique programs in the batch");
        assert_eq!(conc.score_batch(&items), cb, "repeat batch identical");
    }

    #[test]
    fn shared_evaluator_matches_serial_evaluator() {
        // the sharded evaluator is observationally identical to the
        // serial one: same values, same counters, for the same call
        // sequence (the transparency contract run_parallel relies on)
        let mut rng = Rng::new(31);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::TileSize, &mut rng, false).unwrap();
        let mut serial = CachedEvaluator::new(
            CostModel::new(Target::Cpu, 77),
            Simulator::new(Target::Cpu),
        );
        let shared = SharedEvalCache::new(4);
        let mut conc = SharedCachedEvaluator {
            cost: CostModel::new(Target::Cpu, 77),
            sim: Simulator::new(Target::Cpu),
            cache: &shared,
            scratch: ScoreScratch::default(),
        };
        for s in [&s0, &s1, &s0, &s1] {
            let a = serial.measure(s);
            let b = conc.measure(s);
            assert_eq!(a, b);
            assert_eq!(serial.true_latency(s), conc.true_latency(s));
            assert_eq!(serial.score(s), conc.score(s));
        }
        assert_eq!(serial.best_latency(), conc.best_latency());
        assert_eq!(serial.cache_stats(), conc.cache_stats());
    }
}

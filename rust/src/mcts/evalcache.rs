//! Shared evaluation cache + the [`Evaluator`] abstraction the search
//! engine talks to.
//!
//! COLT's shared tree lets many LLMs extend each other's transformation
//! prefixes — but that only pays off at the systems level if re-visiting a
//! prefix is cheap. This module makes prefix reuse real: a
//! transposition-style cache keyed by a canonical hash of the schedule's
//! transform trace (computed in **O(1) per lookup** from the trace's
//! incrementally maintained running hash and the schedule's cached
//! structural fingerprint — see [`trace_key`]) memoizes every
//! ground-truth simulator evaluation
//! (shared across everything, including repeated searches over one
//! cache) and every cost-model prediction (keyed per model instance and
//! retraining generation — shared within a search, never leaked between
//! different models' training trajectories). Identical candidate
//! programs — re-proposed by different LLMs, re-scored during
//! course-alteration re-expansion, or re-searched across repeated runs —
//! are evaluated exactly once.
//!
//! # The `Evaluator` trait
//!
//! [`Evaluator`] is the single surface through which the MCTS engine
//! ([`crate::mcts::Mcts`]) reaches the cost model and the hardware
//! simulator:
//!
//! * [`Evaluator::measure`] — ground-truth evaluation that also trains the
//!   learned cost model and advances the incumbent (the paper's
//!   on-hardware measurement step),
//! * [`Evaluator::true_latency`] — ground-truth latency *without*
//!   training (the oracle blended into expansion scoring),
//! * [`Evaluator::score`] — the normalized predicted performance score
//!   from the learned cost model.
//!
//! [`CachedEvaluator`] is the production implementation: a
//! [`CostModel`] + [`Simulator`] pair fronted by an [`EvalCache`]. All
//! cached values are pure functions of their key (the simulator is
//! deterministic; predictions are memoized per retraining generation and
//! per cost-model identity), so enabling the cache never changes a search
//! result — it only removes redundant evaluation work.
//!
//! # Cache knobs
//!
//! * capacity — [`EvalCache::with_capacity`] bounds the number of entries
//!   per map (default [`EvalCache::DEFAULT_CAPACITY`]); once full, new
//!   values are still computed and returned but not inserted.
//! * sharing — an [`EvalCache`] can be built externally and passed to
//!   [`crate::mcts::Mcts::with_cache`] to persist ground-truth hits
//!   across repeated searches of the same workload; retrieve the warm
//!   cache afterwards from [`crate::mcts::Mcts::run_with_cache`].
//! * counters — [`CacheStats`] hit/miss counters are surfaced in
//!   [`crate::mcts::SearchResult::eval_cache`] and aggregated by the
//!   parallel driver ([`crate::runtime::driver`]).

use crate::costmodel::CostModel;
use crate::schedule::trace::{fnv_str, fnv_u64};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use std::collections::HashMap;

/// Hit/miss counters for one cache (or an aggregate over many).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when never consulted).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fold another counter into this one (driver-level aggregation).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }
}

/// Canonical 64-bit key of a scheduled program on a target.
///
/// Mixes the trace's **cached running hash** (which already folds in every
/// transform-trace step: name, block, and the sampled decision string —
/// the trace records every decision, so it replays to exactly one
/// program), the workload identity, the target, and the schedule's
/// **lazily cached** structural fingerprint (which disambiguates the rare
/// trace renderings that don't pin the structure, e.g. two reads of the
/// same buffer).
///
/// # O(1) contract
///
/// This function is O(1) in trace depth and (amortized) in program size:
/// the per-step hashing happened incrementally at
/// [`Trace::push_step`](crate::schedule::trace::Trace::push_step) time and
/// the fingerprint is computed at most once per schedule instance
/// ([`Schedule::fingerprint`]), so a lookup touches two cached u64s plus
/// the workload and target names. Nothing here iterates over trace steps
/// — keep it that way: the search performs several key computations per
/// MCTS iteration, and O(depth) keys make aggregate work along a path
/// quadratic.
pub fn trace_key(s: &Schedule, target: Target) -> u64 {
    let mut h = s.trace.running_hash();
    h = fnv_str(h, &s.workload.name);
    h = fnv_str(h, target.name());
    fnv_u64(h, s.fingerprint())
}

/// Key of one cost-model prediction: program key + cost-model identity
/// (its seed salt) + retraining generation. Predictions are pure between
/// retrains, so this triple fully determines the value.
pub type PredKey = (u64, u64, usize);

/// Bounded transposition cache over ground-truth latencies and cost-model
/// predictions. See the module docs for the soundness argument and knobs.
#[derive(Clone, Debug)]
pub struct EvalCache {
    lat: HashMap<u64, f64>,
    pred: HashMap<PredKey, f64>,
    stats: CacheStats,
    max_entries: usize,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl EvalCache {
    /// Default per-map entry bound: generous for multi-thousand-sample
    /// searches, small next to the tree itself. An entry is a u64 (or
    /// `PredKey` triple) key plus an f64 value — roughly 16–32 B of
    /// payload, which `HashMap`'s open-addressing table grows to ~1.5–2×
    /// with control bytes and load-factor slack — so a full latency map
    /// at this bound costs on the order of 10 MB, not the "~16 B/entry"
    /// naive figure.
    pub const DEFAULT_CAPACITY: usize = 1 << 18;

    pub fn new() -> EvalCache {
        EvalCache::default()
    }

    /// Cache with an explicit per-map entry bound. Once a map is full, new
    /// values are computed and returned but not inserted.
    pub fn with_capacity(max_entries: usize) -> EvalCache {
        EvalCache {
            lat: HashMap::new(),
            pred: HashMap::new(),
            stats: CacheStats::default(),
            max_entries,
        }
    }

    /// Total entries currently held (both maps).
    pub fn len(&self) -> usize {
        self.lat.len() + self.pred.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lat.is_empty() && self.pred.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Reset the hit/miss counters (entries are kept) — used when one
    /// shared cache serves several searches that each report their own
    /// stats.
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Drop prediction entries not belonging to the cost model with the
    /// given identity `salt`. Prediction keys are per model instance, so
    /// when a shared cache is adopted by a new search, prior searches'
    /// entries are unreachable — pruning them keeps the map from filling
    /// up with dead entries (which would eventually block inserts).
    pub fn retain_predictions_of(&mut self, salt: u64) {
        self.pred.retain(|k, _| k.1 == salt);
    }

    /// Ground-truth latency for `key`, computing (and caching) via `f` on
    /// a miss.
    pub fn latency_or(&mut self, key: u64, f: impl FnOnce() -> f64) -> f64 {
        self.latency_or_served(key, f).0
    }

    /// Like [`EvalCache::latency_or`], but also reports whether the value
    /// was served from the cache (`true` = hit, `f` never ran). This is
    /// the authoritative hit signal for callers that account for the cost
    /// of running `f` — it is returned from the lookup itself rather than
    /// inferred from counter deltas, so it stays correct no matter how
    /// many other cache interactions surround the call.
    pub fn latency_or_served(&mut self, key: u64, f: impl FnOnce() -> f64) -> (f64, bool) {
        if let Some(&v) = self.lat.get(&key) {
            self.stats.hits += 1;
            return (v, true);
        }
        self.stats.misses += 1;
        let v = f();
        if self.lat.len() < self.max_entries {
            self.lat.insert(key, v);
        }
        (v, false)
    }

    /// Cost-model predicted latency for `key`, computing (and caching) via
    /// `f` on a miss.
    pub fn prediction_or(&mut self, key: PredKey, f: impl FnOnce() -> f64) -> f64 {
        if let Some(&v) = self.pred.get(&key) {
            self.stats.hits += 1;
            return v;
        }
        self.stats.misses += 1;
        let v = f();
        if self.pred.len() < self.max_entries {
            self.pred.insert(key, v);
        }
        v
    }
}

/// Outcome of one ground-truth measurement: the latency plus whether the
/// shared cache served it. When `cache_hit` is true no simulator (i.e.
/// simulated compile-and-run harness) invocation happened, so callers
/// accounting for harness wall-clock must not charge measurement overhead
/// for it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Measured {
    pub latency_s: f64,
    pub cache_hit: bool,
}

/// The single surface through which the search engine evaluates programs.
/// See the module docs.
pub trait Evaluator {
    /// Ground-truth measurement: evaluate on the hardware model, feed the
    /// learned cost model, advance the incumbent. Reports the latency (s)
    /// and whether the cache served it (see [`Measured`]) — the cost
    /// model is fed either way, the harness overhead only on a miss.
    fn measure(&mut self, s: &Schedule) -> Measured;

    /// Ground-truth latency *without* training — the deterministic oracle
    /// used in expansion and rollout scoring, served through the cache.
    fn true_latency(&mut self, s: &Schedule) -> f64;

    /// Normalized predicted performance score in [0, 1] from the learned
    /// cost model (higher = better), with per-generation prediction
    /// caching.
    fn score(&mut self, s: &Schedule) -> f64;

    /// Best (lowest) measured latency seen so far.
    fn best_latency(&self) -> f64;

    /// The evaluation target.
    fn target(&self) -> Target;

    /// Cache hit/miss counters accumulated so far.
    fn cache_stats(&self) -> CacheStats;
}

/// Production [`Evaluator`]: learned cost model + hardware simulator,
/// fronted by an [`EvalCache`].
pub struct CachedEvaluator {
    pub cost: CostModel,
    pub sim: Simulator,
    pub cache: EvalCache,
}

impl CachedEvaluator {
    pub fn new(cost: CostModel, sim: Simulator) -> CachedEvaluator {
        CachedEvaluator::with_cache(cost, sim, EvalCache::default())
    }

    /// Use an externally owned cache (shared across searches). Stale
    /// prediction entries from other cost-model instances are pruned and
    /// the hit/miss counters reset — entries persist across searches, but
    /// each search reports only its own counters; ground-truth latency
    /// entries — the shareable part — are kept.
    pub fn with_cache(cost: CostModel, sim: Simulator, mut cache: EvalCache) -> CachedEvaluator {
        cache.retain_predictions_of(cost.salt);
        cache.reset_stats();
        CachedEvaluator { cost, sim, cache }
    }

    /// Hand the cache back (e.g. to reuse it for a follow-up search).
    pub fn into_cache(self) -> EvalCache {
        self.cache
    }
}

impl Evaluator for CachedEvaluator {
    fn measure(&mut self, s: &Schedule) -> Measured {
        let key = trace_key(s, self.sim.target);
        let sim = &self.sim;
        let (lat, cache_hit) = self.cache.latency_or_served(key, || sim.latency(s));
        self.cost.observe(s, lat);
        Measured {
            latency_s: lat,
            cache_hit,
        }
    }

    fn true_latency(&mut self, s: &Schedule) -> f64 {
        let key = trace_key(s, self.sim.target);
        let sim = &self.sim;
        self.cache.latency_or(key, || sim.latency(s))
    }

    fn score(&mut self, s: &Schedule) -> f64 {
        let pred = match self.cost.generation() {
            Some(gen) => {
                let key = (trace_key(s, self.sim.target), self.cost.salt, gen);
                let cost = &self.cost;
                self.cache.prediction_or(key, || cost.predict_latency(s))
            }
            // before the first fit, predictions track the latest
            // observation and aren't pure — don't cache them
            None => self.cost.predict_latency(s),
        };
        self.cost.score_of_prediction(pred)
    }

    fn best_latency(&self) -> f64 {
        self.cost.best_latency
    }

    fn target(&self) -> Target {
        self.sim.target
    }

    fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn base() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)))
    }

    #[test]
    fn key_is_stable_across_calls_and_clones() {
        let mut rng = Rng::new(1);
        let s = apply(&base(), TransformKind::TileSize, &mut rng, false).unwrap();
        let k1 = trace_key(&s, Target::Cpu);
        let k2 = trace_key(&s, Target::Cpu);
        let k3 = trace_key(&s.clone(), Target::Cpu);
        assert_eq!(k1, k2);
        assert_eq!(k1, k3);
    }

    #[test]
    fn key_distinguishes_targets_and_traces() {
        let mut rng = Rng::new(2);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::Vectorize, &mut rng, false).unwrap();
        assert_ne!(trace_key(&s0, Target::Cpu), trace_key(&s0, Target::Gpu));
        assert_ne!(trace_key(&s0, Target::Cpu), trace_key(&s1, Target::Cpu));
    }

    #[test]
    fn hit_on_identical_trace() {
        let mut rng = Rng::new(3);
        let s = apply(&base(), TransformKind::Parallel, &mut rng, false).unwrap();
        let mut ev = CachedEvaluator::new(
            CostModel::new(Target::Cpu, 7),
            Simulator::new(Target::Cpu),
        );
        let a = ev.true_latency(&s);
        let b = ev.true_latency(&s.clone());
        assert_eq!(a, b);
        let stats = ev.cache_stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn miss_on_divergent_trace() {
        let mut rng = Rng::new(4);
        let s0 = base();
        let s1 = apply(&s0, TransformKind::Unroll, &mut rng, false).unwrap();
        let s2 = apply(&s1, TransformKind::Vectorize, &mut rng, false).unwrap();
        let mut ev = CachedEvaluator::new(
            CostModel::new(Target::Cpu, 8),
            Simulator::new(Target::Cpu),
        );
        ev.true_latency(&s0);
        ev.true_latency(&s1);
        ev.true_latency(&s2);
        let stats = ev.cache_stats();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 3);
    }

    #[test]
    fn measure_trains_but_caches_ground_truth() {
        let s = base();
        let sim = Simulator::new(Target::Cpu);
        let expect = sim.latency(&s);
        let mut ev = CachedEvaluator::new(CostModel::new(Target::Cpu, 9), sim);
        let a = ev.measure(&s);
        let b = ev.measure(&s);
        assert_eq!(a.latency_s, expect);
        assert_eq!(b.latency_s, expect);
        // measure reports what actually happened at the harness level:
        // the first run hit the simulator, the repeat was cache-served
        assert!(!a.cache_hit);
        assert!(b.cache_hit);
        // both measures still fed the cost model, only the sim run was
        // deduplicated
        assert_eq!(ev.cost.n_measured, 2);
        assert_eq!(ev.cache_stats().hits, 1);
    }

    #[test]
    fn trace_key_reads_cached_hashes() {
        // trace_key must be a pure function of (running trace hash,
        // workload, target, fingerprint) — recomputing it on a clone that
        // shares the trace nodes gives the identical key, and a trace
        // rebuilt from the same decisions (fresh nodes, same strings) too.
        let mut rng_a = Rng::new(12);
        let mut rng_b = Rng::new(12);
        let a = apply(&base(), TransformKind::TileSize, &mut rng_a, false).unwrap();
        let b = apply(&base(), TransformKind::TileSize, &mut rng_b, false).unwrap();
        assert_eq!(a.trace.running_hash(), b.trace.running_hash());
        assert_eq!(trace_key(&a, Target::Cpu), trace_key(&b, Target::Cpu));
        // a divergent decision changes the running hash and therefore the
        // key — built deterministically so the assertion always runs
        let mut c = a.clone();
        c.trace.push("sample_perfect_tile", "matmul", "loop=i, decision=[2, 128]".into());
        assert_ne!(c.trace.running_hash(), a.trace.running_hash());
        assert_ne!(trace_key(&a, Target::Cpu), trace_key(&c, Target::Cpu));
    }

    #[test]
    fn capacity_zero_disables_insertion_but_not_correctness() {
        let s = base();
        let sim = Simulator::new(Target::Cpu);
        let mut ev = CachedEvaluator::with_cache(
            CostModel::new(Target::Cpu, 10),
            sim,
            EvalCache::with_capacity(0),
        );
        let a = ev.true_latency(&s);
        let b = ev.true_latency(&s);
        assert_eq!(a, b);
        assert_eq!(ev.cache_stats().hits, 0);
        assert_eq!(ev.cache_stats().misses, 2);
        assert!(ev.cache.is_empty());
    }

    #[test]
    fn stats_merge_and_reset() {
        let mut a = CacheStats { hits: 2, misses: 3 };
        let b = CacheStats { hits: 1, misses: 0 };
        a.merge(&b);
        assert_eq!(a, CacheStats { hits: 3, misses: 3 });
        let mut c = EvalCache::new();
        c.latency_or(1, || 1.0);
        c.reset_stats();
        assert_eq!(c.stats(), CacheStats::default());
        assert!(!c.is_empty());
    }
}

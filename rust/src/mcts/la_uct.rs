//! LA-UCT — the LLM-aware UCT tree policy (paper §2.3, Appendix A).
//!
//! For a child with visit count N, cumulative normalized reward W,
//! assigned model llm, and parent visit count Np:
//!
//! ```text
//! LA-UCT = (1-λ)·W/N + λ·φ_small(llm) + c·√(ln Np / N)
//! ```
//!
//! which (Appendix A) is UCB1 on the transformed reward
//! `(1-λ)R + λφ_small`, concentrating visits on children maximizing the
//! surrogate mean `(1-λ)μ + λφ_small` — smaller models are favored when
//! their downstream reward is competitive; a larger model still wins when
//! its expected reward overcomes the size-preference term.

/// One child's statistics, as seen by the tree policy.
#[derive(Clone, Copy, Debug)]
pub struct ChildStats {
    pub visits: f64,
    pub reward_sum: f64,
    pub phi_small: f64,
}

/// The LA-UCT score. Unvisited children score +inf (must-visit).
pub fn la_uct(child: &ChildStats, parent_visits: f64, lambda: f64, c: f64) -> f64 {
    if child.visits < 1.0 {
        return f64::INFINITY;
    }
    let exploit = (1.0 - lambda) * (child.reward_sum / child.visits)
        + lambda * child.phi_small;
    let explore = c * ((parent_visits.max(1.0)).ln() / child.visits).sqrt();
    exploit + explore
}

/// Index of the LA-UCT-maximal child among `children`.
///
/// Ties break deterministically to the lowest index (strict `>`), so a
/// search replayed from the same seed always descends the same path; a
/// NaN score never replaces the incumbent.
pub fn select(children: &[ChildStats], parent_visits: f64, lambda: f64, c: f64) -> usize {
    let mut best = 0;
    let mut best_score = f64::NEG_INFINITY;
    for (i, ch) in children.iter().enumerate() {
        let s = la_uct(ch, parent_visits, lambda, c);
        if s > best_score {
            best_score = s;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn ch(visits: f64, mean_r: f64, phi: f64) -> ChildStats {
        ChildStats {
            visits,
            reward_sum: mean_r * visits,
            phi_small: phi,
        }
    }

    #[test]
    fn unvisited_first() {
        let kids = [ch(5.0, 0.9, 0.0), ch(0.0, 0.0, 0.0)];
        assert_eq!(select(&kids, 5.0, 0.5, 1.4), 1);
    }

    #[test]
    fn lambda_zero_is_reward_only_uct() {
        // equal phi irrelevance at lambda=0
        let a = ch(10.0, 0.8, 0.0);
        let b = ch(10.0, 0.6, 1.0);
        assert_eq!(select(&[a, b], 20.0, 0.0, 0.0), 0);
    }

    #[test]
    fn lambda_one_prefers_small_models() {
        let a = ch(10.0, 0.9, 0.0); // big model, great reward
        let b = ch(10.0, 0.1, 1.0); // tiny model, poor reward
        assert_eq!(select(&[a, b], 20.0, 1.0, 0.0), 1);
    }

    #[test]
    fn big_model_wins_when_reward_gap_large() {
        // λ=0.5: a needs reward advantage > phi advantage
        let a = ch(10.0, 0.95, 0.0);
        let b = ch(10.0, 0.2, 0.6);
        assert_eq!(select(&[a, b], 20.0, 0.5, 0.0), 0);
    }

    #[test]
    fn exploration_term_lifts_undervisited() {
        let a = ch(1000.0, 0.6, 0.5);
        let b = ch(2.0, 0.55, 0.5);
        // big c: exploration dominates
        assert_eq!(select(&[a, b], 1002.0, 0.5, 3.0), 1);
    }

    #[test]
    fn tie_breaking_deterministic_across_seeds() {
        // equal-scored children must always resolve to the lowest index,
        // however the (identical) stats were produced
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed);
            let visits = 1.0 + (rng.next_u64() % 50) as f64;
            let mean_r = rng.f64();
            let phi = rng.f64();
            let kids = vec![ch(visits, mean_r, phi); 4];
            assert_eq!(select(&kids, 4.0 * visits, 0.5, 1.4), 0, "seed {seed}");
        }
        // several unvisited children (all +inf) also tie-break to index 0
        let kids = [ch(0.0, 0.0, 0.1), ch(0.0, 0.0, 0.9), ch(0.0, 0.0, 0.5)];
        assert_eq!(select(&kids, 3.0, 0.5, 1.4), 0);
    }

    #[test]
    fn nan_scores_never_win_and_never_panic() {
        let nan = ch(10.0, f64::NAN, 0.0);
        let ok = ch(10.0, 0.2, 0.0);
        // NaN first: falls through to the finite child
        assert_eq!(select(&[nan, ok], 20.0, 0.0, 0.0), 1);
        // all-NaN: still returns a valid index
        assert_eq!(select(&[nan, nan], 20.0, 0.0, 0.0), 0);
    }

    #[test]
    fn asymptotic_concentration_on_surrogate_max() {
        // simulate UCB1 bandit on transformed reward; arm 1 has the best
        // surrogate mean — it must receive the majority of pulls
        let mut rng = Rng::new(1);
        let lambda = 0.5;
        let c = 2f64.sqrt();
        let mu = [0.5, 0.7, 0.3];
        let phi = [0.2, 0.6, 0.9];
        let mut kids: Vec<ChildStats> = phi
            .iter()
            .map(|&p| ChildStats {
                visits: 0.0,
                reward_sum: 0.0,
                phi_small: p,
            })
            .collect();
        let mut parent = 0.0;
        for _ in 0..4000 {
            let i = select(&kids, parent, lambda, c);
            let r = (mu[i] + rng.normal_ms(0.0, 0.1)).clamp(0.0, 1.0);
            kids[i].visits += 1.0;
            kids[i].reward_sum += r;
            parent += 1.0;
        }
        // surrogate means: 0.35, 0.65, 0.60 -> arm 1 wins
        assert!(
            kids[1].visits > kids[0].visits && kids[1].visits > kids[2].visits,
            "visits {:?}",
            kids.iter().map(|k| k.visits).collect::<Vec<_>>()
        );
        assert!(kids[1].visits > 2000.0);
    }
}

//! Deterministic keyed-union merging of root-parallel search trees.
//!
//! Root-parallel distributed search runs N independent lanes of the same
//! scenario — same workload, target, and search configuration, distinct
//! RNG seeds — then folds their trees back into **one** resumable engine,
//! preserving the single-shared-tree semantics the paper's cross-model
//! value propagation depends on. Nodes are matched across lanes by their
//! O(1) canonical trace key ([`super::evalcache::trace_key`]: the trace's
//! cached running hash folded with workload, target, and the structural
//! fingerprint), so two lanes that discovered the same program through
//! the same transform history share one merged node.
//!
//! ## The merge algebra
//!
//! The merge is an honest-to-goodness commutative, associative operation
//! **up to bit equality of the canonical re-serialization**
//! ([`Mcts::snapshot`]), which the merge-algebra property tests lock:
//!
//! * **Canonical lane order.** Lanes are sorted by `cfg.seed` before
//!   anything else, and duplicate seeds are an `Err` — every tie-break
//!   below falls back to the seed, giving each comparison a strict total
//!   order.
//! * **Grid-quantized sums.** Every summed f64 (node visits and reward
//!   sums, per-model cost/latency/token totals, measurement time) is
//!   first snapped to the dyadic grid 2⁻²⁶ by [`qgrid`]. Grid values of
//!   magnitude below 2²⁷ are exactly representable and close under
//!   addition, so grid sums are exact and therefore order-independent,
//!   and `qgrid` is idempotent on its own outputs — nested merges
//!   re-quantize without drift. That is what upgrades "commutative up to
//!   float error" to bitwise commutative *and* associative.
//! * **Winner lane.** The incumbent is the best (lowest measured
//!   latency) across lanes; the winning lane — min by `(best_latency,
//!   seed)` — also donates the pieces that cannot be meaningfully
//!   averaged: the RNG stream, the trained cost model (with its
//!   salt-keyed prediction-cache entries), the routing pointer, the
//!   parallel round counter, and the merged `cfg.seed`.
//! * **Maxima / minima / unions** everywhere else: per-node
//!   `predicted_score` is the max, model assignment the min,
//!   `measured`/`pruned` are ORs; the speedup curve is the running max
//!   of the pointwise max over the union of sample coordinates;
//!   checkpoints are the sorted deduped union; sample counts, budgets,
//!   course-alteration, error, and lint-reject tallies are sums.
//! * **Identity.** A single-lane merge is a pure passthrough — no
//!   quantization, no reordering — so `merge([run]) ≡ run` bit-for-bit,
//!   and merging against skipped (missing/corrupt) lanes degrades to
//!   exactly the healthy-lanes merge.
//!
//! Schedules of merged nodes are **canonically rebuilt** parent-first:
//! each node's schedule is its merged parent's clone plus the trace
//! steps and content-changed blocks of its first contributor (in
//! canonical lane order). Copy-on-write block `Arc`s therefore encode
//! *content* change relative to the parent — not which lane happened to
//! allocate them — which is what makes the snapshot's delta encoding a
//! pure function of merged tree content.
//!
//! Merged trees can hold more than `cfg.branching` children per node
//! (the union of each lane's children). The engine never grows such a
//! node further — selection only expands nodes with spare branching
//! capacity — so continued search remains well-defined; see the
//! branching invariant in [`Mcts::run_parallel_until`]'s round loop.
//!
//! Lint-reject accounting caveat: a lane's running total reads the
//! per-thread analyzer counter, so `merge_engines` over engines that ran
//! *interleaved on the calling thread* attributes rejections to every
//! lane constructed before them. Lane totals stay deterministic (the
//! algebra holds), but for exact fleet tallies merge through snapshots
//! ([`merge_snapshot_files`]), where each lane's total was fixed at
//! snapshot time — which is what the distributed driver does.

use super::evalcache::{trace_key, CachedEvaluator, EvalCache};
use super::{Mcts, Node};
use crate::costmodel::ScoreScratch;
use crate::llm::{CallKind, ModelSet, ModelStats};
use crate::schedule::Schedule;
use crate::sim::Simulator;
use crate::util::Json;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::{Arc, OnceLock};

/// 2²⁶ — the merge's dyadic quantization grid.
const GRID: f64 = 67_108_864.0;

/// Snap a summed statistic to the 2⁻²⁶ dyadic grid. Grid values below
/// 2²⁷ in magnitude (comfortably covering visit counts, rewards in
/// [0, 1.5], dollar and second totals) are exactly representable, and
/// f64 addition of exactly representable results is exact — so sums of
/// quantized values are order-independent and `qgrid` is idempotent on
/// them. The foundation of the merge's bitwise associativity.
fn qgrid(x: f64) -> f64 {
    (x * GRID).round() / GRID
}

/// Strict total order for `expanded_by` provenance: `None` (the root /
/// synthetic nodes) sorts first, then by model index, then call kind.
fn exp_rank(e: Option<(usize, CallKind)>) -> (u8, usize, u8) {
    match e {
        None => (0, 0, 0),
        Some((m, k)) => (
            1,
            m,
            match k {
                CallKind::Regular => 0,
                CallKind::CourseAlteration => 1,
            },
        ),
    }
}

/// What a fleet merge did — surfaced by the distributed driver and the
/// corruption tests.
#[derive(Clone, Debug)]
pub struct MergeReport {
    /// Healthy lanes that contributed to the merged tree.
    pub lanes_merged: usize,
    /// `(path, reason)` for every lane snapshot skipped by the degrading
    /// loader (missing file, parse error, version mismatch, arena
    /// validation failure, ...).
    pub skipped: Vec<(String, String)>,
    /// Node count of the merged tree.
    pub n_nodes: usize,
    /// Merged incumbent speedup (best across lanes).
    pub best_speedup: f64,
}

/// Sanity checks that make a merge meaningful: all lanes must be
/// searches of the same scenario under the same configuration (only the
/// seed streams — and consequently trees, stats, and caches — differ).
fn check_consistent(lanes: &[Mcts]) -> Result<(), String> {
    let a = &lanes[0];
    let wname = a.nodes[0].schedule.workload.name.as_str();
    let tname = a.eval.sim.target().name();
    for e in &lanes[1..] {
        if e.nodes[0].schedule.workload.name != wname {
            return Err(format!(
                "tree merge: workload mismatch ({} vs {wname})",
                e.nodes[0].schedule.workload.name
            ));
        }
        if e.eval.sim.target().name() != tname {
            return Err(format!(
                "tree merge: target mismatch ({} vs {tname})",
                e.eval.sim.target().name()
            ));
        }
        if e.cfg.branching != a.cfg.branching
            || e.cfg.lambda.to_bits() != a.cfg.lambda.to_bits()
            || e.cfg.exploration_c.to_bits() != a.cfg.exploration_c.to_bits()
            || e.cfg.rollout_depth != a.cfg.rollout_depth
            || e.cfg.ca_threshold != a.cfg.ca_threshold
            || e.cfg.routing != a.cfg.routing
            || e.cfg.measure_interval != a.cfg.measure_interval
            || e.cfg.measure_top_k != a.cfg.measure_top_k
            || e.cfg.measure_overhead_s.to_bits() != a.cfg.measure_overhead_s.to_bits()
        {
            return Err("tree merge: lane search configurations differ".to_string());
        }
        if e.models.specs.len() != a.models.specs.len()
            || e.models.specs.iter().zip(&a.models.specs).any(|(x, y)| x.name != y.name)
        {
            return Err("tree merge: lane model rosters differ".to_string());
        }
        if e.baseline_latency.to_bits() != a.baseline_latency.to_bits() {
            return Err("tree merge: lane baseline latencies differ".to_string());
        }
    }
    Ok(())
}

/// Combine one group of matched nodes (same canonical key, same
/// per-parent occurrence) into a merged node. `contribs` is `(lane,
/// node)` in canonical lane order; `out` holds the already-built merged
/// ancestors (the parent's canonical schedule is rebuilt against).
fn combine_node(lanes: &[Mcts], contribs: &[(usize, usize)], parent: Option<usize>, out: &[Node]) -> Node {
    let (l0, n0) = contribs[0];
    let src = &lanes[l0].nodes[n0];
    // canonical schedule rebuild: parent clone + the first contributor's
    // trace extension, sharing the parent's block Arcs wherever the
    // content is unchanged (see the module docs)
    let sched = match parent {
        None => Schedule::initial(Arc::clone(&src.schedule.workload)),
        Some(p) => {
            let base: &Schedule = &out[p].schedule;
            let mut s = base.clone();
            let steps = src.schedule.trace.steps();
            debug_assert!(
                steps.len() >= base.trace.len(),
                "matched child trace must extend its merged parent's"
            );
            for st in steps.into_iter().skip(base.trace.len()) {
                s.trace.push_step(st);
            }
            for b in 0..s.blocks.len() {
                if *src.schedule.blocks[b] != *s.blocks[b] {
                    *s.block_mut(b) = (*src.schedule.blocks[b]).clone();
                }
            }
            s
        }
    };
    let mut visits = 0.0f64;
    let mut reward_sum = 0.0f64;
    let mut predicted_score = f64::NEG_INFINITY;
    let mut llm = usize::MAX;
    let mut expanded_by = src.expanded_by;
    let mut regression_chain = usize::MAX;
    let mut pruned = false;
    let mut measured = false;
    for &(l, n) in contribs {
        let nd = &lanes[l].nodes[n];
        debug_assert_eq!(nd.depth, src.depth, "matched nodes must share a depth");
        visits += qgrid(nd.visits);
        reward_sum += qgrid(nd.reward_sum);
        if nd.predicted_score.total_cmp(&predicted_score).is_gt() {
            predicted_score = nd.predicted_score;
        }
        llm = llm.min(nd.llm);
        if exp_rank(nd.expanded_by) < exp_rank(expanded_by) {
            expanded_by = nd.expanded_by;
        }
        regression_chain = regression_chain.min(nd.regression_chain);
        pruned |= nd.pruned;
        measured |= nd.measured;
    }
    Node {
        parent,
        children: Vec::new(),
        schedule: Arc::new(sched),
        code: OnceLock::new(),
        trace_tail: OnceLock::new(),
        llm,
        visits,
        reward_sum,
        predicted_score,
        expanded_by,
        depth: parent.map_or(0, |p| out[p].depth + 1),
        regression_chain,
        pruned,
        measured,
        virtual_loss: 0.0,
        pending_children: 0,
    }
}

/// Merge N root-parallel lanes of one scenario into a single resumable
/// engine. See the module docs for the full algebra. `Err` on an empty
/// lane list, duplicate lane seeds, or configuration/scenario mismatch;
/// a single lane is returned unchanged (the merge identity).
pub fn merge_engines(mut lanes: Vec<Mcts>) -> Result<Mcts, String> {
    if lanes.is_empty() {
        return Err("tree merge: no lanes to merge".to_string());
    }
    if lanes.len() == 1 {
        return Ok(lanes.pop().expect("len checked"));
    }
    lanes.sort_by_key(|e| e.cfg.seed);
    for w in lanes.windows(2) {
        if w[0].cfg.seed == w[1].cfg.seed {
            return Err(format!(
                "tree merge: duplicate lane seed {} (lanes must use distinct seed streams)",
                w[0].cfg.seed
            ));
        }
    }
    check_consistent(&lanes)?;

    // winner lane: best incumbent, seed-ascending tie-break (lanes are
    // already seed-sorted, so keeping the earlier lane on ties is exact)
    let winner = (1..lanes.len()).fold(0usize, |w, i| {
        if lanes[i].best_latency.total_cmp(&lanes[w].best_latency).is_lt() {
            i
        } else {
            w
        }
    });
    let winner_best = lanes[winner]
        .nodes
        .iter()
        .position(|n| Arc::ptr_eq(&n.schedule, &lanes[winner].best_schedule))
        .unwrap_or(0);

    // ---- keyed-union walk (BFS, so parents precede children and each
    // parent's children land at consecutive, sorted indices — the order
    // `Mcts::resume` rebuilds children lists in) -----------------------
    let target = lanes[0].eval.sim.target();
    let mut out: Vec<Node> = Vec::new();
    let mut merged_best = 0usize;
    let mut queue: VecDeque<(Option<usize>, Vec<(usize, usize)>)> = VecDeque::new();
    queue.push_back((None, (0..lanes.len()).map(|l| (l, 0usize)).collect()));
    while let Some((parent, contribs)) = queue.pop_front() {
        let idx = out.len();
        let node = combine_node(&lanes, &contribs, parent, &out);
        if let Some(p) = parent {
            out[p].children.push(idx);
        }
        if contribs.iter().any(|&(l, n)| l == winner && n == winner_best) {
            merged_best = idx;
        }
        out.push(node);
        // group the contributors' children by (canonical trace key,
        // occurrence among same-key siblings): pairing each lane's j-th
        // same-key child with every other lane's j-th keeps the grouping
        // stable under nested merges, and the (key, occurrence) sort
        // fixes the canonical child order
        let mut kids: Vec<(u64, usize, usize, usize)> = Vec::new();
        for &(l, n) in &contribs {
            let mut occ: HashMap<u64, usize> = HashMap::new();
            for &c in &lanes[l].nodes[n].children {
                let k = trace_key(&lanes[l].nodes[c].schedule, target);
                let e = occ.entry(k).or_insert(0usize);
                kids.push((k, *e, l, c));
                *e += 1;
            }
        }
        kids.sort_by(|a, b| (a.0, a.1, a.2).cmp(&(b.0, b.1, b.2)));
        let mut i = 0usize;
        while i < kids.len() {
            let (k, o, ..) = kids[i];
            let mut group: Vec<(usize, usize)> = Vec::new();
            while i < kids.len() && kids[i].0 == k && kids[i].1 == o {
                group.push((kids[i].2, kids[i].3));
                i += 1;
            }
            queue.push_back((Some(idx), group));
        }
    }

    // ---- scalar engine state -----------------------------------------
    let samples: usize = lanes.iter().map(|e| e.samples).sum();
    let budget: usize = lanes.iter().map(|e| e.cfg.budget).sum();
    let n_ca_events: usize = lanes.iter().map(|e| e.n_ca_events).sum();
    let n_errors: usize = lanes.iter().map(|e| e.n_errors).sum();
    let measure_time_s: f64 = lanes.iter().map(|e| qgrid(e.measure_time_s)).sum();
    let max_depth = lanes.iter().map(|e| e.max_depth).max().expect("non-empty");
    let best_latency = lanes[winner].best_latency;
    let baseline_latency = lanes[0].baseline_latency;
    let lint_total: u64 = lanes
        .iter()
        .map(|e| {
            e.lint_rejects_base
                + crate::analysis::lint_rejects().saturating_sub(e.lint_rejects_at_start)
        })
        .sum();

    // speedup curve: running max of the pointwise max over the union of
    // sample coordinates (each lane's curve is already nondecreasing)
    let mut pts: BTreeMap<usize, f64> = BTreeMap::new();
    for e in &lanes {
        for &(s, v) in &e.curve {
            pts.entry(s)
                .and_modify(|cur| {
                    if v.total_cmp(cur).is_gt() {
                        *cur = v;
                    }
                })
                .or_insert(v);
        }
    }
    let mut curve: Vec<(usize, f64)> = Vec::with_capacity(pts.len());
    let mut run = f64::NEG_INFINITY;
    for (s, v) in pts {
        if v.total_cmp(&run).is_gt() {
            run = v;
        }
        curve.push((s, run));
    }

    let mut checkpoints: Vec<usize> =
        lanes.iter().flat_map(|e| e.cfg.checkpoints.iter().copied()).collect();
    checkpoints.sort_unstable();
    checkpoints.dedup();
    let checkpoint_cursor = checkpoints.iter().filter(|&&c| c <= samples).count();

    let unmeasured: Vec<usize> = out
        .iter()
        .enumerate()
        .filter(|(_, n)| !n.measured)
        .map(|(i, _)| i)
        .collect();

    // per-model stats: usize tallies summed, f64 totals grid-summed, in
    // canonical lane order
    let mut models: ModelSet = lanes[winner].models.clone();
    for m in 0..models.stats.len() {
        let mut st = ModelStats {
            regular_calls: 0,
            regular_hits: 0,
            ca_calls: 0,
            ca_hits: 0,
            errors: 0,
            total_cost_usd: 0.0,
            total_latency_s: 0.0,
            tokens_in: 0.0,
            tokens_out: 0.0,
        };
        for e in &lanes {
            let s = &e.models.stats[m];
            st.regular_calls += s.regular_calls;
            st.regular_hits += s.regular_hits;
            st.ca_calls += s.ca_calls;
            st.ca_hits += s.ca_hits;
            st.errors += s.errors;
            st.total_cost_usd += qgrid(s.total_cost_usd);
            st.total_latency_s += qgrid(s.total_latency_s);
            st.tokens_in += qgrid(s.tokens_in);
            st.tokens_out += qgrid(s.tokens_out);
        }
        models.stats[m] = st;
    }

    // fault accounting: counters summed, f64 charges grid-summed, in
    // canonical lane order; the winner's clone already donated the fault
    // *plan* (rates + stream position — a stream, like the RNG, cannot be
    // meaningfully averaged)
    let mut fr = crate::llm::faults::FaultReport::default();
    for e in &lanes {
        let f = &e.models.fault_report;
        fr.timeouts += f.timeouts;
        fr.rate_limits += f.rate_limits;
        fr.transients += f.transients;
        fr.malformed += f.malformed;
        fr.retries += f.retries;
        fr.fallbacks += f.fallbacks;
        fr.forced += f.forced;
        fr.backoff_latency_s += qgrid(f.backoff_latency_s);
        fr.fault_latency_s += qgrid(f.fault_latency_s);
        fr.fault_cost_usd += qgrid(f.fault_cost_usd);
    }
    models.fault_report = fr;

    let best_schedule = Arc::clone(&out[merged_best].schedule);

    // consume the lanes: the winner donates config, RNG, cost model (and
    // its salt-keyed prediction entries); every other lane's cache is
    // federated in canonical order (ground-truth union + summed counters)
    let mut winner_parts = None;
    let mut other_caches: Vec<EvalCache> = Vec::new();
    for (i, e) in lanes.into_iter().enumerate() {
        if i == winner {
            winner_parts = Some((e.cfg, e.eval, e.rng, e.rr_ptr, e.round));
        } else {
            other_caches.push(e.eval.cache);
        }
    }
    let (mut cfg, eval, rng, rr_ptr, round) = winner_parts.expect("winner in range");
    cfg.budget = budget;
    cfg.checkpoints = checkpoints.clone();
    let CachedEvaluator { cost, sim, cache: mut merged_cache, scratch: _ } = eval;
    for c in other_caches {
        merged_cache.federate(c);
    }

    Ok(Mcts {
        cfg,
        models,
        eval: CachedEvaluator {
            cost,
            sim,
            cache: merged_cache,
            scratch: ScoreScratch::default(),
        },
        nodes: out,
        rng,
        rr_ptr,
        samples,
        measure_time_s,
        n_ca_events,
        n_errors,
        best_latency,
        best_schedule,
        baseline_latency,
        unmeasured,
        curve,
        max_depth,
        checkpoints_sorted: checkpoints,
        checkpoint_cursor,
        sel_children: Vec::new(),
        sel_stats: Vec::new(),
        sel_path: Vec::new(),
        lint_rejects_at_start: crate::analysis::lint_rejects(),
        lint_rejects_base: lint_total,
        round,
    })
}

/// Degrading fleet merge over persisted lane snapshots: a missing,
/// unparseable, version-mismatched, or structurally invalid lane file is
/// **skipped with a stderr warning** — it never panics and never poisons
/// the surviving lanes, whose merge is bit-identical to a merge that
/// only ever saw the healthy files. `parts` supplies the process-local
/// pieces a snapshot cannot carry (fresh model set, simulator, initial
/// schedule), once per lane file. `Err` only when *no* lane resumes.
pub fn merge_snapshot_files<F>(paths: &[String], mut parts: F) -> Result<(Mcts, MergeReport), String>
where
    F: FnMut() -> (ModelSet, Simulator, Schedule),
{
    let mut healthy: Vec<Mcts> = Vec::new();
    let mut skipped: Vec<(String, String)> = Vec::new();
    for p in paths {
        if !std::path::Path::new(p).exists() {
            eprintln!("warning: lane snapshot {p}: missing; skipping lane");
            skipped.push((p.clone(), "missing".to_string()));
            continue;
        }
        let (models, sim, root) = parts();
        match Json::parse_file(p).and_then(|v| Mcts::resume(&v, models, sim, root)) {
            Ok(engine) => healthy.push(engine),
            Err(e) => {
                eprintln!("warning: lane snapshot {p}: {e}; skipping lane");
                skipped.push((p.clone(), e));
            }
        }
    }
    if healthy.is_empty() {
        return Err(format!(
            "tree merge: no healthy lane snapshots among {} paths",
            paths.len()
        ));
    }
    let lanes_merged = healthy.len();
    let merged = merge_engines(healthy)?;
    let report = MergeReport {
        lanes_merged,
        skipped,
        n_nodes: merged.nodes.len(),
        best_speedup: merged.best_speedup(),
    };
    Ok((merged, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::registry::paper_config;
    use crate::mcts::SearchConfig;
    use crate::sim::Target;
    use crate::workloads;

    fn lane(seed: u64, budget: usize) -> Mcts {
        let w = workloads::by_name("gemm").unwrap();
        let root = Schedule::initial(Arc::new(w));
        let cfg = SearchConfig {
            budget,
            seed,
            checkpoints: vec![budget / 2, budget],
            ..SearchConfig::default()
        };
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        Mcts::new(cfg, models, Simulator::new(Target::Cpu), root).run_until(budget)
    }

    #[test]
    fn qgrid_idempotent_and_exact() {
        for x in [0.0, 1.0, 0.3, 17.25, 123.456, 1e6, -2.5] {
            let q = qgrid(x);
            assert_eq!(q.to_bits(), qgrid(q).to_bits(), "qgrid not idempotent at {x}");
        }
        // grid sums are exact: associativity of quantized addition
        let (a, b, c) = (qgrid(0.1), qgrid(0.2), qgrid(0.3));
        assert_eq!(((a + b) + c).to_bits(), (a + (b + c)).to_bits());
    }

    #[test]
    fn single_lane_merge_is_identity() {
        let e = lane(3, 20);
        let snap = format!("{}", e.snapshot());
        let merged = merge_engines(vec![e]).unwrap();
        assert_eq!(snap, format!("{}", merged.snapshot()));
    }

    #[test]
    fn duplicate_seeds_rejected() {
        let (a, b) = (lane(5, 12), lane(5, 12));
        assert!(merge_engines(vec![a, b]).unwrap_err().contains("duplicate lane seed"));
    }

    #[test]
    fn merged_incumbent_is_best_across_lanes() {
        let (a, b) = (lane(1, 24), lane(2, 24));
        let best = a.best_speedup().max(b.best_speedup());
        let samples = a.samples() + b.samples();
        let merged = merge_engines(vec![a, b]).unwrap();
        assert_eq!(merged.best_speedup().to_bits(), best.to_bits());
        assert_eq!(merged.samples(), samples);
        assert!(merged.first_tree_deny().is_none());
        // the merged incumbent must be a live tree node (snapshot's
        // best_node lookup depends on Arc identity)
        assert!(merged
            .nodes
            .iter()
            .any(|n| Arc::ptr_eq(&n.schedule, &merged.best_schedule)));
    }

    #[test]
    fn merge_is_commutative_on_the_snapshot() {
        let ab = merge_engines(vec![lane(1, 16), lane(2, 16)]).unwrap();
        let ba = merge_engines(vec![lane(2, 16), lane(1, 16)]).unwrap();
        assert_eq!(format!("{}", ab.snapshot()), format!("{}", ba.snapshot()));
    }

    #[test]
    fn merged_tree_resumes_and_continues() {
        let merged = merge_engines(vec![lane(1, 16), lane(2, 16)]).unwrap();
        let snap = merged.snapshot();
        let w = workloads::by_name("gemm").unwrap();
        let root = Schedule::initial(Arc::new(w));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let mut resumed =
            Mcts::resume(&snap, models, Simulator::new(Target::Cpu), root).unwrap();
        assert_eq!(format!("{}", resumed.snapshot()), format!("{snap}"));
        let before = resumed.best_speedup();
        resumed.extend_budget(8);
        let done = resumed.run_until(usize::MAX);
        assert!(done.samples() >= 40, "merged search must keep sampling");
        assert!(done.best_speedup() >= before, "incumbent must stay monotone");
    }
}

//! Shared-tree MCTS with endogenous model selection — the paper's core
//! contribution (§2).
//!
//! Each node is a joint state ⟨program, llm⟩: the schedule reached so far
//! plus the model assigned to expand it. One iteration runs
//! selection (LA-UCT, [`la_uct`]) → expansion (the active LLM proposes a
//! joint ⟨transform-sequence, next-llm⟩ action) → rollout (random
//! transforms, cost-model scored) → backpropagation (reward credited along
//! the selected path, so signal discovered by one model informs all
//! others). Course alteration (§2.5) prunes persistent small-model
//! regressions and re-expands from the same parent with the largest model
//! under a shorter targeted prompt.

pub mod evalcache;
pub mod la_uct;
pub mod treemerge;
pub mod treestore;

use crate::costmodel::CostModel;
use crate::llm::faults::FaultReport;
use crate::llm::prompts::{PromptCtx, VariantCtx};
use crate::llm::{CallKind, ModelSet};
use crate::runtime::driver::WorkerPool;
use crate::schedule::printer::print_dominant;
use crate::schedule::transforms::{apply_sequence, TransformKind};
use crate::schedule::Schedule;
use crate::sim::Simulator;
use crate::util::Rng;
use evalcache::{
    CacheStats, CachedEvaluator, EvalCache, Evaluator, SharedCachedEvaluator, SharedEvalCache,
};
use std::sync::{Arc, OnceLock};

/// Next-model routing policy (Appendix G ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// The paper's mechanism: the active LLM proposes the next model.
    Endogenous,
    /// Ablation: uniform-random next model.
    Random,
    /// Ablation: fixed round-robin next model.
    RoundRobin,
}

/// Search configuration (paper §3.1 defaults).
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// LA-UCT size-preference weight λ (paper: 0.5).
    pub lambda: f64,
    /// UCT exploration constant c (paper: √2).
    pub exploration_c: f64,
    /// Tree branching factor B (paper: 2).
    pub branching: usize,
    /// Search budget in samples (expanded candidates).
    pub budget: usize,
    /// Random-transform rollout depth after expansion.
    pub rollout_depth: usize,
    /// Course alteration after this many consecutive small-model
    /// regressions on a path (paper: Some(2); Appendix F: Some(1)/None).
    pub ca_threshold: Option<usize>,
    /// Measure the top-K predicted candidates every this many samples.
    pub measure_interval: usize,
    pub measure_top_k: usize,
    /// Simulated harness time per measured candidate (compile+run).
    pub measure_overhead_s: f64,
    pub routing: Routing,
    pub seed: u64,
    /// Curve checkpoints (samples) at which best speedup is recorded.
    pub checkpoints: Vec<usize>,
    /// In-search tree parallelism: worker threads one search runs its
    /// leaf evaluations on ([`Mcts::run_parallel`]). `1` (the default) is
    /// the serial engine, bit-identical to [`Mcts::run`]; `t > 1` is
    /// deterministic for a fixed `(seed, t)` pair. Ignored by searchers
    /// with no tree (e.g. the evolutionary baseline).
    pub search_threads: usize,
    /// Warm-start seed for the evaluation cache: ground-truth entries
    /// cloned into the search's cache at construction (e.g. a
    /// `--cache-file` loaded by the driver). Shared by `Arc` so one
    /// loaded cache can seed a whole sweep without per-spec deep copies;
    /// each search clones the entries out, so searches stay independent
    /// and results stay a pure function of (config, warm entries).
    /// `None` (the default) starts cold. An explicit cache handed to
    /// [`Mcts::with_cache`] takes precedence over this field. Warm
    /// entries never change a search's result — only its hit rate and
    /// (honestly accounted) measurement time; see [`evalcache`].
    pub warm_cache: Option<Arc<EvalCache>>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            lambda: 0.5,
            exploration_c: 2f64.sqrt(),
            branching: 2,
            budget: 1000,
            rollout_depth: 2,
            ca_threshold: Some(2),
            measure_interval: 16,
            measure_top_k: 8,
            measure_overhead_s: 1.5,
            routing: Routing::Endogenous,
            seed: 0,
            checkpoints: vec![50, 100, 250, 500, 750, 1000],
            search_threads: 1,
            warm_cache: None,
        }
    }
}

/// One tree node: a joint ⟨program, llm⟩ state.
///
/// The schedule sits behind an `Arc`: selection, expansion, rollout, and
/// measurement all borrow or refcount-share it instead of deep-cloning.
/// The prompt renderings the node contributes to LLM context (`code`,
/// `trace_tail`) are **lazy**: a `OnceLock` renders them the first time
/// the node actually appears in a prompt (as leaf, parent, or
/// grandparent) and shares the `Arc<str>` by refcount ever after. Nodes
/// that never reach a prompt — the common case for deep trees, where
/// most nodes are never re-selected — pay nothing, and the insertion
/// hot path allocates no prompt strings at all.
#[derive(Clone, Debug)]
struct Node {
    parent: Option<usize>,
    children: Vec<usize>,
    schedule: Arc<Schedule>,
    /// [`print_dominant`] rendering of `schedule`, rendered on first
    /// prompt use ([`Mcts::prompt_ctx`]) and shared by refcount after.
    code: OnceLock<Arc<str>>,
    /// `trace.render_tail(PROMPT_TRACE_TAIL)` of `schedule`, rendered on
    /// first prompt use and shared by refcount after.
    trace_tail: OnceLock<Arc<str>>,
    /// Model assigned to expand this node.
    llm: usize,
    visits: f64,
    reward_sum: f64,
    predicted_score: f64,
    /// Which model produced this node, and through what call type.
    expanded_by: Option<(usize, CallKind)>,
    depth: usize,
    /// Consecutive small-model regressions on the path ending here
    /// (large-model nodes pass their parent's count through unchanged).
    regression_chain: usize,
    pruned: bool,
    measured: bool,
    /// Tree-parallel virtual loss: in-flight lanes of the current round
    /// that descended through this node. Counted as extra zero-reward
    /// visits by LA-UCT so concurrent selectors spread over disjoint
    /// paths; always 0 outside a parallel round (and in serial search).
    virtual_loss: f64,
    /// In-flight expansions of the current round that picked this node as
    /// their leaf; counted against the branching factor so a round's
    /// lanes don't all expand the same parent.
    pending_children: usize,
}

/// Everything a finished search reports.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub workload: String,
    pub best_speedup: f64,
    pub best_latency_s: f64,
    pub baseline_latency_s: f64,
    /// (samples, best measured speedup) at each checkpoint.
    pub curve: Vec<(usize, f64)>,
    /// Total simulated compilation time: serial LLM latency + measurement.
    pub compile_time_s: f64,
    pub api_cost_usd: f64,
    pub n_samples: usize,
    pub n_ca_events: usize,
    pub n_errors: usize,
    /// (model name, regular calls, ca calls) per model.
    pub call_counts: Vec<(String, usize, usize)>,
    /// Evaluation-cache hit/miss counters for this search (see
    /// [`evalcache`]): nonzero hits mean candidate programs were
    /// re-proposed and served without re-evaluation.
    pub eval_cache: CacheStats,
    /// Transform applications this search rejected because the result
    /// carried a Deny-level diagnostic from the static legality
    /// analyzer ([`crate::analysis`]) — illegal schedules the tree
    /// never saw. Deterministic per (config, seed): every `apply` of a
    /// search runs on its coordinator thread.
    pub lint_rejects: u64,
    /// Everything the resilient model-call path absorbed (see
    /// [`crate::llm::faults`]): injected fault counts per kind, retries,
    /// fallback escalations, forced calls, and their honest latency/cost
    /// charges. Empty unless a nonzero [`crate::llm::faults::FaultPlan`]
    /// was installed on the model set.
    pub faults: FaultReport,
    pub best_schedule: Schedule,
}

/// Fill `curve` with every configured checkpoint it is missing, carrying
/// `final_speedup` forward for checkpoints the search never reached
/// (instead of silently dropping them), and keep it sorted by sample
/// count. Shared by the MCTS engine and the evolutionary baseline so the
/// [`SearchResult::curve`] contract lives in one place.
pub fn fill_missing_checkpoints(
    curve: &mut Vec<(usize, f64)>,
    checkpoints: &[usize],
    final_speedup: f64,
) {
    for &cp in checkpoints {
        if !curve.iter().any(|&(s, _)| s == cp) {
            curve.push((cp, final_speedup));
        }
    }
    curve.sort_by_key(|&(s, _)| s);
}

impl SearchResult {
    /// Invocation rate of a model (fraction of total calls), regular + CA.
    pub fn invocation_rate(&self, name: &str) -> (f64, f64) {
        let total: usize = self.call_counts.iter().map(|(_, r, c)| r + c).sum();
        let (r, c) = self
            .call_counts
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, r, c)| (*r, *c))
            .unwrap_or((0, 0));
        (
            r as f64 / total.max(1) as f64,
            c as f64 / total.max(1) as f64,
        )
    }
}

/// The shared-tree search engine. All cost-model / simulator access goes
/// through the [`Evaluator`] trait (see [`evalcache`]), so every
/// evaluation — expansion scoring, rollout scoring, course-alteration
/// re-expansion, and periodic measurement — shares one transposition
/// cache.
///
/// The evaluator is a type parameter: the serial engine (`Mcts`, the
/// default) owns a [`CachedEvaluator`]; the tree-parallel engine behind
/// [`Mcts::run_parallel`] drives the same machinery over a
/// [`SharedCachedEvaluator`] whose transposition cache
/// ([`evalcache::SharedEvalCache`]) is shared with its worker threads.
pub struct Mcts<E = CachedEvaluator> {
    pub cfg: SearchConfig,
    pub models: ModelSet,
    pub eval: E,
    nodes: Vec<Node>,
    rng: Rng,
    rr_ptr: usize,
    samples: usize,
    measure_time_s: f64,
    n_ca_events: usize,
    n_errors: usize,
    best_latency: f64,
    best_schedule: Arc<Schedule>,
    baseline_latency: f64,
    unmeasured: Vec<usize>,
    curve: Vec<(usize, f64)>,
    max_depth: usize,
    /// `cfg.checkpoints`, sorted and deduped, consumed front-to-back by
    /// `checkpoint_cursor` — the per-step curve check is O(1) instead of
    /// scanning the checkpoint list every sample.
    checkpoints_sorted: Vec<usize>,
    checkpoint_cursor: usize,
    /// Scratch buffers reused across `select()` descents (one tree level
    /// used to allocate two fresh `Vec`s).
    sel_children: Vec<usize>,
    sel_stats: Vec<la_uct::ChildStats>,
    /// Root→leaf path of the most recent `select()` descent (reused
    /// scratch; the parallel rounds record it to place virtual losses).
    sel_path: Vec<usize>,
    /// Value of the per-thread [`crate::analysis::lint_rejects`] counter
    /// when this search was constructed (before cost-model seeding, so
    /// seeding rejections count toward the search's total); `finish`
    /// reports `lint_rejects_base` plus the delta.
    lint_rejects_at_start: u64,
    /// Lint rejections accumulated by earlier segments of a resumed
    /// search ([`Mcts::resume`] restores the snapshot's running total
    /// here; 0 for a fresh search). Keeps the reported counter honest
    /// across process boundaries, where the per-thread counter restarts.
    lint_rejects_base: u64,
    /// Next tree-parallel round index. Lifted out of the round loop into
    /// engine state so a checkpointed parallel search resumes the exact
    /// per-round lane-seed sequence ([`round_seed`]) an uninterrupted run
    /// would have used. Serial search never touches it.
    round: u64,
}

/// How many trailing trace steps a node contributes to prompt context.
const PROMPT_TRACE_TAIL: usize = 8;

/// One committed expansion, ready to insert into the tree: the output of
/// the expand phase, consumed by the insert/backprop phases.
struct Expansion {
    sched: Schedule,
    score: f64,
    llm: usize,
    expanded_by: Option<(usize, CallKind)>,
    chain: usize,
}

/// Expansion-scoring blend — one definition for the serial score
/// closures, the parallel batch scoring, and course-alteration
/// re-scoring. The model's internal deliberation mixes the learned cost
/// model with a ground-truth-reasoned term (an LLM reads the code
/// directly, not only through the tuner's learned predictor).
fn blend_scores(model_score: f64, best_lat: f64, true_lat: f64) -> f64 {
    let reasoned = (best_lat / true_lat).clamp(0.0, 1.5);
    0.4 * model_score + 0.6 * reasoned
}

/// Random-rollout reward of a freshly expanded node: descend
/// `rollout_depth` random transforms from `base`, score the terminal
/// program with the learned cost model, and blend with the node's own
/// predicted score. Free function so both the serial engine (drawing from
/// its main RNG) and the parallel lanes (drawing from their lane RNGs)
/// share one definition.
fn rollout_reward<E: Evaluator>(
    eval: &mut E,
    base: &Schedule,
    final_score: f64,
    rollout_depth: usize,
    gpu: bool,
    rng: &mut Rng,
) -> f64 {
    // CoW clone: O(blocks) pointer copies, not a deep program copy
    let mut roll = base.clone();
    let vocab = TransformKind::vocabulary(gpu);
    for _ in 0..rollout_depth {
        let k = *rng.choice(&vocab);
        if let Ok(next) = crate::schedule::transforms::apply(&roll, k, rng, gpu) {
            roll = next;
        }
    }
    let rollout_score = eval.score(&roll);
    final_score.max(rollout_score).clamp(0.0, 1.0)
}

impl Mcts {
    /// Build a search. Starts from [`SearchConfig::warm_cache`]'s
    /// entries when set (cloned out of the shared handle), cold
    /// otherwise.
    pub fn new(mut cfg: SearchConfig, models: ModelSet, sim: Simulator, root: Schedule) -> Mcts {
        let cache = match cfg.warm_cache.take() {
            Some(warm) => EvalCache::clone(&warm),
            None => EvalCache::default(),
        };
        Mcts::with_cache(cfg, models, sim, root, cache)
    }

    /// Build a search that shares an externally owned evaluation cache
    /// (e.g. across repeated searches of the same workload); finish with
    /// [`Mcts::run_with_cache`] to get the warmed cache back. The
    /// explicit `cache` argument wins over [`SearchConfig::warm_cache`],
    /// whose reference is dropped here so the engine never holds a
    /// second copy of warm entries for its whole run.
    pub fn with_cache(
        mut cfg: SearchConfig,
        models: ModelSet,
        sim: Simulator,
        root: Schedule,
        cache: EvalCache,
    ) -> Mcts {
        cfg.warm_cache = None;
        let lint_rejects_at_start = crate::analysis::lint_rejects();
        let cost = CostModel::new(sim.target(), cfg.seed);
        let gpu = sim.target().is_gpu();
        let mut eval = CachedEvaluator::with_cache(cost, sim, cache);
        let mut rng = Rng::new(cfg.seed ^ 0x6C17_E600);
        let root = Arc::new(root);
        let baseline_latency = eval.measure(root.as_ref()).latency_s;
        // start with the largest model driving the root expansion, as a
        // single-model baseline would
        let root_llm = models.largest;
        let root_node = Node {
            parent: None,
            children: Vec::new(),
            schedule: Arc::clone(&root),
            code: OnceLock::new(),
            trace_tail: OnceLock::new(),
            llm: root_llm,
            visits: 1.0,
            reward_sum: 0.5,
            predicted_score: 0.5,
            expanded_by: None,
            depth: 0,
            regression_chain: 0,
            pruned: false,
            measured: true,
            virtual_loss: 0.0,
            pending_children: 0,
        };
        // seed cost model with a few random variants so early predictions
        // aren't degenerate
        let vocab = TransformKind::vocabulary(gpu);
        for _ in 0..7 {
            let seq: Vec<_> = (0..3).map(|_| *rng.choice(&vocab)).collect();
            if let Ok(s) = apply_sequence(root.as_ref(), &seq, &mut rng, gpu) {
                eval.measure(&s);
            }
        }
        let best_latency = eval.best_latency();
        let mut checkpoints_sorted = cfg.checkpoints.clone();
        checkpoints_sorted.sort_unstable();
        checkpoints_sorted.dedup();
        Mcts {
            cfg,
            models,
            eval,
            nodes: vec![root_node],
            rng,
            rr_ptr: 0,
            samples: 0,
            measure_time_s: 0.0,
            n_ca_events: 0,
            n_errors: 0,
            best_latency,
            best_schedule: root,
            baseline_latency,
            unmeasured: Vec::new(),
            curve: Vec::new(),
            max_depth: 24,
            checkpoints_sorted,
            checkpoint_cursor: 0,
            sel_children: Vec::new(),
            sel_stats: Vec::new(),
            sel_path: Vec::new(),
            lint_rejects_at_start,
            lint_rejects_base: 0,
            round: 0,
        }
    }
}

impl<E> Mcts<E> {
    /// Samples spent so far (read by the checkpoint and serve layers).
    pub fn samples(&self) -> usize {
        self.samples
    }

    /// Grow the budget for another incremental segment: future stepping
    /// runs until `samples + extra`. The serve loop calls this between
    /// requests on a resumed tree.
    pub fn extend_budget(&mut self, extra: usize) {
        self.cfg.budget = self.samples.saturating_add(extra);
    }

    /// Redirect this engine onto a fresh seed stream: the tree, cost
    /// model, cache, and incumbent are kept, but future randomness draws
    /// from `seed` and the parallel round counter restarts on that
    /// seed's round-seed sequence. The root-parallel driver
    /// ([`crate::coordinator::distributed`]) uses this to fan a shared
    /// warm tree out into lanes that explore along distinct streams —
    /// and distinct `cfg.seed`s are what [`treemerge::merge_engines`]
    /// keys its canonical lane order on.
    pub fn reseed(&mut self, seed: u64) {
        self.cfg.seed = seed;
        self.rng = Rng::new(seed ^ 0x6C17_E600);
        self.round = 0;
    }

    /// Best measured speedup so far (baseline / incumbent latency).
    pub fn best_speedup(&self) -> f64 {
        self.baseline_latency / self.best_latency
    }

    /// Cumulative simulated wall-clock so far: serial LLM latency
    /// (including fault retries and backoff) plus measurement time —
    /// the running form of `SearchResult::compile_time_s`. Deterministic
    /// for a fixed seed, which is what makes the serve loop's
    /// per-request deadline check deterministic too.
    pub fn simulated_time_s(&self) -> f64 {
        self.models.total_latency_s() + self.measure_time_s
    }

    /// The incumbent (best measured) schedule.
    pub fn incumbent(&self) -> &Schedule {
        &self.best_schedule
    }

    /// Swap the evaluator, handing the old one back — the single place
    /// the engine's full field list is threaded through, shared by the
    /// serial↔parallel conversions and the checkpoint/resume paths (a
    /// new engine field added here is added everywhere).
    fn replace_eval<F>(self, eval: F) -> (Mcts<F>, E) {
        let Mcts {
            cfg,
            models,
            eval: old,
            nodes,
            rng,
            rr_ptr,
            samples,
            measure_time_s,
            n_ca_events,
            n_errors,
            best_latency,
            best_schedule,
            baseline_latency,
            unmeasured,
            curve,
            max_depth,
            checkpoints_sorted,
            checkpoint_cursor,
            sel_children,
            sel_stats,
            sel_path,
            lint_rejects_at_start,
            lint_rejects_base,
            round,
        } = self;
        (
            Mcts {
                cfg,
                models,
                eval,
                nodes,
                rng,
                rr_ptr,
                samples,
                measure_time_s,
                n_ca_events,
                n_errors,
                best_latency,
                best_schedule,
                baseline_latency,
                unmeasured,
                curve,
                max_depth,
                checkpoints_sorted,
                checkpoint_cursor,
                sel_children,
                sel_stats,
                sel_path,
                lint_rejects_at_start,
                lint_rejects_base,
                round,
            },
            old,
        )
    }
}

impl<E: Evaluator> Mcts<E> {
    /// Cumulative evaluation-cache counters (restored totals included on
    /// a resumed search); read by the serve loop between segments.
    pub fn eval_cache_stats(&self) -> CacheStats {
        self.eval.cache_stats()
    }

    fn phi(&self, model: usize) -> f64 {
        if self.models.len() == 1 {
            0.0
        } else {
            self.models.phi_small(model)
        }
    }

    /// LA-UCT descent: walk from the root until a node with spare
    /// branching capacity (or the depth cap). Reuses the engine's scratch
    /// buffers — a descent allocates nothing — and records the root→leaf
    /// path in `self.sel_path` (consumed by the parallel rounds to place
    /// virtual losses).
    ///
    /// Virtual loss: each node's in-flight lanes count as extra
    /// zero-reward visits, and a leaf's pending expansions count against
    /// its branching capacity, so concurrent selectors of one round
    /// spread over disjoint subtrees. Both terms are identically zero in
    /// serial search, where this is exactly classic LA-UCT descent.
    fn select(&mut self) -> usize {
        let mut kids = std::mem::take(&mut self.sel_children);
        let mut stats = std::mem::take(&mut self.sel_stats);
        let mut path = std::mem::take(&mut self.sel_path);
        path.clear();
        let mut cur = 0usize;
        loop {
            path.push(cur);
            kids.clear();
            kids.extend(
                self.nodes[cur]
                    .children
                    .iter()
                    .copied()
                    .filter(|&c| !self.nodes[c].pruned),
            );
            if kids.len() + self.nodes[cur].pending_children < self.cfg.branching
                || self.nodes[cur].depth >= self.max_depth
                || kids.is_empty()
            {
                break;
            }
            stats.clear();
            stats.extend(kids.iter().map(|&c| la_uct::ChildStats {
                visits: self.nodes[c].visits + self.nodes[c].virtual_loss,
                reward_sum: self.nodes[c].reward_sum,
                phi_small: self.phi(self.nodes[c].llm),
            }));
            let pick = la_uct::select(
                &stats,
                self.nodes[cur].visits + self.nodes[cur].virtual_loss,
                self.cfg.lambda,
                self.cfg.exploration_c,
            );
            cur = kids[pick];
        }
        self.sel_children = kids;
        self.sel_stats = stats;
        self.sel_path = path;
        cur
    }

    fn prompt_ctx(&self, node_idx: usize) -> PromptCtx {
        let gpu = self.eval.target().is_gpu();
        let node = &self.nodes[node_idx];
        // code / trace_tail render lazily on a node's first prompt
        // appearance; every later use is a refcount bump, not a string
        // copy. Rendering draws no randomness, so laziness cannot perturb
        // the search's RNG streams.
        let variant = |i: usize| {
            let n = &self.nodes[i];
            VariantCtx {
                code: Arc::clone(
                    n.code
                        .get_or_init(|| print_dominant(n.schedule.as_ref(), gpu).into()),
                ),
                trace_tail: Arc::clone(
                    n.trace_tail
                        .get_or_init(|| n.schedule.trace.render_tail(PROMPT_TRACE_TAIL).into()),
                ),
                score: n.predicted_score,
            }
        };
        let parent_idx = node.parent;
        let gp_idx = parent_idx.and_then(|p| self.nodes[p].parent);
        let model_name =
            |i: Option<usize>| i.map(|n| self.models.specs[self.nodes[n].llm].name.to_string());
        PromptCtx {
            current: variant(node_idx),
            parent: parent_idx.map(variant),
            grandparent: gp_idx.map(variant),
            vocabulary: TransformKind::vocabulary(gpu),
            leaf_depth: node.depth,
            trials_done: self.samples,
            trials_budget: self.cfg.budget,
            model_stats: self.models.stat_lines(),
            local_models: [
                Some(self.models.specs[node.llm].name.to_string()),
                model_name(parent_idx),
                model_name(gp_idx),
            ],
        }
    }

    /// Route the next model according to the configured policy (serial
    /// path: randomness from the engine RNG).
    fn route(&mut self, proposed: usize) -> usize {
        match self.cfg.routing {
            Routing::Endogenous => proposed,
            Routing::Random => self.rng.below(self.models.len()),
            Routing::RoundRobin => {
                self.rr_ptr = (self.rr_ptr + 1) % self.models.len();
                self.rr_ptr
            }
        }
    }

    /// [`Mcts::route`] for parallel lanes: randomness comes from the lane
    /// RNG so lanes stay deterministic under any thread interleaving (the
    /// round-robin pointer is still engine state, advanced in lane order).
    fn route_with(&mut self, proposed: usize, rng: &mut Rng) -> usize {
        match self.cfg.routing {
            Routing::Endogenous => proposed,
            Routing::Random => rng.below(self.models.len()),
            Routing::RoundRobin => {
                self.rr_ptr = (self.rr_ptr + 1) % self.models.len();
                self.rr_ptr
            }
        }
    }

    /// Post-proposal regression bookkeeping, shared verbatim by the
    /// serial and parallel engines: the hysteresis-tested regression
    /// flag, the updated small-model regression chain (large-model nodes
    /// pass their parent's count through, improvements reset it — paper
    /// §2.5), and whether course alteration triggers.
    fn regression_outcome(
        &self,
        active: usize,
        child_score: f64,
        parent_score: f64,
        parent_chain: usize,
    ) -> (bool, usize, bool) {
        let active_is_small = active != self.models.largest;
        // regression = the child is predicted meaningfully worse than its
        // parent (hysteresis absorbs cost-model jitter)
        let regressed = child_score < parent_score - 0.02;
        let chain = if regressed && active_is_small {
            parent_chain + 1
        } else if regressed {
            parent_chain
        } else {
            0
        };
        let trigger_ca = self
            .cfg
            .ca_threshold
            .map(|t| active_is_small && regressed && chain >= t)
            .unwrap_or(false)
            && self.models.len() > 1;
        (regressed, chain, trigger_ca)
    }

    /// One full MCTS iteration — the four phases (select → expand →
    /// evaluate/rollout → backprop) fused in the serial draw order.
    /// Returns false once the budget is spent.
    pub fn step(&mut self) -> bool {
        if self.samples >= self.cfg.budget {
            return false;
        }
        let leaf = self.select();
        let Some(exp) = self.expand(leaf) else {
            return true; // nothing applicable; spend no sample
        };
        let child_idx = self.insert_child(leaf, exp);

        // ---- rollout + backpropagation ---------------------------------
        let gpu = self.eval.target().is_gpu();
        let roll_base = Arc::clone(&self.nodes[child_idx].schedule);
        let final_score = self.nodes[child_idx].predicted_score;
        let reward = rollout_reward(
            &mut self.eval,
            roll_base.as_ref(),
            final_score,
            self.cfg.rollout_depth,
            gpu,
            &mut self.rng,
        );
        self.backprop(child_idx, reward);
        self.after_sample();
        true
    }

    /// Expansion phase (serial draw order): query the active LLM for a
    /// joint ⟨transform-sequence, next-llm⟩ action, apply it, and resolve
    /// course alteration. `None` = the proposal (or its CA replacement)
    /// was structurally inapplicable; no sample is spent.
    fn expand(&mut self, leaf: usize) -> Option<Expansion> {
        let gpu = self.eval.target().is_gpu();

        // ---- expansion: query the active LLM ---------------------------
        let ctx = self.prompt_ctx(leaf);
        let active = self.nodes[leaf].llm;
        // refcount bump, not a deep copy: the node keeps its program, the
        // expansion borrows it
        let parent_sched = Arc::clone(&self.nodes[leaf].schedule);
        // The model's internal deliberation scores candidate sequences by
        // reading the program: emulated as a blend of the learned cost
        // model and the analytic performance model (an LLM reasons about
        // code structure directly, not only through the tuner's learned
        // predictor). Capability-scaled noise is added by the proposer.
        // Candidates that re-propose an already-seen program are served
        // from the shared evaluation cache.
        let best_lat = self.best_latency;
        let mut eval_rng = self.rng.fork(self.samples as u64);
        let eval = &mut self.eval;
        let mut score_fn = |seq: &[TransformKind]| -> f64 {
            match apply_sequence(parent_sched.as_ref(), seq, &mut eval_rng, gpu) {
                Ok(s) => {
                    let true_lat = eval.true_latency(&s);
                    blend_scores(eval.score(&s), best_lat, true_lat)
                }
                Err(_) => 0.0,
            }
        };
        let (proposal, rec) =
            self.models
                .propose(active, &ctx, CallKind::Regular, &[], &mut score_fn, &mut self.rng);
        // fault-path escalation may have handed the call to a larger
        // model; credit hits and provenance to whoever actually served
        // (identical to `active` whenever no fault plan is installed)
        let served = rec.model;
        self.n_errors += proposal.n_errors;

        let child_sched = match apply_sequence(
            parent_sched.as_ref(),
            &proposal.transforms,
            &mut self.rng,
            gpu,
        ) {
            Ok(s) => s,
            Err(_) => return None, // nothing applicable; spend no sample
        };
        let child_score = self.eval.score(&child_sched);
        let next_llm = self.route(proposal.next_model);
        let parent_score = self.nodes[leaf].predicted_score;
        let parent_chain = self.nodes[leaf].regression_chain;
        let (regressed, chain, trigger_ca) =
            self.regression_outcome(served, child_score, parent_score, parent_chain);
        if !regressed {
            self.models.credit_hit(served, CallKind::Regular);
        }

        // ---- course alteration ------------------------------------------
        if trigger_ca {
            // move the engine RNG out so the shared CA helper can draw
            // from it next to `&mut self`; the stream continues unchanged
            // and is restored right after (draw order identical to the
            // historical inline CA block)
            let banned = proposal.transforms.clone();
            let mut rng = std::mem::replace(&mut self.rng, Rng::new(0));
            let eval_rng = rng.fork(self.samples as u64 ^ 0xCA);
            let exp = self.course_alter(
                &ctx,
                parent_sched.as_ref(),
                parent_score,
                banned,
                best_lat,
                gpu,
                eval_rng,
                &mut rng,
            );
            self.rng = rng;
            exp
        } else {
            Some(Expansion {
                sched: child_sched,
                score: child_score,
                llm: next_llm,
                expanded_by: Some((served, CallKind::Regular)),
                chain,
            })
        }
    }

    /// Course-alteration re-expansion (paper §2.5), shared verbatim by
    /// the serial engine and the parallel lanes: the regressive proposal
    /// is pruned (no node inserted, its value never backpropagates) and
    /// the **largest** model re-expands from the same parent under a
    /// shorter targeted prompt with the failed sequence banned. All
    /// randomness comes from the caller's streams (`eval_rng` for
    /// candidate application, `rng` for the call itself), so both engines
    /// run one definition of the CA protocol. `None` = the replacement
    /// was structurally inapplicable; no sample is spent.
    #[allow(clippy::too_many_arguments)]
    fn course_alter(
        &mut self,
        ctx: &PromptCtx,
        parent_sched: &Schedule,
        parent_score: f64,
        banned: Vec<TransformKind>,
        best_lat: f64,
        gpu: bool,
        mut eval_rng: Rng,
        rng: &mut Rng,
    ) -> Option<Expansion> {
        self.n_ca_events += 1;
        let largest = self.models.largest;
        let eval = &mut self.eval;
        let mut ca_score_fn = |seq: &[TransformKind]| -> f64 {
            match apply_sequence(parent_sched, seq, &mut eval_rng, gpu) {
                Ok(s) => {
                    let true_lat = eval.true_latency(&s);
                    blend_scores(eval.score(&s), best_lat, true_lat)
                }
                Err(_) => 0.0,
            }
        };
        let (ca_prop, _) = self.models.propose(
            largest,
            ctx,
            CallKind::CourseAlteration,
            &banned,
            &mut ca_score_fn,
            rng,
        );
        self.n_errors += ca_prop.n_errors;
        match apply_sequence(parent_sched, &ca_prop.transforms, rng, gpu) {
            Ok(s) => {
                let sc = self.eval.score(&s);
                if sc >= parent_score {
                    self.models.credit_hit(largest, CallKind::CourseAlteration);
                }
                let next = self.route_with(ca_prop.next_model, rng);
                Some(Expansion {
                    sched: s,
                    score: sc,
                    llm: next,
                    expanded_by: Some((largest, CallKind::CourseAlteration)),
                    chain: 0,
                })
            }
            Err(_) => None,
        }
    }

    /// Insert phase: commit an expansion as a new tree node (prompt
    /// renderings stay unrendered until the node first appears in a
    /// prompt) and spend one sample.
    fn insert_child(&mut self, leaf: usize, exp: Expansion) -> usize {
        let gpu = self.eval.target().is_gpu();
        // the apply-time Deny gate makes illegal states unreachable; in
        // debug builds, re-assert that invariant on every inserted node
        debug_assert!(
            crate::analysis::first_deny(&exp.sched, gpu).is_none(),
            "illegal schedule reached tree insertion: {}",
            crate::analysis::first_deny(&exp.sched, gpu).unwrap()
        );
        let depth = self.nodes[leaf].depth + 1;
        let child_idx = self.nodes.len();
        self.nodes.push(Node {
            parent: Some(leaf),
            children: Vec::new(),
            schedule: Arc::new(exp.sched),
            code: OnceLock::new(),
            trace_tail: OnceLock::new(),
            llm: exp.llm,
            visits: 0.0,
            reward_sum: 0.0,
            predicted_score: exp.score,
            expanded_by: exp.expanded_by,
            depth,
            regression_chain: exp.chain,
            pruned: false,
            measured: false,
            virtual_loss: 0.0,
            pending_children: 0,
        });
        self.nodes[leaf].children.push(child_idx);
        self.unmeasured.push(child_idx);
        self.samples += 1;
        child_idx
    }

    /// Backpropagation phase: credit the rollout-blended reward along the
    /// selected path, so signal discovered by one model informs all
    /// others.
    fn backprop(&mut self, from: usize, reward: f64) {
        let mut cur = Some(from);
        while let Some(i) = cur {
            self.nodes[i].visits += 1.0;
            self.nodes[i].reward_sum += reward;
            cur = self.nodes[i].parent;
        }
    }

    /// Post-sample bookkeeping: periodic measurement + cost-model
    /// retraining, then curve checkpoints.
    fn after_sample(&mut self) {
        if self.samples % self.cfg.measure_interval == 0 || self.samples >= self.cfg.budget {
            self.measure_batch();
        }
        // curve checkpoints: `samples` grows by one per spent sample, so a
        // sorted cursor replaces the per-step O(checkpoints) list scan;
        // passed (sub-sample-count) checkpoints are skipped exactly like
        // the scan skipped them.
        while self.checkpoint_cursor < self.checkpoints_sorted.len()
            && self.checkpoints_sorted[self.checkpoint_cursor] <= self.samples
        {
            if self.checkpoints_sorted[self.checkpoint_cursor] == self.samples {
                let sp = self.baseline_latency / self.best_latency;
                self.curve.push((self.samples, sp));
            }
            self.checkpoint_cursor += 1;
        }
    }

    /// Measure the top-K unmeasured candidates (by predicted score) on the
    /// simulator; feed the cost model; update the incumbent.
    fn measure_batch(&mut self) {
        // rank by predicted score, best first
        self.unmeasured.sort_by(|&a, &b| {
            self.nodes[b]
                .predicted_score
                .total_cmp(&self.nodes[a].predicted_score)
        });
        let take: Vec<usize> = self
            .unmeasured
            .drain(..self.cfg.measure_top_k.min(self.unmeasured.len()))
            .collect();
        for idx in take {
            let m = self.eval.measure(&*self.nodes[idx].schedule);
            self.nodes[idx].measured = true;
            // harness overhead (simulated compile+run wall-clock) is only
            // charged when the simulator actually ran — a measurement
            // served by the shared eval cache costs no harness time, so
            // warm-cache searches report honest compile_time_s
            if !m.cache_hit {
                self.measure_time_s += self.cfg.measure_overhead_s;
            }
            if m.latency_s < self.best_latency {
                self.best_latency = m.latency_s;
                self.best_schedule = Arc::clone(&self.nodes[idx].schedule);
            }
        }
        self.unmeasured.clear(); // stale predictions aren't re-ranked
    }

    /// Serial search loop: step to budget exhaustion (with a stall guard
    /// for configurations where nothing is ever applicable).
    fn run_serial_loop(&mut self) {
        let mut stall = 0;
        while self.samples < self.cfg.budget && stall < 10_000 {
            let before = self.samples;
            self.step();
            if self.samples == before {
                stall += 1;
            } else {
                stall = 0;
            }
        }
    }

    /// Final measurement flush + report assembly, shared by the serial
    /// and tree-parallel paths. Hands the evaluator back so callers can
    /// recover the warm cache.
    fn finish(mut self, workload_name: &str) -> (SearchResult, E) {
        self.measure_batch();
        let final_speedup = self.baseline_latency / self.best_latency;
        let mut curve = std::mem::take(&mut self.curve);
        // make sure the final point is on the curve
        if !curve.iter().any(|&(s, _)| s == self.samples) {
            curve.push((self.samples, final_speedup));
        }
        // use the same normalized (sorted, deduped) checkpoint list the
        // step() cursor consumed — one source of truth for the curve grid
        fill_missing_checkpoints(&mut curve, &self.checkpoints_sorted, final_speedup);
        let result = SearchResult {
            workload: workload_name.to_string(),
            best_speedup: final_speedup,
            best_latency_s: self.best_latency,
            baseline_latency_s: self.baseline_latency,
            curve,
            compile_time_s: self.models.total_latency_s() + self.measure_time_s,
            api_cost_usd: self.models.total_cost_usd(),
            n_samples: self.samples,
            n_ca_events: self.n_ca_events,
            n_errors: self.n_errors,
            call_counts: self
                .models
                .specs
                .iter()
                .zip(&self.models.stats)
                .map(|(m, s)| (m.name.to_string(), s.regular_calls, s.ca_calls))
                .collect(),
            eval_cache: self.eval.cache_stats(),
            // every apply of this search ran on this (the coordinator)
            // thread, so the per-thread delta is this search's count;
            // the base carries totals from pre-resume segments of a
            // checkpointed search across process boundaries
            lint_rejects: self.lint_rejects_base
                + crate::analysis::lint_rejects().saturating_sub(self.lint_rejects_at_start),
            faults: self.models.fault_report.clone(),
            best_schedule: (*self.best_schedule).clone(),
        };
        (result, self.eval)
    }
}

impl Mcts {
    /// Run to budget exhaustion and report.
    pub fn run(self, workload_name: &str) -> SearchResult {
        self.run_with_cache(workload_name).0
    }

    /// Like [`Mcts::run`], but also hands back the warmed evaluation
    /// cache so a follow-up search ([`Mcts::with_cache`]) can reuse every
    /// ground-truth evaluation this one performed.
    pub fn run_with_cache(mut self, workload_name: &str) -> (SearchResult, EvalCache) {
        self.run_serial_loop();
        let (result, eval) = self.finish(workload_name);
        (result, eval.into_cache())
    }

    /// Tree-parallel search: run this one search across `threads` worker
    /// threads (see [`Mcts::run_parallel_with_cache`] for the contract).
    pub fn run_parallel(self, workload_name: &str, threads: usize) -> SearchResult {
        self.run_parallel_with_cache(workload_name, threads).0
    }

    /// Tree-parallel search with virtual loss and batched leaf
    /// evaluation.
    ///
    /// Each round, up to `threads` lanes descend the shared tree (virtual
    /// losses keep them on disjoint paths), draw their LLM candidate
    /// sequences serially, then fan every candidate's ground-truth
    /// evaluation out across a persistent pool of `threads` workers
    /// ([`crate::runtime::driver::WorkerPool`], spawned once per search)
    /// over a sharded concurrent cache ([`SharedEvalCache`]); lane
    /// proposals, insertions, rollouts, and backpropagation are then
    /// merged **in lane order**, so the result is a pure function of the
    /// configuration.
    ///
    /// Determinism contract:
    /// * `threads <= 1` delegates to the serial engine — bit-identical to
    ///   [`Mcts::run`] (same RNG streams, same result, same counters);
    /// * `threads > 1` is deterministic for a fixed `(seed, threads)`
    ///   pair: every lane draws from its own
    ///   [`lane_seed`](crate::runtime::driver::lane_seed)-derived stream
    ///   and nothing observable depends on thread scheduling. Different
    ///   `threads` values explore different (equally valid) trees.
    ///   Caveat: this additionally assumes the shared cache keeps insert
    ///   capacity — a full shard degrades to compute-per-lookup and its
    ///   final contents become timing-dependent (see [`SharedEvalCache`]);
    ///   the default [`EvalCache::DEFAULT_CAPACITY`] leaves ample
    ///   headroom.
    pub fn run_parallel_with_cache(
        self,
        workload_name: &str,
        threads: usize,
    ) -> (SearchResult, EvalCache) {
        if threads <= 1 {
            return self.run_with_cache(workload_name);
        }
        let (this, CachedEvaluator {
            cost,
            sim,
            cache,
            scratch,
        }) = self.replace_eval(());
        let shared = SharedEvalCache::from_cache(cache, SharedEvalCache::DEFAULT_SHARDS);
        let (engine, ()) = this.replace_eval(SharedCachedEvaluator {
            cost,
            sim,
            cache: &shared,
            scratch,
        });
        let result = engine.run_parallel_rounds(workload_name, threads);
        (result, shared.into_cache())
    }

    /// Step the serial engine until at least `k` samples are spent (or
    /// the budget / stall guard stops it) and hand the engine back —
    /// the checkpoint point for [`Mcts::snapshot`]. Running the
    /// remainder afterwards (e.g. after a snapshot/resume round-trip)
    /// is bit-identical to an uninterrupted run: the loop is the same
    /// `step()` sequence [`Mcts::run`] drives.
    pub fn run_until(mut self, k: usize) -> Mcts {
        let k = k.min(self.cfg.budget);
        let mut stall = 0;
        while self.samples < k && stall < 10_000 {
            let before = self.samples;
            self.step();
            if self.samples == before {
                stall += 1;
            } else {
                stall = 0;
            }
        }
        self
    }

    /// Tree-parallel analogue of [`Mcts::run_until`]: run whole parallel
    /// rounds until at least `k` samples are spent, then convert back to
    /// the serial (checkpointable) engine form. Checkpoints land on
    /// round boundaries; lane counts are computed against the full
    /// configured budget, so the rounds executed here are exactly the
    /// prefix an uninterrupted [`Mcts::run_parallel`] at the same
    /// `(seed, threads)` would run — the persisted `round` counter keeps
    /// the continuation on the same per-round lane-seed sequence.
    pub fn run_parallel_until(self, threads: usize, k: usize) -> Mcts {
        if threads <= 1 {
            return self.run_until(k);
        }
        let k = k.min(self.cfg.budget);
        let (this, CachedEvaluator {
            cost,
            sim,
            cache,
            scratch,
        }) = self.replace_eval(());
        let shared = SharedEvalCache::from_cache(cache, SharedEvalCache::DEFAULT_SHARDS);
        let (mut engine, ()) = this.replace_eval(SharedCachedEvaluator {
            cost,
            sim,
            cache: &shared,
            scratch,
        });
        engine.run_parallel_rounds_until(threads, k);
        let (this, SharedCachedEvaluator {
            cost,
            sim,
            scratch,
            ..
        }) = engine.replace_eval(());
        let (engine, ()) = this.replace_eval(CachedEvaluator {
            cost,
            sim,
            cache: shared.into_cache(),
            scratch,
        });
        engine
    }
}

/// Deterministic per-round seed: every round of a parallel search derives
/// its lane streams from this, so `(seed, threads)` fully pins the search.
fn round_seed(seed: u64, round: u64) -> u64 {
    let mut st = seed ^ round.wrapping_mul(0xA076_1D64_78BD_642F);
    crate::util::rng::splitmix64(&mut st)
}

/// One in-flight lane of a parallel round, between the select/draw phase
/// and the batched evaluation.
struct Lane {
    leaf: usize,
    path: Vec<usize>,
    rng: Rng,
    cands: Vec<Vec<TransformKind>>,
    applied: Vec<Option<Schedule>>,
}

/// A lane whose candidates have been evaluated, ready for the serial
/// lane-ordered merge.
struct ReadyLane {
    leaf: usize,
    path: Vec<usize>,
    rng: Rng,
    scored: Vec<(Vec<TransformKind>, f64)>,
}

impl<'s> Mcts<SharedCachedEvaluator<'s>> {
    /// Parallel round loop (same budget/stall contract as the serial
    /// loop), then the shared report assembly.
    ///
    /// The leaf-evaluation worker pool lives for the **whole search**:
    /// thread spawn/join is paid once here, and each round costs a couple
    /// of channel operations per candidate — the per-candidate work (one
    /// simulator evaluation through the shared cache) is small enough
    /// that per-round thread spawning would dominate it.
    fn run_parallel_rounds(mut self, workload_name: &str, threads: usize) -> SearchResult {
        let until = self.cfg.budget;
        self.run_parallel_rounds_until(threads, until);
        self.finish(workload_name).0
    }

    /// Run whole parallel rounds until at least `until` samples are
    /// spent (or the stall guard trips). The persistent `self.round`
    /// counter — not a local — feeds [`round_seed`], so a search
    /// checkpointed here and resumed later replays the exact same
    /// per-round lane-seed sequence an uninterrupted run would.
    fn run_parallel_rounds_until(&mut self, threads: usize, until: usize) {
        let until = until.min(self.cfg.budget);
        // trees merged from root-parallel lanes (mcts::treemerge) can
        // legitimately hold more than `branching` children per node (the
        // union of each lane's children); such nodes never grow —
        // selection only expands nodes with spare capacity — so the
        // post-round invariant is checked against each node's width at
        // entry, not the branching factor alone
        let entry_width: Vec<usize> = self.nodes.iter().map(|n| n.children.len()).collect();
        let shared = self.eval.cache;
        let target = self.eval.target();
        let sim = self.eval.sim.clone();
        std::thread::scope(|scope| {
            let pool: WorkerPool<Schedule, f64> =
                WorkerPool::spawn(scope, threads, move |s: Schedule| {
                    shared
                        .latency_or_served(evalcache::trace_key(&s, target), || sim.latency(&s))
                        .0
                });
            let mut stall = 0;
            while self.samples < until && stall < 10_000 {
                let before = self.samples;
                let round = self.round;
                self.parallel_round(round, threads, &pool);
                self.round = self.round.wrapping_add(1);
                if self.samples == before {
                    stall += 1;
                } else {
                    stall = 0;
                }
            }
            // the pool drops when this closure returns, shutting the
            // workers down before the scope joins them
        });
        debug_assert!(
            self.nodes
                .iter()
                .all(|n| n.virtual_loss == 0.0 && n.pending_children == 0),
            "virtual loss / pending-expansion marks leaked past a round"
        );
        debug_assert!(
            self.nodes.iter().enumerate().all(|(i, n)| {
                n.depth >= self.max_depth
                    || n.children.len() <= self.cfg.branching.max(1)
                    || (i < entry_width.len() && n.children.len() <= entry_width[i])
            }),
            "branching factor violated by parallel expansion"
        );
    }

    /// One tree-parallel round:
    ///
    /// 1. **select + draw** (serial): up to `threads` lanes descend with
    ///    virtual loss and draw their LLM candidate sequences from
    ///    per-lane seeded RNGs;
    /// 2. **evaluate** (parallel): every applicable candidate's
    ///    ground-truth latency is computed across the persistent worker
    ///    pool through the shared sharded cache — the expensive part of
    ///    an iteration, batched;
    /// 3. **merge** (serial, lane order): each lane finishes its proposal
    ///    (noise, routing, accounting), resolves course alteration,
    ///    inserts its child, rolls out, and backpropagates — identical
    ///    bookkeeping to the serial engine, applied deterministically.
    fn parallel_round(&mut self, round: u64, threads: usize, pool: &WorkerPool<Schedule, f64>) {
        let lanes_n = threads.min(self.cfg.budget - self.samples).max(1);
        let gpu = self.eval.target().is_gpu();
        let vocab = TransformKind::vocabulary(gpu);
        let best_lat = self.best_latency;
        let rseed = round_seed(self.cfg.seed, round);

        // ---- phase 1: select with virtual loss + draw candidates -------
        let mut lanes: Vec<Lane> = Vec::with_capacity(lanes_n);
        for lane in 0..lanes_n {
            let leaf = self.select();
            // a childless frontier node is reached through select()'s
            // empty-children escape, which bypasses the branching cap:
            // once this round's earlier lanes have saturated the node's
            // capacity with pending expansions, the round stops adding
            // lanes instead of over-expanding it (depth-capped nodes keep
            // the serial engine's unbounded-children behavior). `break`,
            // not `continue`: a skip changes none of select()'s inputs,
            // so every later lane of this round would deterministically
            // re-walk the same descent and skip too.
            let kids_n = self.nodes[leaf]
                .children
                .iter()
                .filter(|&&c| !self.nodes[c].pruned)
                .count();
            if self.nodes[leaf].depth < self.max_depth
                && kids_n + self.nodes[leaf].pending_children >= self.cfg.branching
            {
                break;
            }
            let path = self.sel_path.clone();
            for &i in &path {
                self.nodes[i].virtual_loss += 1.0;
            }
            self.nodes[leaf].pending_children += 1;
            let mut rng = Rng::new(crate::runtime::driver::lane_seed(rseed, lane as u64));
            let mut eval_rng = rng.fork(0xE7A1);
            let active = self.nodes[leaf].llm;
            let cands =
                self.models
                    .draw_candidates(active, &vocab, CallKind::Regular, &[], &mut rng);
            let parent = Arc::clone(&self.nodes[leaf].schedule);
            let applied: Vec<Option<Schedule>> = cands
                .iter()
                .map(|seq| apply_sequence(parent.as_ref(), seq, &mut eval_rng, gpu).ok())
                .collect();
            lanes.push(Lane {
                leaf,
                path,
                rng,
                cands,
                applied,
            });
        }

        // ---- phase 2: batched leaf evaluation on the worker pool -------
        // candidate schedules are CoW, so a submission ships pointer
        // copies; results come back index-addressed, i.e. in submission
        // order regardless of worker interleaving
        let mut n_jobs = 0usize;
        for l in &lanes {
            for s in l.applied.iter().flatten() {
                pool.submit(n_jobs, s.clone());
                n_jobs += 1;
            }
        }
        let lats = pool.collect(n_jobs);

        // ---- phase 3: deterministic lane-ordered merge -----------------
        let mut li = 0usize;
        for lane in lanes {
            let Lane {
                leaf,
                path,
                rng,
                cands,
                applied,
            } = lane;
            // batched candidate scoring: one SoA cost-model pass over the
            // lane's applicable candidates (scores, served values, and
            // cache counters are exactly what per-candidate `score` calls
            // in this order would produce — see `Evaluator::score_batch`).
            // Per lane, not per round: a lane's merge can retrain the cost
            // model, and later lanes must score against the updated model
            // exactly as the sequential merge always has.
            let refs: Vec<&Schedule> = applied.iter().flatten().collect();
            let model_scores = self.eval.score_batch(&refs);
            let mut mi = 0usize;
            let mut scored: Vec<(Vec<TransformKind>, f64)> = Vec::with_capacity(cands.len());
            for (seq, app) in cands.into_iter().zip(&applied) {
                let sc = match app {
                    Some(_) => {
                        let lat = lats[li];
                        li += 1;
                        let ms = model_scores[mi];
                        mi += 1;
                        blend_scores(ms, best_lat, lat)
                    }
                    None => 0.0,
                };
                scored.push((seq, sc));
            }
            self.finish_lane(
                ReadyLane {
                    leaf,
                    path,
                    rng,
                    scored,
                },
                best_lat,
                gpu,
            );
        }
    }

    /// Serial tail of one lane: finish the proposal from its evaluated
    /// candidates, resolve course alteration, then insert / roll out /
    /// backpropagate — the same bookkeeping as the serial engine, with
    /// all randomness drawn from the lane RNG.
    fn finish_lane(&mut self, lane: ReadyLane, best_lat: f64, gpu: bool) {
        let ReadyLane {
            leaf,
            path,
            mut rng,
            scored,
        } = lane;
        let ctx = self.prompt_ctx(leaf);
        let active = self.nodes[leaf].llm;
        let parent_sched = Arc::clone(&self.nodes[leaf].schedule);
        let (proposal, rec) =
            self.models
                .propose_scored(active, &ctx, CallKind::Regular, &[], scored, &mut rng);
        // see `expand`: attribute the call to whoever actually served it
        let served = rec.model;
        self.n_errors += proposal.n_errors;
        let child_sched =
            match apply_sequence(parent_sched.as_ref(), &proposal.transforms, &mut rng, gpu) {
                Ok(s) => s,
                Err(_) => {
                    // nothing applicable; spend no sample
                    self.clear_lane(&path, leaf);
                    return;
                }
            };
        let child_score = self.eval.score(&child_sched);
        let next_llm = self.route_with(proposal.next_model, &mut rng);
        let parent_score = self.nodes[leaf].predicted_score;
        let parent_chain = self.nodes[leaf].regression_chain;
        let (regressed, chain, trigger_ca) =
            self.regression_outcome(served, child_score, parent_score, parent_chain);
        if !regressed {
            self.models.credit_hit(served, CallKind::Regular);
        }

        let exp = if trigger_ca {
            // CA is rare: its candidates are scored inline on the
            // coordinator (still through the shared cache), so the lane
            // can reuse the exact serial CA protocol, fed by its lane RNG
            let banned = proposal.transforms.clone();
            let ca_eval_rng = rng.fork(0xCA);
            match self.course_alter(
                &ctx,
                parent_sched.as_ref(),
                parent_score,
                banned,
                best_lat,
                gpu,
                ca_eval_rng,
                &mut rng,
            ) {
                Some(exp) => exp,
                None => {
                    self.clear_lane(&path, leaf);
                    return;
                }
            }
        } else {
            Expansion {
                sched: child_sched,
                score: child_score,
                llm: next_llm,
                expanded_by: Some((served, CallKind::Regular)),
                chain,
            }
        };

        // lift the lane's virtual loss before crediting the real visit
        self.clear_lane(&path, leaf);
        let child_idx = self.insert_child(leaf, exp);
        let roll_base = Arc::clone(&self.nodes[child_idx].schedule);
        let final_score = self.nodes[child_idx].predicted_score;
        let reward = rollout_reward(
            &mut self.eval,
            roll_base.as_ref(),
            final_score,
            self.cfg.rollout_depth,
            gpu,
            &mut rng,
        );
        self.backprop(child_idx, reward);
        self.after_sample();
    }

    /// Remove one lane's virtual loss along its selection path and its
    /// pending-expansion mark on the leaf.
    fn clear_lane(&mut self, path: &[usize], leaf: usize) {
        for &i in path {
            self.nodes[i].virtual_loss -= 1.0;
        }
        self.nodes[leaf].pending_children -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::registry::paper_config;
    use crate::sim::Target;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn quick_cfg(budget: usize, seed: u64) -> SearchConfig {
        SearchConfig {
            budget,
            seed,
            checkpoints: vec![budget / 2, budget],
            ..SearchConfig::default()
        }
    }

    fn run_search(n_llms: usize, budget: usize, seed: u64) -> SearchResult {
        let sched = Schedule::initial(Arc::new(gemm::gemm(512, 512, 512)));
        let models = ModelSet::new(paper_config(n_llms, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        Mcts::new(quick_cfg(budget, seed), models, sim, sched).run("gemm")
    }

    #[test]
    fn search_improves_over_baseline() {
        let r = run_search(2, 60, 1);
        assert!(r.best_speedup > 1.5, "speedup {}", r.best_speedup);
        assert_eq!(r.n_samples, 60);
        assert!(r.api_cost_usd > 0.0);
        assert!(r.compile_time_s > 0.0);
    }

    #[test]
    fn curve_monotone_nondecreasing() {
        let r = run_search(4, 80, 2);
        for w in r.curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "curve {:?}", r.curve);
        }
    }

    #[test]
    fn multi_llm_uses_small_models() {
        let r = run_search(8, 120, 3);
        let total: usize = r.call_counts.iter().map(|(_, a, b)| a + b).sum();
        let (big_r, big_c) = r
            .call_counts
            .iter()
            .find(|(n, _, _)| n == "gpt-5.2")
            .map(|(_, a, b)| (*a, *b))
            .unwrap();
        let big_share = (big_r + big_c) as f64 / total as f64;
        assert!(big_share < 0.7, "largest share {big_share}");
        // at least three distinct models used
        let used = r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count();
        assert!(used >= 3, "only {used} models used");
    }

    #[test]
    fn course_alteration_fires() {
        let r = run_search(8, 150, 4);
        assert!(r.n_ca_events > 0, "no CA events in 150 samples");
        let ca_calls: usize = r.call_counts.iter().map(|(_, _, c)| c).sum();
        assert_eq!(ca_calls, r.n_ca_events);
    }

    #[test]
    fn ca_disabled_means_no_ca_calls() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(8, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            ca_threshold: None,
            budget: 80,
            seed: 5,
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        assert_eq!(r.n_ca_events, 0);
    }

    #[test]
    fn single_model_search_works() {
        let r = run_search(1, 50, 6);
        assert!(r.best_speedup >= 1.0);
        assert_eq!(r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_search(4, 40, 7);
        let b = run_search(4, 40, 7);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.api_cost_usd, b.api_cost_usd);
        assert_eq!(a.eval_cache, b.eval_cache);
    }

    #[test]
    fn curve_emits_all_checkpoints_with_carry_forward() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            budget: 30,
            seed: 9,
            checkpoints: vec![10, 30, 100, 1000],
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        let at = |cp: usize| {
            r.curve
                .iter()
                .find(|&&(s, _)| s == cp)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("checkpoint {cp} missing from {:?}", r.curve))
        };
        // checkpoints past the 30-sample budget carry the final speedup
        assert_eq!(at(100), r.best_speedup);
        assert_eq!(at(1000), r.best_speedup);
        assert!(at(10) <= r.best_speedup + 1e-9);
        // curve stays sorted and monotone
        for w in r.curve.windows(2) {
            assert!(w[1].0 > w[0].0, "unsorted curve {:?}", r.curve);
            assert!(w[1].1 >= w[0].1 - 1e-9, "curve {:?}", r.curve);
        }
    }

    #[test]
    fn repeated_search_with_shared_cache_reports_hits() {
        let mk = |cache: EvalCache| {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(2, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            Mcts::with_cache(quick_cfg(40, 11), models, sim, sched, cache)
        };
        // first search hands back its fully warmed cache
        let (baseline, cache) = mk(EvalCache::new()).run_with_cache("gemm");
        assert!(!cache.is_empty());
        // replay the identical search against the shared cache: adoption
        // resets the counters, and every ground-truth evaluation is
        // already present
        let (r, _) = mk(cache).run_with_cache("gemm");
        assert!(r.eval_cache.hits > 0, "no cache hits: {:?}", r.eval_cache);
        assert!(
            r.eval_cache.hits > baseline.eval_cache.hits,
            "warm run {:?} should out-hit cold run {:?}",
            r.eval_cache,
            baseline.eval_cache
        );
        // caching is transparent: results are identical to the cold run
        assert_eq!(r.best_speedup, baseline.best_speedup);
        assert_eq!(r.curve, baseline.curve);
        assert_eq!(r.api_cost_usd, baseline.api_cost_usd);
    }

    #[test]
    fn warm_cache_search_reports_honest_compile_time() {
        // a measurement served by the shared cache runs no simulator, so
        // it must not be charged measure_overhead_s
        let mk = |cache: EvalCache| {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(2, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            Mcts::with_cache(quick_cfg(40, 21), models, sim, sched, cache)
        };
        let (cold, cache) = mk(EvalCache::new()).run_with_cache("gemm");
        let (warm, _) = mk(cache).run_with_cache("gemm");
        // caching stays observationally transparent on the search outcome
        assert_eq!(warm.best_speedup, cold.best_speedup);
        assert_eq!(warm.curve, cold.curve);
        // but the warm run's harness time is honest: every ground-truth
        // measurement was cache-served, so only LLM latency remains
        assert!(
            warm.compile_time_s < cold.compile_time_s,
            "warm {} !< cold {}",
            warm.compile_time_s,
            cold.compile_time_s
        );
        assert_eq!(warm.api_cost_usd, cold.api_cost_usd);
    }

    #[test]
    fn unsorted_duplicate_checkpoints_recorded_once_in_order() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            budget: 30,
            seed: 9,
            checkpoints: vec![30, 10, 10, 20],
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        let samples: Vec<usize> = r.curve.iter().map(|&(s, _)| s).collect();
        assert_eq!(samples, vec![10, 20, 30], "curve {:?}", r.curve);
    }

    #[test]
    fn deterministic_at_depth_with_rollout_and_ca() {
        // transparency of the CoW/Arc/caching refactor: a fixed-seed
        // search that exercises deep selection, rollouts, and the
        // course-alteration path is bit-for-bit repeatable (same
        // configuration as course_alteration_fires, which pins that this
        // seed triggers CA)
        let a = run_search(8, 150, 4);
        let b = run_search(8, 150, 4);
        assert!(a.n_ca_events > 0, "CA path never exercised");
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.eval_cache, b.eval_cache);
        assert_eq!(a.call_counts, b.call_counts);
        assert_eq!(a.compile_time_s, b.compile_time_s);
        assert_eq!(a.api_cost_usd, b.api_cost_usd);
        assert_eq!(a.n_samples, b.n_samples);
        assert_eq!(a.best_schedule.trace.running_hash(), b.best_schedule.trace.running_hash());
    }

    /// Field-by-field bit-equality of two search reports (SearchResult
    /// intentionally has no PartialEq; the schedule is compared through
    /// its trace hash + structural fingerprint).
    fn assert_results_identical(a: &SearchResult, b: &SearchResult) {
        assert_eq!(a.workload, b.workload);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.best_latency_s, b.best_latency_s);
        assert_eq!(a.baseline_latency_s, b.baseline_latency_s);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.compile_time_s, b.compile_time_s);
        assert_eq!(a.api_cost_usd, b.api_cost_usd);
        assert_eq!(a.n_samples, b.n_samples);
        assert_eq!(a.n_ca_events, b.n_ca_events);
        assert_eq!(a.n_errors, b.n_errors);
        assert_eq!(a.call_counts, b.call_counts);
        assert_eq!(a.eval_cache, b.eval_cache);
        assert_eq!(a.lint_rejects, b.lint_rejects);
        assert_eq!(a.faults, b.faults);
        assert_eq!(
            a.best_schedule.trace.running_hash(),
            b.best_schedule.trace.running_hash()
        );
        assert_eq!(a.best_schedule.fingerprint(), b.best_schedule.fingerprint());
    }

    const ALL_WORKLOADS: [&str; 6] = [
        "llama3_attention",
        "deepseek_moe",
        "flux_attention",
        "flux_conv",
        "llama4_mlp",
        "gemm",
    ];

    fn engine_for(workload: &str, n_llms: usize, budget: usize, seed: u64) -> Mcts {
        let w = crate::workloads::by_name(workload).unwrap();
        let sched = Schedule::initial(Arc::new(w));
        let models = ModelSet::new(paper_config(n_llms, "gpt-5.2"));
        Mcts::new(quick_cfg(budget, seed), models, Simulator::new(Target::Cpu), sched)
    }

    #[test]
    fn run_parallel_one_thread_bit_identical_to_run_on_every_workload() {
        // threads=1 must delegate to the serial engine: same RNG streams,
        // same result, same counters — on every built-in workload
        for name in ALL_WORKLOADS {
            let serial = engine_for(name, 4, 30, 11).run(name);
            let par1 = engine_for(name, 4, 30, 11).run_parallel(name, 1);
            assert_results_identical(&serial, &par1);
        }
    }

    #[test]
    fn run_parallel_deterministic_for_fixed_seed_and_threads() {
        // same (seed, threads) twice -> identical SearchResult, down to
        // the cache counters (the exactly-once miss protocol at work)
        let a = engine_for("gemm", 8, 64, 9).run_parallel("gemm", 4);
        let b = engine_for("gemm", 8, 64, 9).run_parallel("gemm", 4);
        assert_results_identical(&a, &b);
        assert_eq!(a.n_samples, 64, "parallel rounds must spend the budget");
        assert!(a.best_speedup > 1.0, "speedup {}", a.best_speedup);
        assert!(
            a.eval_cache.hits + a.eval_cache.misses > 0,
            "parallel search must route evaluation through the shared cache"
        );
        // curve stays sorted and monotone under lane-ordered merges
        for w in a.curve.windows(2) {
            assert!(w[1].0 > w[0].0, "unsorted curve {:?}", a.curve);
            assert!(w[1].1 >= w[0].1 - 1e-9, "curve {:?}", a.curve);
        }
    }

    #[test]
    fn run_parallel_hands_back_warm_shared_cache() {
        // the drained shard union must serve a repeat parallel search
        let (cold, cache) = {
            let e = engine_for("gemm", 2, 40, 13);
            e.run_parallel_with_cache("gemm", 4)
        };
        assert!(!cache.is_empty());
        let w = crate::workloads::by_name("gemm").unwrap();
        let sched = Schedule::initial(Arc::new(w));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let warm_engine = Mcts::with_cache(
            quick_cfg(40, 13),
            models,
            Simulator::new(Target::Cpu),
            sched,
            cache,
        );
        let (warm, _) = warm_engine.run_parallel_with_cache("gemm", 4);
        assert!(
            warm.eval_cache.hits > cold.eval_cache.hits,
            "warm {:?} should out-hit cold {:?}",
            warm.eval_cache,
            cold.eval_cache
        );
        // caching stays observationally transparent in parallel too
        assert_eq!(warm.best_speedup, cold.best_speedup);
        assert_eq!(warm.curve, cold.curve);
    }

    #[test]
    fn virtual_loss_bookkeeping_returns_to_zero() {
        // after a parallel run every virtual loss and pending-expansion
        // mark must have been lifted (leaks would skew later selections)
        let w = crate::workloads::by_name("gemm").unwrap();
        let sched = Schedule::initial(Arc::new(w));
        let models = ModelSet::new(paper_config(4, "gpt-5.2"));
        let mut engine = Mcts::new(quick_cfg(32, 3), models, Simulator::new(Target::Cpu), sched);
        // serial stepping never touches the virtual-loss fields at all
        for _ in 0..5 {
            engine.step();
        }
        assert!(engine
            .nodes
            .iter()
            .all(|n| n.virtual_loss == 0.0 && n.pending_children == 0));
    }

    #[test]
    fn routing_ablations_run() {
        for routing in [Routing::Random, Routing::RoundRobin] {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(8, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            let cfg = SearchConfig {
                routing,
                budget: 60,
                seed: 8,
                ..SearchConfig::default()
            };
            let r = Mcts::new(cfg, models, sim, sched).run("gemm");
            assert!(r.best_speedup >= 1.0);
            let used = r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count();
            assert!(used >= 4, "{routing:?} used only {used} models");
        }
    }
}

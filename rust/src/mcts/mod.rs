//! Shared-tree MCTS with endogenous model selection — the paper's core
//! contribution (§2).
//!
//! Each node is a joint state ⟨program, llm⟩: the schedule reached so far
//! plus the model assigned to expand it. One iteration runs
//! selection (LA-UCT, [`la_uct`]) → expansion (the active LLM proposes a
//! joint ⟨transform-sequence, next-llm⟩ action) → rollout (random
//! transforms, cost-model scored) → backpropagation (reward credited along
//! the selected path, so signal discovered by one model informs all
//! others). Course alteration (§2.5) prunes persistent small-model
//! regressions and re-expands from the same parent with the largest model
//! under a shorter targeted prompt.

pub mod evalcache;
pub mod la_uct;

use crate::costmodel::CostModel;
use crate::llm::prompts::{PromptCtx, VariantCtx};
use crate::llm::{CallKind, ModelSet};
use crate::schedule::printer::print_dominant;
use crate::schedule::transforms::{apply_sequence, TransformKind};
use crate::schedule::Schedule;
use crate::sim::Simulator;
use crate::util::Rng;
use evalcache::{CacheStats, CachedEvaluator, EvalCache, Evaluator};
use std::sync::Arc;

/// Next-model routing policy (Appendix G ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// The paper's mechanism: the active LLM proposes the next model.
    Endogenous,
    /// Ablation: uniform-random next model.
    Random,
    /// Ablation: fixed round-robin next model.
    RoundRobin,
}

/// Search configuration (paper §3.1 defaults).
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// LA-UCT size-preference weight λ (paper: 0.5).
    pub lambda: f64,
    /// UCT exploration constant c (paper: √2).
    pub exploration_c: f64,
    /// Tree branching factor B (paper: 2).
    pub branching: usize,
    /// Search budget in samples (expanded candidates).
    pub budget: usize,
    /// Random-transform rollout depth after expansion.
    pub rollout_depth: usize,
    /// Course alteration after this many consecutive small-model
    /// regressions on a path (paper: Some(2); Appendix F: Some(1)/None).
    pub ca_threshold: Option<usize>,
    /// Measure the top-K predicted candidates every this many samples.
    pub measure_interval: usize,
    pub measure_top_k: usize,
    /// Simulated harness time per measured candidate (compile+run).
    pub measure_overhead_s: f64,
    pub routing: Routing,
    pub seed: u64,
    /// Curve checkpoints (samples) at which best speedup is recorded.
    pub checkpoints: Vec<usize>,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            lambda: 0.5,
            exploration_c: 2f64.sqrt(),
            branching: 2,
            budget: 1000,
            rollout_depth: 2,
            ca_threshold: Some(2),
            measure_interval: 16,
            measure_top_k: 8,
            measure_overhead_s: 1.5,
            routing: Routing::Endogenous,
            seed: 0,
            checkpoints: vec![50, 100, 250, 500, 750, 1000],
        }
    }
}

/// One tree node: a joint ⟨program, llm⟩ state.
///
/// The schedule sits behind an `Arc`: selection, expansion, rollout, and
/// measurement all borrow or refcount-share it instead of deep-cloning,
/// and the prompt renderings the node contributes to LLM context
/// (`code`, `trace_tail`) are computed once here at insertion rather
/// than re-rendered every iteration the node appears as leaf, parent, or
/// grandparent.
#[derive(Clone, Debug)]
struct Node {
    parent: Option<usize>,
    children: Vec<usize>,
    schedule: Arc<Schedule>,
    /// [`print_dominant`] rendering of `schedule`, cached at insertion
    /// and shared into prompt contexts by refcount.
    code: Arc<str>,
    /// `trace.render_tail(PROMPT_TRACE_TAIL)` of `schedule`, cached at
    /// insertion and shared into prompt contexts by refcount.
    trace_tail: Arc<str>,
    /// Model assigned to expand this node.
    llm: usize,
    visits: f64,
    reward_sum: f64,
    predicted_score: f64,
    /// Which model produced this node, and through what call type.
    expanded_by: Option<(usize, CallKind)>,
    depth: usize,
    /// Consecutive small-model regressions on the path ending here
    /// (large-model nodes pass their parent's count through unchanged).
    regression_chain: usize,
    pruned: bool,
    measured: bool,
}

/// Everything a finished search reports.
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub workload: String,
    pub best_speedup: f64,
    pub best_latency_s: f64,
    pub baseline_latency_s: f64,
    /// (samples, best measured speedup) at each checkpoint.
    pub curve: Vec<(usize, f64)>,
    /// Total simulated compilation time: serial LLM latency + measurement.
    pub compile_time_s: f64,
    pub api_cost_usd: f64,
    pub n_samples: usize,
    pub n_ca_events: usize,
    pub n_errors: usize,
    /// (model name, regular calls, ca calls) per model.
    pub call_counts: Vec<(String, usize, usize)>,
    /// Evaluation-cache hit/miss counters for this search (see
    /// [`evalcache`]): nonzero hits mean candidate programs were
    /// re-proposed and served without re-evaluation.
    pub eval_cache: CacheStats,
    pub best_schedule: Schedule,
}

/// Fill `curve` with every configured checkpoint it is missing, carrying
/// `final_speedup` forward for checkpoints the search never reached
/// (instead of silently dropping them), and keep it sorted by sample
/// count. Shared by the MCTS engine and the evolutionary baseline so the
/// [`SearchResult::curve`] contract lives in one place.
pub fn fill_missing_checkpoints(
    curve: &mut Vec<(usize, f64)>,
    checkpoints: &[usize],
    final_speedup: f64,
) {
    for &cp in checkpoints {
        if !curve.iter().any(|&(s, _)| s == cp) {
            curve.push((cp, final_speedup));
        }
    }
    curve.sort_by_key(|&(s, _)| s);
}

impl SearchResult {
    /// Invocation rate of a model (fraction of total calls), regular + CA.
    pub fn invocation_rate(&self, name: &str) -> (f64, f64) {
        let total: usize = self.call_counts.iter().map(|(_, r, c)| r + c).sum();
        let (r, c) = self
            .call_counts
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, r, c)| (*r, *c))
            .unwrap_or((0, 0));
        (
            r as f64 / total.max(1) as f64,
            c as f64 / total.max(1) as f64,
        )
    }
}

/// The shared-tree search engine. All cost-model / simulator access goes
/// through the [`Evaluator`] trait (see [`evalcache`]), so every
/// evaluation — expansion scoring, rollout scoring, course-alteration
/// re-expansion, and periodic measurement — shares one transposition
/// cache.
pub struct Mcts {
    pub cfg: SearchConfig,
    pub models: ModelSet,
    pub eval: CachedEvaluator,
    nodes: Vec<Node>,
    rng: Rng,
    rr_ptr: usize,
    samples: usize,
    measure_time_s: f64,
    n_ca_events: usize,
    n_errors: usize,
    best_latency: f64,
    best_schedule: Arc<Schedule>,
    baseline_latency: f64,
    unmeasured: Vec<usize>,
    curve: Vec<(usize, f64)>,
    max_depth: usize,
    /// `cfg.checkpoints`, sorted and deduped, consumed front-to-back by
    /// `checkpoint_cursor` — the per-step curve check is O(1) instead of
    /// scanning the checkpoint list every sample.
    checkpoints_sorted: Vec<usize>,
    checkpoint_cursor: usize,
    /// Scratch buffers reused across `select()` descents (one tree level
    /// used to allocate two fresh `Vec`s).
    sel_children: Vec<usize>,
    sel_stats: Vec<la_uct::ChildStats>,
}

/// How many trailing trace steps a node contributes to prompt context.
const PROMPT_TRACE_TAIL: usize = 8;

impl Mcts {
    pub fn new(cfg: SearchConfig, models: ModelSet, sim: Simulator, root: Schedule) -> Mcts {
        Mcts::with_cache(cfg, models, sim, root, EvalCache::default())
    }

    /// Build a search that shares an externally owned evaluation cache
    /// (e.g. across repeated searches of the same workload); finish with
    /// [`Mcts::run_with_cache`] to get the warmed cache back.
    pub fn with_cache(
        cfg: SearchConfig,
        models: ModelSet,
        sim: Simulator,
        root: Schedule,
        cache: EvalCache,
    ) -> Mcts {
        let cost = CostModel::new(sim.target, cfg.seed);
        let gpu = sim.target.is_gpu();
        let mut eval = CachedEvaluator::with_cache(cost, sim, cache);
        let mut rng = Rng::new(cfg.seed ^ 0x6C17_E600);
        let root = Arc::new(root);
        let baseline_latency = eval.measure(root.as_ref()).latency_s;
        // start with the largest model driving the root expansion, as a
        // single-model baseline would
        let root_llm = models.largest;
        let root_node = Node {
            parent: None,
            children: Vec::new(),
            schedule: Arc::clone(&root),
            code: print_dominant(root.as_ref(), gpu).into(),
            trace_tail: root.trace.render_tail(PROMPT_TRACE_TAIL).into(),
            llm: root_llm,
            visits: 1.0,
            reward_sum: 0.5,
            predicted_score: 0.5,
            expanded_by: None,
            depth: 0,
            regression_chain: 0,
            pruned: false,
            measured: true,
        };
        // seed cost model with a few random variants so early predictions
        // aren't degenerate
        let vocab = TransformKind::vocabulary(gpu);
        for _ in 0..7 {
            let seq: Vec<_> = (0..3).map(|_| *rng.choice(&vocab)).collect();
            if let Ok(s) = apply_sequence(root.as_ref(), &seq, &mut rng, gpu) {
                eval.measure(&s);
            }
        }
        let best_latency = eval.best_latency();
        let mut checkpoints_sorted = cfg.checkpoints.clone();
        checkpoints_sorted.sort_unstable();
        checkpoints_sorted.dedup();
        Mcts {
            cfg,
            models,
            eval,
            nodes: vec![root_node],
            rng,
            rr_ptr: 0,
            samples: 0,
            measure_time_s: 0.0,
            n_ca_events: 0,
            n_errors: 0,
            best_latency,
            best_schedule: root,
            baseline_latency,
            unmeasured: Vec::new(),
            curve: Vec::new(),
            max_depth: 24,
            checkpoints_sorted,
            checkpoint_cursor: 0,
            sel_children: Vec::new(),
            sel_stats: Vec::new(),
        }
    }

    fn phi(&self, model: usize) -> f64 {
        if self.models.len() == 1 {
            0.0
        } else {
            self.models.phi_small(model)
        }
    }

    /// LA-UCT descent: walk from the root until a node with spare
    /// branching capacity (or the depth cap). Reuses the engine's scratch
    /// buffers — a descent allocates nothing.
    fn select(&mut self) -> usize {
        let mut kids = std::mem::take(&mut self.sel_children);
        let mut stats = std::mem::take(&mut self.sel_stats);
        let mut cur = 0usize;
        loop {
            kids.clear();
            kids.extend(
                self.nodes[cur]
                    .children
                    .iter()
                    .copied()
                    .filter(|&c| !self.nodes[c].pruned),
            );
            if kids.len() < self.cfg.branching || self.nodes[cur].depth >= self.max_depth {
                break;
            }
            stats.clear();
            stats.extend(kids.iter().map(|&c| la_uct::ChildStats {
                visits: self.nodes[c].visits,
                reward_sum: self.nodes[c].reward_sum,
                phi_small: self.phi(self.nodes[c].llm),
            }));
            let pick = la_uct::select(
                &stats,
                self.nodes[cur].visits,
                self.cfg.lambda,
                self.cfg.exploration_c,
            );
            cur = kids[pick];
        }
        self.sel_children = kids;
        self.sel_stats = stats;
        cur
    }

    fn prompt_ctx(&self, node_idx: usize) -> PromptCtx {
        let gpu = self.eval.target().is_gpu();
        let node = &self.nodes[node_idx];
        // code / trace_tail were rendered once when the node was inserted;
        // sharing them here is a refcount bump, not a string copy
        let variant = |i: usize| VariantCtx {
            code: Arc::clone(&self.nodes[i].code),
            trace_tail: Arc::clone(&self.nodes[i].trace_tail),
            score: self.nodes[i].predicted_score,
        };
        let parent_idx = node.parent;
        let gp_idx = parent_idx.and_then(|p| self.nodes[p].parent);
        let model_name =
            |i: Option<usize>| i.map(|n| self.models.specs[self.nodes[n].llm].name.to_string());
        PromptCtx {
            current: variant(node_idx),
            parent: parent_idx.map(variant),
            grandparent: gp_idx.map(variant),
            vocabulary: TransformKind::vocabulary(gpu),
            leaf_depth: node.depth,
            trials_done: self.samples,
            trials_budget: self.cfg.budget,
            model_stats: self.models.stat_lines(),
            local_models: [
                Some(self.models.specs[node.llm].name.to_string()),
                model_name(parent_idx),
                model_name(gp_idx),
            ],
        }
    }

    /// Route the next model according to the configured policy.
    fn route(&mut self, proposed: usize) -> usize {
        match self.cfg.routing {
            Routing::Endogenous => proposed,
            Routing::Random => self.rng.below(self.models.len()),
            Routing::RoundRobin => {
                self.rr_ptr = (self.rr_ptr + 1) % self.models.len();
                self.rr_ptr
            }
        }
    }

    /// One full MCTS iteration. Returns false once the budget is spent.
    pub fn step(&mut self) -> bool {
        if self.samples >= self.cfg.budget {
            return false;
        }
        let leaf = self.select();
        let gpu = self.eval.target().is_gpu();

        // ---- expansion: query the active LLM ---------------------------
        let ctx = self.prompt_ctx(leaf);
        let active = self.nodes[leaf].llm;
        // refcount bump, not a deep copy: the node keeps its program, the
        // expansion borrows it
        let parent_sched = Arc::clone(&self.nodes[leaf].schedule);
        // The model's internal deliberation scores candidate sequences by
        // reading the program: emulated as a blend of the learned cost
        // model and the analytic performance model (an LLM reasons about
        // code structure directly, not only through the tuner's learned
        // predictor). Capability-scaled noise is added by the proposer.
        // Candidates that re-propose an already-seen program are served
        // from the shared evaluation cache.
        let best_lat = self.best_latency;
        let mut eval_rng = self.rng.fork(self.samples as u64);
        let eval = &mut self.eval;
        let mut score_fn = |seq: &[TransformKind]| -> f64 {
            match apply_sequence(parent_sched.as_ref(), seq, &mut eval_rng, gpu) {
                Ok(s) => {
                    let reasoned = (best_lat / eval.true_latency(&s)).clamp(0.0, 1.5);
                    0.4 * eval.score(&s) + 0.6 * reasoned
                }
                Err(_) => 0.0,
            }
        };
        let (proposal, _rec) =
            self.models
                .propose(active, &ctx, CallKind::Regular, &[], &mut score_fn, &mut self.rng);
        self.n_errors += proposal.n_errors;

        let child_sched = match apply_sequence(
            parent_sched.as_ref(),
            &proposal.transforms,
            &mut self.rng,
            gpu,
        ) {
            Ok(s) => s,
            Err(_) => return true, // nothing applicable; spend no sample
        };
        let child_score = self.eval.score(&child_sched);
        let next_llm = self.route(proposal.next_model);
        let parent_score = self.nodes[leaf].predicted_score;
        let parent_chain = self.nodes[leaf].regression_chain;
        let active_is_small = active != self.models.largest;
        // regression = the child is predicted meaningfully worse than its
        // parent (hysteresis absorbs cost-model jitter)
        let regressed = child_score < parent_score - 0.02;
        if !regressed {
            self.models.credit_hit(active, CallKind::Regular);
        }

        // regression chain: small-model regressions accumulate; large-model
        // nodes pass the count through (paper: "ignoring intervening large
        // model nodes"); an improvement resets it.
        let chain = if regressed && active_is_small {
            parent_chain + 1
        } else if regressed {
            parent_chain
        } else {
            0
        };

        // ---- course alteration ------------------------------------------
        let trigger_ca = self
            .cfg
            .ca_threshold
            .map(|t| active_is_small && regressed && chain >= t)
            .unwrap_or(false)
            && self.models.len() > 1;

        let (final_sched, final_score, final_llm, expanded_by, final_chain) = if trigger_ca {
            // prune the regressive proposal (no node inserted, its value
            // never backpropagates), re-expand with the largest model
            self.n_ca_events += 1;
            let largest = self.models.largest;
            let banned = proposal.transforms.clone();
            let best_lat = self.best_latency;
            let mut eval_rng = self.rng.fork(self.samples as u64 ^ 0xCA);
            let eval = &mut self.eval;
            let mut ca_score_fn = |seq: &[TransformKind]| -> f64 {
                match apply_sequence(parent_sched.as_ref(), seq, &mut eval_rng, gpu) {
                    Ok(s) => {
                        let reasoned = (best_lat / eval.true_latency(&s)).clamp(0.0, 1.5);
                        0.4 * eval.score(&s) + 0.6 * reasoned
                    }
                    Err(_) => 0.0,
                }
            };
            let (ca_prop, _) = self.models.propose(
                largest,
                &ctx,
                CallKind::CourseAlteration,
                &banned,
                &mut ca_score_fn,
                &mut self.rng,
            );
            self.n_errors += ca_prop.n_errors;
            match apply_sequence(parent_sched.as_ref(), &ca_prop.transforms, &mut self.rng, gpu) {
                Ok(s) => {
                    let sc = self.eval.score(&s);
                    if sc >= parent_score {
                        self.models.credit_hit(largest, CallKind::CourseAlteration);
                    }
                    let next = self.route(ca_prop.next_model);
                    (s, sc, next, Some((largest, CallKind::CourseAlteration)), 0)
                }
                Err(_) => return true,
            }
        } else {
            (
                child_sched,
                child_score,
                next_llm,
                Some((active, CallKind::Regular)),
                chain,
            )
        };

        // ---- insert child -------------------------------------------------
        let depth = self.nodes[leaf].depth + 1;
        let child_idx = self.nodes.len();
        // render prompt context once, at insertion (re-used every time
        // this node later appears as current/parent/grandparent)
        let code: Arc<str> = print_dominant(&final_sched, gpu).into();
        let trace_tail: Arc<str> = final_sched.trace.render_tail(PROMPT_TRACE_TAIL).into();
        self.nodes.push(Node {
            parent: Some(leaf),
            children: Vec::new(),
            schedule: Arc::new(final_sched),
            code,
            trace_tail,
            llm: final_llm,
            visits: 0.0,
            reward_sum: 0.0,
            predicted_score: final_score,
            expanded_by,
            depth,
            regression_chain: final_chain,
            pruned: false,
            measured: false,
        });
        self.nodes[leaf].children.push(child_idx);
        self.unmeasured.push(child_idx);
        self.samples += 1;

        // ---- rollout --------------------------------------------------------
        // CoW clone: O(blocks) pointer copies, not a deep program copy
        let mut roll = (*self.nodes[child_idx].schedule).clone();
        let vocab = TransformKind::vocabulary(gpu);
        for _ in 0..self.cfg.rollout_depth {
            let k = *self.rng.choice(&vocab);
            if let Ok(next) = crate::schedule::transforms::apply(&roll, k, &mut self.rng, gpu) {
                roll = next;
            }
        }
        let rollout_score = self.eval.score(&roll);
        let reward = final_score.max(rollout_score).clamp(0.0, 1.0);

        // ---- backpropagation -------------------------------------------------
        let mut cur = Some(child_idx);
        while let Some(i) = cur {
            self.nodes[i].visits += 1.0;
            self.nodes[i].reward_sum += reward;
            cur = self.nodes[i].parent;
        }

        // ---- periodic measurement + cost-model retraining ---------------------
        if self.samples % self.cfg.measure_interval == 0 || self.samples >= self.cfg.budget {
            self.measure_batch();
        }
        // curve checkpoints: `samples` grows by one per spent sample, so a
        // sorted cursor replaces the per-step O(checkpoints) list scan;
        // passed (sub-sample-count) checkpoints are skipped exactly like
        // the scan skipped them.
        while self.checkpoint_cursor < self.checkpoints_sorted.len()
            && self.checkpoints_sorted[self.checkpoint_cursor] <= self.samples
        {
            if self.checkpoints_sorted[self.checkpoint_cursor] == self.samples {
                let sp = self.baseline_latency / self.best_latency;
                self.curve.push((self.samples, sp));
            }
            self.checkpoint_cursor += 1;
        }
        true
    }

    /// Measure the top-K unmeasured candidates (by predicted score) on the
    /// simulator; feed the cost model; update the incumbent.
    fn measure_batch(&mut self) {
        // rank by predicted score, best first
        self.unmeasured.sort_by(|&a, &b| {
            self.nodes[b]
                .predicted_score
                .total_cmp(&self.nodes[a].predicted_score)
        });
        let take: Vec<usize> = self
            .unmeasured
            .drain(..self.cfg.measure_top_k.min(self.unmeasured.len()))
            .collect();
        for idx in take {
            let m = self.eval.measure(&*self.nodes[idx].schedule);
            self.nodes[idx].measured = true;
            // harness overhead (simulated compile+run wall-clock) is only
            // charged when the simulator actually ran — a measurement
            // served by the shared eval cache costs no harness time, so
            // warm-cache searches report honest compile_time_s
            if !m.cache_hit {
                self.measure_time_s += self.cfg.measure_overhead_s;
            }
            if m.latency_s < self.best_latency {
                self.best_latency = m.latency_s;
                self.best_schedule = Arc::clone(&self.nodes[idx].schedule);
            }
        }
        self.unmeasured.clear(); // stale predictions aren't re-ranked
    }

    /// Run to budget exhaustion and report.
    pub fn run(self, workload_name: &str) -> SearchResult {
        self.run_with_cache(workload_name).0
    }

    /// Like [`Mcts::run`], but also hands back the warmed evaluation
    /// cache so a follow-up search ([`Mcts::with_cache`]) can reuse every
    /// ground-truth evaluation this one performed.
    pub fn run_with_cache(mut self, workload_name: &str) -> (SearchResult, EvalCache) {
        let mut stall = 0;
        while self.samples < self.cfg.budget && stall < 10_000 {
            let before = self.samples;
            self.step();
            if self.samples == before {
                stall += 1;
            } else {
                stall = 0;
            }
        }
        self.measure_batch();
        let final_speedup = self.baseline_latency / self.best_latency;
        let mut curve = std::mem::take(&mut self.curve);
        // make sure the final point is on the curve
        if !curve.iter().any(|&(s, _)| s == self.samples) {
            curve.push((self.samples, final_speedup));
        }
        // use the same normalized (sorted, deduped) checkpoint list the
        // step() cursor consumed — one source of truth for the curve grid
        fill_missing_checkpoints(&mut curve, &self.checkpoints_sorted, final_speedup);
        let result = SearchResult {
            workload: workload_name.to_string(),
            best_speedup: final_speedup,
            best_latency_s: self.best_latency,
            baseline_latency_s: self.baseline_latency,
            curve,
            compile_time_s: self.models.total_latency_s() + self.measure_time_s,
            api_cost_usd: self.models.total_cost_usd(),
            n_samples: self.samples,
            n_ca_events: self.n_ca_events,
            n_errors: self.n_errors,
            call_counts: self
                .models
                .specs
                .iter()
                .zip(&self.models.stats)
                .map(|(m, s)| (m.name.to_string(), s.regular_calls, s.ca_calls))
                .collect(),
            eval_cache: self.eval.cache_stats(),
            best_schedule: (*self.best_schedule).clone(),
        };
        (result, self.eval.into_cache())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::registry::paper_config;
    use crate::sim::Target;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn quick_cfg(budget: usize, seed: u64) -> SearchConfig {
        SearchConfig {
            budget,
            seed,
            checkpoints: vec![budget / 2, budget],
            ..SearchConfig::default()
        }
    }

    fn run_search(n_llms: usize, budget: usize, seed: u64) -> SearchResult {
        let sched = Schedule::initial(Arc::new(gemm::gemm(512, 512, 512)));
        let models = ModelSet::new(paper_config(n_llms, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        Mcts::new(quick_cfg(budget, seed), models, sim, sched).run("gemm")
    }

    #[test]
    fn search_improves_over_baseline() {
        let r = run_search(2, 60, 1);
        assert!(r.best_speedup > 1.5, "speedup {}", r.best_speedup);
        assert_eq!(r.n_samples, 60);
        assert!(r.api_cost_usd > 0.0);
        assert!(r.compile_time_s > 0.0);
    }

    #[test]
    fn curve_monotone_nondecreasing() {
        let r = run_search(4, 80, 2);
        for w in r.curve.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "curve {:?}", r.curve);
        }
    }

    #[test]
    fn multi_llm_uses_small_models() {
        let r = run_search(8, 120, 3);
        let total: usize = r.call_counts.iter().map(|(_, a, b)| a + b).sum();
        let (big_r, big_c) = r
            .call_counts
            .iter()
            .find(|(n, _, _)| n == "gpt-5.2")
            .map(|(_, a, b)| (*a, *b))
            .unwrap();
        let big_share = (big_r + big_c) as f64 / total as f64;
        assert!(big_share < 0.7, "largest share {big_share}");
        // at least three distinct models used
        let used = r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count();
        assert!(used >= 3, "only {used} models used");
    }

    #[test]
    fn course_alteration_fires() {
        let r = run_search(8, 150, 4);
        assert!(r.n_ca_events > 0, "no CA events in 150 samples");
        let ca_calls: usize = r.call_counts.iter().map(|(_, _, c)| c).sum();
        assert_eq!(ca_calls, r.n_ca_events);
    }

    #[test]
    fn ca_disabled_means_no_ca_calls() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(8, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            ca_threshold: None,
            budget: 80,
            seed: 5,
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        assert_eq!(r.n_ca_events, 0);
    }

    #[test]
    fn single_model_search_works() {
        let r = run_search(1, 50, 6);
        assert!(r.best_speedup >= 1.0);
        assert_eq!(r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count(), 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_search(4, 40, 7);
        let b = run_search(4, 40, 7);
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.api_cost_usd, b.api_cost_usd);
        assert_eq!(a.eval_cache, b.eval_cache);
    }

    #[test]
    fn curve_emits_all_checkpoints_with_carry_forward() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            budget: 30,
            seed: 9,
            checkpoints: vec![10, 30, 100, 1000],
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        let at = |cp: usize| {
            r.curve
                .iter()
                .find(|&&(s, _)| s == cp)
                .map(|&(_, v)| v)
                .unwrap_or_else(|| panic!("checkpoint {cp} missing from {:?}", r.curve))
        };
        // checkpoints past the 30-sample budget carry the final speedup
        assert_eq!(at(100), r.best_speedup);
        assert_eq!(at(1000), r.best_speedup);
        assert!(at(10) <= r.best_speedup + 1e-9);
        // curve stays sorted and monotone
        for w in r.curve.windows(2) {
            assert!(w[1].0 > w[0].0, "unsorted curve {:?}", r.curve);
            assert!(w[1].1 >= w[0].1 - 1e-9, "curve {:?}", r.curve);
        }
    }

    #[test]
    fn repeated_search_with_shared_cache_reports_hits() {
        let mk = |cache: EvalCache| {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(2, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            Mcts::with_cache(quick_cfg(40, 11), models, sim, sched, cache)
        };
        // first search hands back its fully warmed cache
        let (baseline, cache) = mk(EvalCache::new()).run_with_cache("gemm");
        assert!(!cache.is_empty());
        // replay the identical search against the shared cache: adoption
        // resets the counters, and every ground-truth evaluation is
        // already present
        let (r, _) = mk(cache).run_with_cache("gemm");
        assert!(r.eval_cache.hits > 0, "no cache hits: {:?}", r.eval_cache);
        assert!(
            r.eval_cache.hits > baseline.eval_cache.hits,
            "warm run {:?} should out-hit cold run {:?}",
            r.eval_cache,
            baseline.eval_cache
        );
        // caching is transparent: results are identical to the cold run
        assert_eq!(r.best_speedup, baseline.best_speedup);
        assert_eq!(r.curve, baseline.curve);
        assert_eq!(r.api_cost_usd, baseline.api_cost_usd);
    }

    #[test]
    fn warm_cache_search_reports_honest_compile_time() {
        // a measurement served by the shared cache runs no simulator, so
        // it must not be charged measure_overhead_s
        let mk = |cache: EvalCache| {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(2, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            Mcts::with_cache(quick_cfg(40, 21), models, sim, sched, cache)
        };
        let (cold, cache) = mk(EvalCache::new()).run_with_cache("gemm");
        let (warm, _) = mk(cache).run_with_cache("gemm");
        // caching stays observationally transparent on the search outcome
        assert_eq!(warm.best_speedup, cold.best_speedup);
        assert_eq!(warm.curve, cold.curve);
        // but the warm run's harness time is honest: every ground-truth
        // measurement was cache-served, so only LLM latency remains
        assert!(
            warm.compile_time_s < cold.compile_time_s,
            "warm {} !< cold {}",
            warm.compile_time_s,
            cold.compile_time_s
        );
        assert_eq!(warm.api_cost_usd, cold.api_cost_usd);
    }

    #[test]
    fn unsorted_duplicate_checkpoints_recorded_once_in_order() {
        let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
        let models = ModelSet::new(paper_config(2, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        let cfg = SearchConfig {
            budget: 30,
            seed: 9,
            checkpoints: vec![30, 10, 10, 20],
            ..SearchConfig::default()
        };
        let r = Mcts::new(cfg, models, sim, sched).run("gemm");
        let samples: Vec<usize> = r.curve.iter().map(|&(s, _)| s).collect();
        assert_eq!(samples, vec![10, 20, 30], "curve {:?}", r.curve);
    }

    #[test]
    fn deterministic_at_depth_with_rollout_and_ca() {
        // transparency of the CoW/Arc/caching refactor: a fixed-seed
        // search that exercises deep selection, rollouts, and the
        // course-alteration path is bit-for-bit repeatable (same
        // configuration as course_alteration_fires, which pins that this
        // seed triggers CA)
        let a = run_search(8, 150, 4);
        let b = run_search(8, 150, 4);
        assert!(a.n_ca_events > 0, "CA path never exercised");
        assert_eq!(a.best_speedup, b.best_speedup);
        assert_eq!(a.curve, b.curve);
        assert_eq!(a.eval_cache, b.eval_cache);
        assert_eq!(a.call_counts, b.call_counts);
        assert_eq!(a.compile_time_s, b.compile_time_s);
        assert_eq!(a.api_cost_usd, b.api_cost_usd);
        assert_eq!(a.n_samples, b.n_samples);
        assert_eq!(a.best_schedule.trace.running_hash(), b.best_schedule.trace.running_hash());
    }

    #[test]
    fn routing_ablations_run() {
        for routing in [Routing::Random, Routing::RoundRobin] {
            let sched = Schedule::initial(Arc::new(gemm::gemm(256, 256, 256)));
            let models = ModelSet::new(paper_config(8, "gpt-5.2"));
            let sim = Simulator::new(Target::Cpu);
            let cfg = SearchConfig {
                routing,
                budget: 60,
                seed: 8,
                ..SearchConfig::default()
            };
            let r = Mcts::new(cfg, models, sim, sched).run("gemm");
            assert!(r.best_speedup >= 1.0);
            let used = r.call_counts.iter().filter(|(_, a, b)| a + b > 0).count();
            assert!(used >= 4, "{routing:?} used only {used} models");
        }
    }
}

//! Tensor IR substrate (the TVM-TensorIR stand-in).
//!
//! A workload is a set of [`Buffer`]s plus a DAG of [`BlockDef`]s — perfect
//! loop nests with named spatial/reduction axes and affine buffer accesses
//! (each buffer dimension is indexed by a sum of axes, which covers dense
//! matmul, im2col conv, attention, and elementwise epilogues).
//!
//! The IR is deliberately *structured* rather than a general AST: the
//! schedule layer ([`crate::schedule`]) manipulates loop structure
//! symbolically (tiling, reordering, caching, fusion), the simulator
//! ([`crate::sim`]) evaluates it analytically, and the printer
//! ([`printer`]) renders TVMScript-like text for LLM prompt context —
//! exactly the three consumers TVM's TensorIR serves in the paper.

pub mod printer;

use crate::util::fnv::{fnv_f64, fnv_i64, fnv_u64, FNV_OFFSET};
use std::fmt;
use std::sync::OnceLock;

/// Element type of a buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
}

impl DType {
    pub fn bytes(self) -> i64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::BF16 => "bfloat16",
            DType::F16 => "float16",
            DType::I32 => "int32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A dense tensor in the workload.
#[derive(Clone, Debug)]
pub struct Buffer {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: DType,
}

impl Buffer {
    pub fn new(name: &str, shape: &[i64], dtype: DType) -> Buffer {
        assert!(shape.iter().all(|&d| d > 0), "buffer {name}: bad shape");
        Buffer {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    pub fn elems(&self) -> i64 {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> i64 {
        self.elems() * self.dtype.bytes()
    }
}

/// Axis role within a block's iteration domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AxisKind {
    Spatial,
    Reduction,
}

/// One named loop axis of a block.
#[derive(Clone, Debug)]
pub struct Axis {
    pub name: String,
    pub extent: i64,
    pub kind: AxisKind,
}

impl Axis {
    pub fn spatial(name: &str, extent: i64) -> Axis {
        Axis {
            name: name.to_string(),
            extent,
            kind: AxisKind::Spatial,
        }
    }
    pub fn reduction(name: &str, extent: i64) -> Axis {
        Axis {
            name: name.to_string(),
            extent,
            kind: AxisKind::Reduction,
        }
    }
}

/// An affine access: buffer dimension `d` is indexed by the sum of the
/// block axes listed in `dim_axes[d]` (e.g. conv's `h_out + kh`).
/// An empty list means the dimension is broadcast (stride-0).
#[derive(Clone, Debug)]
pub struct Access {
    /// Index into `Workload::buffers`.
    pub buffer: usize,
    /// Per buffer-dimension: the block-axis indices whose sum indexes it.
    pub dim_axes: Vec<Vec<usize>>,
}

impl Access {
    pub fn new(buffer: usize, dim_axes: Vec<Vec<usize>>) -> Access {
        Access { buffer, dim_axes }
    }

    /// True if the given block axis appears anywhere in this access.
    pub fn uses_axis(&self, axis: usize) -> bool {
        self.dim_axes.iter().any(|dims| dims.contains(&axis))
    }

    /// True if the given block axis indexes the *innermost* buffer
    /// dimension (stride-1 direction) — the contiguity test the
    /// vectorizer and GPU-coalescing model rely on.
    pub fn axis_is_contiguous(&self, axis: usize) -> bool {
        self.dim_axes
            .last()
            .map(|dims| dims.contains(&axis))
            .unwrap_or(false)
    }
}

/// Arithmetic character of a block body (used by the simulator to pick
/// throughput tables: MAC-heavy vs transcendental vs data movement).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BodyKind {
    /// Multiply-accumulate contraction (matmul-like).
    Mac,
    /// Elementwise arithmetic chain.
    Elementwise,
    /// Exp/softmax-style transcendental.
    Transcendental,
    /// Max/sum reduction without multiplies.
    Reduce,
    /// Pure data movement (layout/copy/im2col).
    Copy,
}

/// One perfect-loop-nest compute block.
#[derive(Clone, Debug)]
pub struct BlockDef {
    pub name: String,
    pub axes: Vec<Axis>,
    pub reads: Vec<Access>,
    pub writes: Vec<Access>,
    pub body: BodyKind,
    /// FLOPs executed per loop-domain point (2.0 for a MAC).
    pub flops_per_point: f64,
    /// Block indices (into `Workload::blocks`) whose output this block
    /// consumes — the fusion (ComputeLocation) graph.
    pub producers: Vec<usize>,
}

impl BlockDef {
    pub fn domain_points(&self) -> i64 {
        self.axes.iter().map(|a| a.extent).product()
    }

    pub fn spatial_points(&self) -> i64 {
        self.axes
            .iter()
            .filter(|a| a.kind == AxisKind::Spatial)
            .map(|a| a.extent)
            .product()
    }

    pub fn reduction_points(&self) -> i64 {
        self.axes
            .iter()
            .filter(|a| a.kind == AxisKind::Reduction)
            .map(|a| a.extent)
            .product()
    }

    pub fn flops(&self) -> f64 {
        self.domain_points() as f64 * self.flops_per_point
    }

    pub fn has_reduction(&self) -> bool {
        self.axes.iter().any(|a| a.kind == AxisKind::Reduction)
    }
}

/// A complete workload: buffers + block DAG. This is the paper's
/// "unoptimized IRModule".
///
/// Workloads are immutable once evaluation starts (they are built by the
/// workload constructors / scenario lowering, wrapped in an `Arc`, and
/// only read from there); [`Workload::fingerprint`] relies on that —
/// it is computed at most once per instance and cached. A `Clone` starts
/// with an empty fingerprint cache, so cloning-then-editing (as the
/// validation tests do) can never serve a stale fingerprint.
#[derive(Debug)]
pub struct Workload {
    pub name: String,
    pub buffers: Vec<Buffer>,
    pub blocks: Vec<BlockDef>,
    /// Lazily cached structural fingerprint; see [`Workload::fingerprint`].
    fp: OnceLock<u64>,
}

impl Clone for Workload {
    fn clone(&self) -> Workload {
        Workload {
            name: self.name.clone(),
            buffers: self.buffers.clone(),
            blocks: self.blocks.clone(),
            // deliberately NOT cloned: a clone may be mutated before use
            // (the struct's fields are public), so it re-derives its
            // fingerprint from its own — possibly edited — structure
            fp: OnceLock::new(),
        }
    }
}

impl Workload {
    /// Build a workload (fingerprint cache starts empty).
    pub fn new(name: String, buffers: Vec<Buffer>, blocks: Vec<BlockDef>) -> Workload {
        Workload {
            name,
            buffers,
            blocks,
            fp: OnceLock::new(),
        }
    }

    /// Total FLOPs over all blocks.
    pub fn flops(&self) -> f64 {
        self.blocks.iter().map(|b| b.flops()).sum()
    }

    /// Deterministic structural fingerprint of everything the simulator
    /// may read from this workload: buffer shapes and dtypes, and every
    /// block's axes (extent + kind), affine accesses, body kind,
    /// flops-per-point, and producer edges. **Names are deliberately
    /// excluded** — they never influence simulation, so two
    /// differently-named but structurally identical workloads share one
    /// fingerprint (and therefore share block-memo entries, see
    /// [`crate::sim::blockcache`]).
    ///
    /// FNV-1a folded (no randomized hasher state), so the value is stable
    /// across runs, threads, and processes. Computed at most once per
    /// instance and cached; workloads are immutable once evaluated (see
    /// the type docs), which is what makes the caching sound.
    pub fn fingerprint(&self) -> u64 {
        *self.fp.get_or_init(|| {
            let mut h = FNV_OFFSET;
            h = fnv_u64(h, self.buffers.len() as u64);
            for buf in &self.buffers {
                h = fnv_u64(h, buf.shape.len() as u64);
                for &d in &buf.shape {
                    h = fnv_i64(h, d);
                }
                h = fnv_u64(h, buf.dtype as u64);
            }
            h = fnv_u64(h, self.blocks.len() as u64);
            for blk in &self.blocks {
                h = fnv_u64(h, blk.axes.len() as u64);
                for ax in &blk.axes {
                    h = fnv_i64(h, ax.extent);
                    h = fnv_u64(h, ax.kind as u64);
                }
                for accs in [&blk.reads, &blk.writes] {
                    h = fnv_u64(h, accs.len() as u64);
                    for acc in accs {
                        h = fnv_u64(h, acc.buffer as u64);
                        h = fnv_u64(h, acc.dim_axes.len() as u64);
                        for dims in &acc.dim_axes {
                            h = fnv_u64(h, dims.len() as u64);
                            for &a in dims {
                                h = fnv_u64(h, a as u64);
                            }
                        }
                    }
                }
                h = fnv_u64(h, blk.body as u64);
                h = fnv_f64(h, blk.flops_per_point);
                h = fnv_u64(h, blk.producers.len() as u64);
                for &p in &blk.producers {
                    h = fnv_u64(h, p as u64);
                }
            }
            h
        })
    }

    /// Structural validation: access arities match buffer ranks, axis
    /// indices in range, producer edges acyclic and in range. Delegates
    /// to the static analyzer's workload-scope lints
    /// ([`crate::analysis::workload_error`]) so legality has one source
    /// of truth; the error text is the first Deny diagnostic's message.
    pub fn validate(&self) -> Result<(), String> {
        match crate::analysis::workload_error(self) {
            Some(d) => Err(d.message),
            None => Ok(()),
        }
    }

    /// Buffer index by name (panics if missing — used by workload builders
    /// and tests where the name is static).
    pub fn buffer_idx(&self, name: &str) -> usize {
        self.buffers
            .iter()
            .position(|b| b.name == name)
            .unwrap_or_else(|| panic!("no buffer named {name}"))
    }

    /// The consumers of each block (inverse of `producers`).
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut cons = vec![Vec::new(); self.blocks.len()];
        for (bi, blk) in self.blocks.iter().enumerate() {
            for &p in &blk.producers {
                cons[p].push(bi);
            }
        }
        cons
    }

    /// Index of the block doing the most FLOPs — the schedule search's
    /// primary target ("dominant block").
    pub fn dominant_block(&self) -> usize {
        let mut best = 0;
        let mut best_flops = -1.0;
        for (i, b) in self.blocks.iter().enumerate() {
            if b.flops() > best_flops {
                best_flops = b.flops();
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// C[i,j] += A[i,k] * B[k,j] over 64x64x64.
    pub fn tiny_matmul() -> Workload {
        let buffers = vec![
            Buffer::new("A", &[64, 64], DType::F32),
            Buffer::new("B", &[64, 64], DType::F32),
            Buffer::new("C", &[64, 64], DType::F32),
        ];
        let blocks = vec![BlockDef {
            name: "matmul".into(),
            axes: vec![
                Axis::spatial("i", 64),
                Axis::spatial("j", 64),
                Axis::reduction("k", 64),
            ],
            reads: vec![
                Access::new(0, vec![vec![0], vec![2]]),
                Access::new(1, vec![vec![2], vec![1]]),
            ],
            writes: vec![Access::new(2, vec![vec![0], vec![1]])],
            body: BodyKind::Mac,
            flops_per_point: 2.0,
            producers: vec![],
        }];
        Workload::new("tiny_matmul".into(), buffers, blocks)
    }

    #[test]
    fn matmul_flops() {
        let w = tiny_matmul();
        assert_eq!(w.flops(), 2.0 * 64.0 * 64.0 * 64.0);
        w.validate().unwrap();
    }

    #[test]
    fn contiguity() {
        let w = tiny_matmul();
        let blk = &w.blocks[0];
        // A[i,k]: k is the contiguous axis
        assert!(blk.reads[0].axis_is_contiguous(2));
        assert!(!blk.reads[0].axis_is_contiguous(0));
        // C[i,j]: j contiguous
        assert!(blk.writes[0].axis_is_contiguous(1));
    }

    #[test]
    fn validation_catches_bad_rank() {
        let mut w = tiny_matmul();
        w.blocks[0].reads[0].dim_axes.push(vec![0]);
        assert!(w.validate().is_err());
    }

    #[test]
    fn validation_catches_axis_oob() {
        let mut w = tiny_matmul();
        w.blocks[0].reads[0].dim_axes[0] = vec![9];
        assert!(w.validate().is_err());
    }

    #[test]
    fn dominant_block_is_biggest() {
        let w = tiny_matmul();
        assert_eq!(w.dominant_block(), 0);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::F32.name(), "float32");
    }

    #[test]
    fn fingerprint_is_structural_and_name_blind() {
        let a = tiny_matmul();
        let b = tiny_matmul();
        // separately built identical structures share one fingerprint
        // (cross-instance block-memo sharing depends on this)
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.fingerprint(), a.fingerprint(), "cached value stable");
        // names never influence the fingerprint...
        let mut renamed = tiny_matmul();
        renamed.name = "other".into();
        renamed.blocks[0].name = "other_mm".into();
        renamed.buffers[0].name = "X".into();
        assert_eq!(renamed.fingerprint(), a.fingerprint());
        // ...but anything the simulator reads does
        let mut wider = tiny_matmul();
        wider.blocks[0].axes[0].extent = 128;
        assert_ne!(wider.fingerprint(), a.fingerprint());
        let mut retyped = tiny_matmul();
        retyped.buffers[1].dtype = DType::BF16;
        assert_ne!(retyped.fingerprint(), a.fingerprint());
        let mut rebody = tiny_matmul();
        rebody.blocks[0].body = BodyKind::Reduce;
        assert_ne!(rebody.fingerprint(), a.fingerprint());
    }

    #[test]
    fn fingerprint_clone_rederives_from_own_structure() {
        let a = tiny_matmul();
        let fp = a.fingerprint();
        // a clone made after fingerprinting starts uncached and may be
        // edited before use — it must hash its own (edited) structure
        let mut c = a.clone();
        c.blocks[0].flops_per_point = 4.0;
        assert_ne!(c.fingerprint(), fp);
        let unedited = a.clone();
        assert_eq!(unedited.fingerprint(), fp);
    }
}

//! Tensor IR substrate (the TVM-TensorIR stand-in).
//!
//! A workload is a set of [`Buffer`]s plus a DAG of [`BlockDef`]s — perfect
//! loop nests with named spatial/reduction axes and affine buffer accesses
//! (each buffer dimension is indexed by a sum of axes, which covers dense
//! matmul, im2col conv, attention, and elementwise epilogues).
//!
//! The IR is deliberately *structured* rather than a general AST: the
//! schedule layer ([`crate::schedule`]) manipulates loop structure
//! symbolically (tiling, reordering, caching, fusion), the simulator
//! ([`crate::sim`]) evaluates it analytically, and the printer
//! ([`printer`]) renders TVMScript-like text for LLM prompt context —
//! exactly the three consumers TVM's TensorIR serves in the paper.

pub mod printer;

use std::fmt;

/// Element type of a buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    BF16,
    F16,
    I32,
}

impl DType {
    pub fn bytes(self) -> i64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::BF16 | DType::F16 => 2,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::BF16 => "bfloat16",
            DType::F16 => "float16",
            DType::I32 => "int32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// A dense tensor in the workload.
#[derive(Clone, Debug)]
pub struct Buffer {
    pub name: String,
    pub shape: Vec<i64>,
    pub dtype: DType,
}

impl Buffer {
    pub fn new(name: &str, shape: &[i64], dtype: DType) -> Buffer {
        assert!(shape.iter().all(|&d| d > 0), "buffer {name}: bad shape");
        Buffer {
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    pub fn elems(&self) -> i64 {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> i64 {
        self.elems() * self.dtype.bytes()
    }
}

/// Axis role within a block's iteration domain.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AxisKind {
    Spatial,
    Reduction,
}

/// One named loop axis of a block.
#[derive(Clone, Debug)]
pub struct Axis {
    pub name: String,
    pub extent: i64,
    pub kind: AxisKind,
}

impl Axis {
    pub fn spatial(name: &str, extent: i64) -> Axis {
        Axis {
            name: name.to_string(),
            extent,
            kind: AxisKind::Spatial,
        }
    }
    pub fn reduction(name: &str, extent: i64) -> Axis {
        Axis {
            name: name.to_string(),
            extent,
            kind: AxisKind::Reduction,
        }
    }
}

/// An affine access: buffer dimension `d` is indexed by the sum of the
/// block axes listed in `dim_axes[d]` (e.g. conv's `h_out + kh`).
/// An empty list means the dimension is broadcast (stride-0).
#[derive(Clone, Debug)]
pub struct Access {
    /// Index into `Workload::buffers`.
    pub buffer: usize,
    /// Per buffer-dimension: the block-axis indices whose sum indexes it.
    pub dim_axes: Vec<Vec<usize>>,
}

impl Access {
    pub fn new(buffer: usize, dim_axes: Vec<Vec<usize>>) -> Access {
        Access { buffer, dim_axes }
    }

    /// True if the given block axis appears anywhere in this access.
    pub fn uses_axis(&self, axis: usize) -> bool {
        self.dim_axes.iter().any(|dims| dims.contains(&axis))
    }

    /// True if the given block axis indexes the *innermost* buffer
    /// dimension (stride-1 direction) — the contiguity test the
    /// vectorizer and GPU-coalescing model rely on.
    pub fn axis_is_contiguous(&self, axis: usize) -> bool {
        self.dim_axes
            .last()
            .map(|dims| dims.contains(&axis))
            .unwrap_or(false)
    }
}

/// Arithmetic character of a block body (used by the simulator to pick
/// throughput tables: MAC-heavy vs transcendental vs data movement).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BodyKind {
    /// Multiply-accumulate contraction (matmul-like).
    Mac,
    /// Elementwise arithmetic chain.
    Elementwise,
    /// Exp/softmax-style transcendental.
    Transcendental,
    /// Max/sum reduction without multiplies.
    Reduce,
    /// Pure data movement (layout/copy/im2col).
    Copy,
}

/// One perfect-loop-nest compute block.
#[derive(Clone, Debug)]
pub struct BlockDef {
    pub name: String,
    pub axes: Vec<Axis>,
    pub reads: Vec<Access>,
    pub writes: Vec<Access>,
    pub body: BodyKind,
    /// FLOPs executed per loop-domain point (2.0 for a MAC).
    pub flops_per_point: f64,
    /// Block indices (into `Workload::blocks`) whose output this block
    /// consumes — the fusion (ComputeLocation) graph.
    pub producers: Vec<usize>,
}

impl BlockDef {
    pub fn domain_points(&self) -> i64 {
        self.axes.iter().map(|a| a.extent).product()
    }

    pub fn spatial_points(&self) -> i64 {
        self.axes
            .iter()
            .filter(|a| a.kind == AxisKind::Spatial)
            .map(|a| a.extent)
            .product()
    }

    pub fn reduction_points(&self) -> i64 {
        self.axes
            .iter()
            .filter(|a| a.kind == AxisKind::Reduction)
            .map(|a| a.extent)
            .product()
    }

    pub fn flops(&self) -> f64 {
        self.domain_points() as f64 * self.flops_per_point
    }

    pub fn has_reduction(&self) -> bool {
        self.axes.iter().any(|a| a.kind == AxisKind::Reduction)
    }
}

/// A complete workload: buffers + block DAG. This is the paper's
/// "unoptimized IRModule".
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub buffers: Vec<Buffer>,
    pub blocks: Vec<BlockDef>,
}

impl Workload {
    /// Total FLOPs over all blocks.
    pub fn flops(&self) -> f64 {
        self.blocks.iter().map(|b| b.flops()).sum()
    }

    /// Structural validation: access arities match buffer ranks, axis
    /// indices in range, producer edges acyclic and in range.
    pub fn validate(&self) -> Result<(), String> {
        for (bi, blk) in self.blocks.iter().enumerate() {
            for acc in blk.reads.iter().chain(blk.writes.iter()) {
                let buf = self
                    .buffers
                    .get(acc.buffer)
                    .ok_or_else(|| format!("block {}: buffer idx out of range", blk.name))?;
                if acc.dim_axes.len() != buf.shape.len() {
                    return Err(format!(
                        "block {}: access rank {} != buffer {} rank {}",
                        blk.name,
                        acc.dim_axes.len(),
                        buf.name,
                        buf.shape.len()
                    ));
                }
                for dims in &acc.dim_axes {
                    for &ax in dims {
                        if ax >= blk.axes.len() {
                            return Err(format!("block {}: axis idx {} oob", blk.name, ax));
                        }
                    }
                }
            }
            if blk.writes.is_empty() {
                return Err(format!("block {}: no writes", blk.name));
            }
            for &p in &blk.producers {
                if p >= bi {
                    return Err(format!(
                        "block {}: producer {} not earlier in topo order",
                        blk.name, p
                    ));
                }
            }
        }
        Ok(())
    }

    /// Buffer index by name (panics if missing — used by workload builders
    /// and tests where the name is static).
    pub fn buffer_idx(&self, name: &str) -> usize {
        self.buffers
            .iter()
            .position(|b| b.name == name)
            .unwrap_or_else(|| panic!("no buffer named {name}"))
    }

    /// The consumers of each block (inverse of `producers`).
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut cons = vec![Vec::new(); self.blocks.len()];
        for (bi, blk) in self.blocks.iter().enumerate() {
            for &p in &blk.producers {
                cons[p].push(bi);
            }
        }
        cons
    }

    /// Index of the block doing the most FLOPs — the schedule search's
    /// primary target ("dominant block").
    pub fn dominant_block(&self) -> usize {
        let mut best = 0;
        let mut best_flops = -1.0;
        for (i, b) in self.blocks.iter().enumerate() {
            if b.flops() > best_flops {
                best_flops = b.flops();
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// C[i,j] += A[i,k] * B[k,j] over 64x64x64.
    pub fn tiny_matmul() -> Workload {
        let buffers = vec![
            Buffer::new("A", &[64, 64], DType::F32),
            Buffer::new("B", &[64, 64], DType::F32),
            Buffer::new("C", &[64, 64], DType::F32),
        ];
        let blocks = vec![BlockDef {
            name: "matmul".into(),
            axes: vec![
                Axis::spatial("i", 64),
                Axis::spatial("j", 64),
                Axis::reduction("k", 64),
            ],
            reads: vec![
                Access::new(0, vec![vec![0], vec![2]]),
                Access::new(1, vec![vec![2], vec![1]]),
            ],
            writes: vec![Access::new(2, vec![vec![0], vec![1]])],
            body: BodyKind::Mac,
            flops_per_point: 2.0,
            producers: vec![],
        }];
        Workload {
            name: "tiny_matmul".into(),
            buffers,
            blocks,
        }
    }

    #[test]
    fn matmul_flops() {
        let w = tiny_matmul();
        assert_eq!(w.flops(), 2.0 * 64.0 * 64.0 * 64.0);
        w.validate().unwrap();
    }

    #[test]
    fn contiguity() {
        let w = tiny_matmul();
        let blk = &w.blocks[0];
        // A[i,k]: k is the contiguous axis
        assert!(blk.reads[0].axis_is_contiguous(2));
        assert!(!blk.reads[0].axis_is_contiguous(0));
        // C[i,j]: j contiguous
        assert!(blk.writes[0].axis_is_contiguous(1));
    }

    #[test]
    fn validation_catches_bad_rank() {
        let mut w = tiny_matmul();
        w.blocks[0].reads[0].dim_axes.push(vec![0]);
        assert!(w.validate().is_err());
    }

    #[test]
    fn validation_catches_axis_oob() {
        let mut w = tiny_matmul();
        w.blocks[0].reads[0].dim_axes[0] = vec![9];
        assert!(w.validate().is_err());
    }

    #[test]
    fn dominant_block_is_biggest() {
        let w = tiny_matmul();
        assert_eq!(w.dominant_block(), 0);
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::F32.name(), "float32");
    }
}

//! TVMScript-like rendering of an *unscheduled* workload (default loop
//! order). Scheduled programs are rendered by [`crate::schedule::printer`],
//! which shows the tiled/annotated loop structure.

use super::{AxisKind, BlockDef, Workload};

/// Render the function signature line.
pub fn signature(w: &Workload) -> String {
    let params: Vec<String> = w
        .buffers
        .iter()
        .map(|b| {
            format!(
                "{}: T.Buffer(({}), \"{}\")",
                b.name,
                b.shape
                    .iter()
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join(", "),
                b.dtype.name()
            )
        })
        .collect();
    format!("def main({}):", params.join(", "))
}

fn body_expr(w: &Workload, blk: &BlockDef) -> String {
    let fmt_access = |acc: &super::Access| -> String {
        let idx: Vec<String> = acc
            .dim_axes
            .iter()
            .map(|dims| {
                if dims.is_empty() {
                    "0".to_string()
                } else {
                    dims.iter()
                        .map(|&a| blk.axes[a].name.clone())
                        .collect::<Vec<_>>()
                        .join(" + ")
                }
            })
            .collect();
        format!("{}[{}]", w.buffers[acc.buffer].name, idx.join(", "))
    };
    let out = fmt_access(&blk.writes[0]);
    let ins: Vec<String> = blk.reads.iter().map(fmt_access).collect();
    use super::BodyKind::*;
    match blk.body {
        Mac => format!("{out} = {out} + {}", ins.join(" * ")),
        Elementwise => format!("{out} = f({})", ins.join(", ")),
        Transcendental => format!("{out} = T.exp({})", ins.join(", ")),
        Reduce => format!("{out} = T.max({out}, {})", ins.join(", ")),
        Copy => format!("{out} = {}", ins.first().cloned().unwrap_or_default()),
    }
}

/// Full TVMScript-like text for the unscheduled workload.
pub fn print_workload(w: &Workload) -> String {
    let mut s = String::from("@T.prim_func\n");
    s.push_str(&signature(w));
    s.push('\n');
    for blk in &w.blocks {
        let mut indent = 1;
        for ax in &blk.axes {
            let kind = match ax.kind {
                AxisKind::Spatial => "T.serial",
                AxisKind::Reduction => "T.serial",
            };
            s.push_str(&"    ".repeat(indent));
            s.push_str(&format!("for {} in {}({}):\n", ax.name, kind, ax.extent));
            indent += 1;
        }
        s.push_str(&"    ".repeat(indent));
        s.push_str(&format!("with T.block(\"{}\"):\n", blk.name));
        s.push_str(&"    ".repeat(indent + 1));
        s.push_str(&body_expr(w, blk));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tir::{Access, Axis, BlockDef, BodyKind, Buffer, DType};

    fn mm() -> Workload {
        Workload::new(
            "mm".into(),
            vec![
                Buffer::new("A", &[8, 8], DType::F32),
                Buffer::new("B", &[8, 8], DType::F32),
                Buffer::new("C", &[8, 8], DType::F32),
            ],
            vec![BlockDef {
                name: "matmul".into(),
                axes: vec![
                    Axis::spatial("i", 8),
                    Axis::spatial("j", 8),
                    Axis::reduction("k", 8),
                ],
                reads: vec![
                    Access::new(0, vec![vec![0], vec![2]]),
                    Access::new(1, vec![vec![2], vec![1]]),
                ],
                writes: vec![Access::new(2, vec![vec![0], vec![1]])],
                body: BodyKind::Mac,
                flops_per_point: 2.0,
                producers: vec![],
            }],
        )
    }

    #[test]
    fn prints_loops_and_block() {
        let text = print_workload(&mm());
        assert!(text.contains("@T.prim_func"));
        assert!(text.contains("for i in T.serial(8):"));
        assert!(text.contains("with T.block(\"matmul\"):"));
        assert!(text.contains("C[i, j] = C[i, j] + A[i, k] * B[k, j]"));
    }

    #[test]
    fn signature_lists_buffers() {
        let sig = signature(&mm());
        assert!(sig.contains("A: T.Buffer((8, 8), \"float32\")"));
    }
}

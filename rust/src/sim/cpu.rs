//! Analytic CPU performance model (Intel i9-class core).
//!
//! Latency per block = max(compute bound, DRAM bound, L2 bound) with
//! parallel-scaling, vectorization, unroll/ILP, accumulator, and loop
//! overhead effects — every schedule primitive has a physically-motivated
//! lever here, so the search space has realistic structure (tiling changes
//! cache fit, vectorize needs contiguity, parallel saturates cores, ...).

use super::footprint::{analyze, Traffic};
use crate::schedule::{LoopKind, Schedule};
use crate::tir::BodyKind;

/// i9-13900K-ish (the paper's Intel Core i9 target, conservative numbers).
#[derive(Clone, Copy, Debug)]
pub struct CpuSpec {
    pub cores: i64,
    pub freq_ghz: f64,
    /// f32 SIMD lanes (AVX2 = 8).
    pub simd_lanes: i64,
    /// FMA units per core.
    pub fma_ports: f64,
    pub l1_bytes: f64,
    pub l2_bytes: f64,
    pub dram_gbs: f64,
    pub l2_gbs: f64,
    /// Per-parallel-task spawn overhead (seconds).
    pub spawn_overhead: f64,
}

impl Default for CpuSpec {
    fn default() -> Self {
        CpuSpec {
            cores: 8,
            freq_ghz: 4.5,
            simd_lanes: 8,
            fma_ports: 2.0,
            l1_bytes: 48.0 * 1024.0,
            l2_bytes: 2.0 * 1024.0 * 1024.0,
            dram_gbs: 70.0,
            l2_gbs: 900.0,
            spawn_overhead: 4e-6,
        }
    }
}

impl CpuSpec {
    /// Peak f32 GFLOP/s of the whole chip.
    pub fn peak_gflops(&self) -> f64 {
        self.cores as f64 * self.freq_ghz * self.simd_lanes as f64 * self.fma_ports * 2.0
    }
}

/// Throughput derate per body kind (fraction of FMA peak achievable).
fn body_factor(body: BodyKind) -> f64 {
    match body {
        BodyKind::Mac => 1.0,
        BodyKind::Elementwise => 0.5,
        BodyKind::Transcendental => 0.12, // exp ≈ 8x the cost of an FMA
        BodyKind::Reduce => 0.5,
        BodyKind::Copy => 0.0, // pure movement, memory-bound by definition
    }
}

/// Latency (seconds) of one block under this schedule on the CPU.
///
/// # Memo-key contract (audited)
///
/// This is a pure function of `(spec, s.workload, block, s.blocks[block])`
/// — it reads the block's own definition, its own [`BlockSched`]
/// (via `s.blocks[block]` and the nest materialized from it), and the
/// workload's buffer dtypes, and **nothing from any other block's
/// schedule state**. The incremental evaluator
/// ([`crate::sim::Simulator::latency`]) memoizes its result under exactly
/// those inputs; if you add a cross-block dependency here (e.g. reading a
/// producer's tiling), fold it into the memo key or the memo will serve
/// stale values (the debug differential assert will catch it).
///
/// [`BlockSched`]: crate::schedule::BlockSched
pub fn block_latency(spec: &CpuSpec, s: &Schedule, block: usize) -> (f64, Traffic) {
    let blk = &s.workload.blocks[block];
    let bs = &s.blocks[block];
    let nest = s.loop_nest(block, false);
    let traffic = analyze(s, block, &nest, spec.l1_bytes, spec.l2_bytes);

    // ---- parallel scaling -------------------------------------------------
    let par_extent = nest.parallel_extent().max(1);
    let cores_used = par_extent.min(spec.cores) as f64;
    // load imbalance: last wave underfilled
    let waves = (par_extent as f64 / spec.cores as f64).ceil();
    let balance = par_extent as f64 / (waves * spec.cores as f64).max(1.0);
    let par_eff = if par_extent == 1 {
        1.0 / spec.cores as f64 // single core of the chip
    } else {
        cores_used / spec.cores as f64 * balance.max(0.5)
    };

    // ---- vectorization ----------------------------------------------------
    let lanes = nest.vector_lanes();
    let vec_loop_axis = nest
        .loops
        .iter()
        .rev()
        .find(|l| l.kind == LoopKind::Vectorized)
        .map(|l| l.axis);
    let vec_eff = match vec_loop_axis {
        Some(ax) => {
            // need contiguity in the write and at least one read
            let w_ok = blk.writes[0].axis_is_contiguous(ax);
            let r_ok = blk.reads.iter().any(|r| r.axis_is_contiguous(ax) || !r.uses_axis(ax));
            let width = (lanes.min(spec.simd_lanes) as f64) / spec.simd_lanes as f64;
            if w_ok && r_ok {
                width
            } else {
                // gather/scatter vectorization: marginal gain
                0.35 * width + 0.25
            }
        }
        // llvm auto-vectorization floor on the innermost loop
        None => 0.25,
    };

    // ---- ILP: unroll + register accumulation ------------------------------
    let unrolled = nest.unrolled_product().max(1) as f64;
    let ilp = 0.55 + 0.45 * (unrolled.log2() / 3.0).clamp(0.0, 1.0);
    // reduction blocks without a register accumulator stall on store-load
    let acc_eff = if blk.has_reduction() && !bs.cache_write {
        0.55
    } else {
        1.0
    };
    // decomposed reduction: init loop no longer pollutes the hot loop
    let decomp_eff = if blk.has_reduction() && bs.decomposed { 1.0 } else if blk.has_reduction() { 0.92 } else { 1.0 };

    // register pressure penalty: huge inner tiles spill
    let spill = if traffic.inner_tile_bytes > 16.0 * 1024.0 {
        0.7
    } else {
        1.0
    };

    let flops = blk.flops();
    let bf = body_factor(blk.body);
    let t_compute = if bf > 0.0 {
        flops / (spec.peak_gflops() * 1e9 * bf * par_eff * vec_eff * ilp * acc_eff * decomp_eff * spill)
    } else {
        0.0
    };

    // ---- memory -----------------------------------------------------------
    // strided/unpacked reads waste bandwidth; cache_read packing fixes it
    let mut dram = traffic.dram_bytes;
    let mut ri = 0;
    for (idx, r) in blk.reads.iter().enumerate() {
        // innermost nest loop axis determines streaming friendliness
        if let Some(last) = nest.loops.last() {
            let contiguous = r.axis_is_contiguous(last.axis) || !r.uses_axis(last.axis);
            let packed = bs.cache_reads[idx].is_some();
            if !contiguous && !packed {
                // strided stream: ~2x DRAM cost (partial cacheline use)
                if ri < traffic.per_access_dram.len() {
                    dram += traffic.per_access_dram[ri];
                }
            }
        }
        ri += 1;
    }
    // parallel DRAM bw saturates with ~4 cores
    let bw_scale = (cores_used / 4.0).clamp(0.35, 1.0);
    let t_dram = dram / (spec.dram_gbs * 1e9 * bw_scale);
    let t_l2 = traffic.l2_bytes / (spec.l2_gbs * 1e9 * (cores_used / spec.cores as f64).max(0.2));

    // ---- overheads ---------------------------------------------------------
    // chunked runtime (OpenMP-static style): at most ~4 tasks per core
    let t_spawn = if par_extent > 1 {
        (par_extent.min(4 * spec.cores) as f64) * spec.spawn_overhead / cores_used
    } else {
        0.0
    };
    // loop management: ~1 cycle per non-unrolled, non-vectorized iteration
    let dyn_iters: f64 = nest
        .loops
        .iter()
        .filter(|l| !matches!(l.kind, LoopKind::Vectorized | LoopKind::Unrolled))
        .map(|l| l.extent as f64)
        .product();
    let t_loop = dyn_iters.min(flops.max(1.0)) * 0.15e-9 / cores_used;

    let lat = t_compute.max(t_dram).max(t_l2) + t_spawn + t_loop;
    (lat, traffic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn base() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(1024, 1024, 1024)))
    }

    #[test]
    fn parallel_speeds_up() {
        let spec = CpuSpec::default();
        let mut rng = Rng::new(1);
        let s0 = base();
        let (l0, _) = block_latency(&spec, &s0, 0);
        let s1 = apply(&s0, TransformKind::Parallel, &mut rng, false).unwrap();
        let (l1, _) = block_latency(&spec, &s1, 0);
        assert!(l1 < l0, "parallel {l1} !< naive {l0}");
    }

    #[test]
    fn vectorize_speeds_up() {
        let spec = CpuSpec::default();
        let mut rng = Rng::new(2);
        let s0 = base();
        let (l0, _) = block_latency(&spec, &s0, 0);
        let s1 = apply(&s0, TransformKind::Vectorize, &mut rng, false).unwrap();
        let (l1, _) = block_latency(&spec, &s1, 0);
        assert!(l1 < l0);
    }

    #[test]
    fn well_tuned_gemm_reaches_sane_speedup() {
        let spec = CpuSpec::default();
        let s0 = base();
        let (naive, _) = block_latency(&spec, &s0, 0);

        let mut s = base();
        s.block_mut(0).retile(0, vec![16, 4, 16]);
        s.block_mut(0).retile(1, vec![8, 16, 8]);
        s.block_mut(0).retile(2, vec![256, 4]);
        s.block_mut(0).order = vec![
            (0, 0),
            (1, 0),
            (2, 0),
            (0, 1),
            (1, 1),
            (2, 1),
            (0, 2),
            (1, 2),
        ];
        s.block_mut(0).parallel = 2;
        s.block_mut(0).vectorize = true;
        s.block_mut(0).unroll = 2;
        s.block_mut(0).cache_write = true;
        s.block_mut(0).decomposed = true;
        s.validate().unwrap();
        let (tuned, _) = block_latency(&spec, &s, 0);

        let speedup = naive / tuned;
        assert!(
            (4.0..400.0).contains(&speedup),
            "speedup {speedup} out of plausible band (naive {naive}, tuned {tuned})"
        );
        // tuned GEMM should hit a decent fraction of peak
        let gflops = 2.0 * 1024f64.powi(3) / tuned / 1e9;
        assert!(gflops > 50.0, "tuned gemm only {gflops} GFLOP/s");
    }

    #[test]
    fn transcendental_slower_than_mac() {
        let spec = CpuSpec::default();
        let w = crate::workloads::mlp::llama4_mlp();
        let s = Schedule::initial(Arc::new(w));
        let silu_idx = s.workload.blocks.iter().position(|b| b.name == "silu_mul").unwrap();
        let (l_silu, _) = block_latency(&spec, &s, silu_idx);
        assert!(l_silu > 0.0);
    }

    #[test]
    fn latency_always_positive_under_storm() {
        let spec = CpuSpec::default();
        let mut rng = Rng::new(3);
        let mut s = base();
        let vocab = TransformKind::vocabulary(false);
        for _ in 0..100 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), &mut rng, false) {
                s = n;
            }
            let (l, _) = block_latency(&spec, &s, 0);
            assert!(l.is_finite() && l > 0.0);
        }
    }
}

//! Analytic GPU performance model (NVIDIA 2080 Ti-class).
//!
//! Blocks map `parallel` loops to blockIdx and `thread_tiles` loops to
//! threadIdx. Occupancy is limited by threads/block and shared-memory use
//! (cache_read staging); memory efficiency by coalescing (contiguity of
//! the innermost thread-mapped axis); compute by occupancy × ILP. A
//! default auto-mapping floor models how TVM's unoptimized IRModule still
//! runs on the GPU (the paper's "pre-optimized code" baseline).

use super::footprint::{analyze, Traffic};
use crate::schedule::{LoopKind, Schedule};
use crate::tir::BodyKind;

/// RTX 2080 Ti (the paper's GPU target).
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub sms: i64,
    pub cuda_cores_per_sm: i64,
    pub freq_ghz: f64,
    pub max_threads_per_sm: i64,
    pub max_threads_per_block: i64,
    pub smem_per_sm: f64,
    pub dram_gbs: f64,
    pub l2_bytes: f64,
    pub l2_gbs: f64,
    pub launch_overhead: f64,
}

impl Default for GpuSpec {
    fn default() -> Self {
        GpuSpec {
            sms: 68,
            cuda_cores_per_sm: 64,
            freq_ghz: 1.545,
            max_threads_per_sm: 1024,
            max_threads_per_block: 1024,
            smem_per_sm: 64.0 * 1024.0,
            dram_gbs: 616.0,
            l2_bytes: 5.5 * 1024.0 * 1024.0,
            l2_gbs: 2000.0,
            launch_overhead: 5e-6,
        }
    }
}

impl GpuSpec {
    /// Peak f32 GFLOP/s (FMA).
    pub fn peak_gflops(&self) -> f64 {
        self.sms as f64 * self.cuda_cores_per_sm as f64 * self.freq_ghz * 2.0
    }
}

fn body_factor(body: BodyKind) -> f64 {
    match body {
        BodyKind::Mac => 1.0,
        BodyKind::Elementwise => 0.5,
        BodyKind::Transcendental => 0.25, // SFU-assisted
        BodyKind::Reduce => 0.4,
        BodyKind::Copy => 0.0,
    }
}

/// Latency (seconds) of one block under this schedule on the GPU.
///
/// # Memo-key contract (audited)
///
/// Pure function of `(spec, s.workload, block, s.blocks[block])` — same
/// contract as [`crate::sim::cpu::block_latency`]: no other block's
/// schedule state is read, which is what lets
/// [`crate::sim::Simulator::latency`] memoize per-block results under
/// (spec, workload fingerprint, block index, block fingerprint). Fold any
/// new cross-block input into that key.
pub fn block_latency(spec: &GpuSpec, s: &Schedule, block: usize) -> (f64, Traffic) {
    let blk = &s.workload.blocks[block];
    let bs = &s.blocks[block];
    let nest = s.loop_nest(block, true);
    // shared memory per block ~ footprint of cache_read staged tiles;
    // approximate with the L1-level analysis (smem plays the L1 role)
    let traffic = analyze(s, block, &nest, spec.smem_per_sm / 2.0, spec.l2_bytes);

    let explicit_grid = nest.parallel_extent();
    let explicit_threads = nest.thread_extent();

    // ---- auto-mapping floor (unscheduled kernels still run) --------------
    let spatial: i64 = blk.spatial_points();
    let (grid, threads, auto_mapped) = if explicit_threads > 1 {
        (explicit_grid.max(1), explicit_threads.min(spec.max_threads_per_block), false)
    } else if explicit_grid > 1 {
        // blocks but no thread binding: 32 threads default
        (explicit_grid, 32, true)
    } else {
        // fully default: naive flat mapping — the TVM unoptimized-IRModule
        // fallback barely fills the machine
        ((spatial / 128).clamp(1, 256), 128, true)
    };

    // ---- occupancy ---------------------------------------------------------
    let smem_used = if bs.cache_reads.iter().any(Option::is_some) {
        traffic.inner_tile_bytes.min(spec.smem_per_sm)
    } else {
        0.0
    };
    let blocks_by_threads = (spec.max_threads_per_sm / threads.max(1)).max(1);
    let blocks_by_smem = if smem_used > 0.0 {
        ((spec.smem_per_sm / smem_used) as i64).max(1)
    } else {
        16
    };
    let blocks_per_sm = blocks_by_threads.min(blocks_by_smem).min(16);
    let warps = ((threads + 31) / 32) * blocks_per_sm;
    let occupancy = (warps as f64 * 32.0 / spec.max_threads_per_sm as f64).clamp(0.05, 1.0);

    // wave quantization: how many rounds of blocks the grid needs
    let concurrent_blocks = (spec.sms * blocks_per_sm) as f64;
    let waves = (grid as f64 / concurrent_blocks).ceil().max(1.0);
    let wave_fill = grid as f64 / (waves * concurrent_blocks);
    // small grids can't fill the machine
    let sm_util = (grid as f64 / spec.sms as f64).clamp(0.02, 1.0).min(1.0) * wave_fill.max(0.5);

    // ---- ILP / auto floor ---------------------------------------------------
    let unrolled = nest.unrolled_product().max(1) as f64;
    let ilp = 0.5 + 0.5 * (unrolled.log2() / 3.0).clamp(0.0, 1.0);
    let acc_eff = if blk.has_reduction() && !bs.cache_write { 0.5 } else { 1.0 };
    // default-mapped kernels run far from peak: scalar code, no tiling of
    // the register file, no software pipelining
    let auto_penalty = if auto_mapped { 0.03 } else { 1.0 };

    let flops = blk.flops();
    let bf = body_factor(blk.body);
    let t_compute = if bf > 0.0 {
        flops
            / (spec.peak_gflops() * 1e9
                * bf
                * occupancy
                * sm_util
                * ilp
                * acc_eff
                * auto_penalty)
    } else {
        0.0
    };

    // ---- memory: coalescing + smem reuse ------------------------------------
    // coalescing: the innermost loop (thread-vector direction) must be
    // contiguous in the majority of accesses
    let inner_axis = nest.loops.last().map(|l| l.axis);
    let coalesced = match inner_axis {
        Some(ax) => {
            let n_ok = blk
                .reads
                .iter()
                .chain(blk.writes.iter())
                .filter(|a| a.axis_is_contiguous(ax) || !a.uses_axis(ax))
                .count();
            n_ok * 2 >= blk.reads.len() + blk.writes.len()
        }
        None => false,
    };
    let smem_staged = bs.cache_reads.iter().any(Option::is_some);
    let bw_eff = match (coalesced, smem_staged) {
        (true, _) => 1.0,
        (false, true) => 0.8, // staged through smem: strided cost paid once
        (false, false) => 0.15,
    };
    let t_dram = traffic.dram_bytes / (spec.dram_gbs * 1e9 * bw_eff * sm_util.max(0.3));
    let t_l2 = traffic.l2_bytes / (spec.l2_gbs * 1e9);

    let lat = t_compute.max(t_dram).max(t_l2) * if auto_mapped { 1.2 } else { 1.0 }
        + spec.launch_overhead * waves.min(8.0);
    (lat, traffic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply, TransformKind};
    use crate::util::Rng;
    use crate::workloads::gemm;
    use std::sync::Arc;

    fn base() -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(2048, 2048, 2048)))
    }

    #[test]
    fn thread_binding_speeds_up() {
        let spec = GpuSpec::default();
        let mut rng = Rng::new(1);
        let s0 = base();
        let (l0, _) = block_latency(&spec, &s0, 0);
        let mut s = s0.clone();
        for k in [TransformKind::TileSize, TransformKind::Parallel, TransformKind::ThreadBind] {
            if let Ok(n) = apply(&s, k, &mut rng, true) {
                s = n;
            }
        }
        let (l1, _) = block_latency(&spec, &s, 0);
        assert!(l1 < l0, "{l1} !< {l0}");
    }

    #[test]
    fn tuned_gemm_plausible_band() {
        let spec = GpuSpec::default();
        let s0 = base();
        let (naive, _) = block_latency(&spec, &s0, 0);

        let mut s = base();
        s.block_mut(0).retile(0, vec![32, 4, 16]);
        s.block_mut(0).retile(1, vec![32, 8, 8]);
        s.block_mut(0).retile(2, vec![512, 4]);
        s.block_mut(0).order = vec![
            (0, 0),
            (1, 0),
            (0, 1),
            (1, 1),
            (2, 0),
            (0, 2),
            (2, 1),
            (1, 2),
        ];
        s.block_mut(0).parallel = 2;
        s.block_mut(0).thread_tiles = 2;
        s.block_mut(0).vectorize = true;
        s.block_mut(0).cache_write = true;
        s.block_mut(0).cache_reads = vec![Some(4), Some(4)];
        s.validate().unwrap();
        let (tuned, _) = block_latency(&spec, &s, 0);
        let speedup = naive / tuned;
        assert!(
            (5.0..1000.0).contains(&speedup),
            "gpu speedup {speedup} (naive {naive} tuned {tuned})"
        );
        let gflops = 2.0 * 2048f64.powi(3) / tuned / 1e9;
        assert!(gflops > 1000.0, "tuned gpu gemm {gflops} GFLOP/s");
    }

    #[test]
    fn storm_stays_finite() {
        let spec = GpuSpec::default();
        let mut rng = Rng::new(2);
        let mut s = base();
        let vocab = TransformKind::vocabulary(true);
        for _ in 0..100 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), &mut rng, true) {
                s = n;
            }
            let (l, _) = block_latency(&spec, &s, 0);
            assert!(l.is_finite() && l > 0.0);
        }
    }
}

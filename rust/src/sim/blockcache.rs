//! Per-block simulation memo — the incremental-evaluation substrate
//! behind [`Simulator::latency`](crate::sim::Simulator::latency).
//!
//! # Why
//!
//! The search's pervasive pattern is "clone a schedule, mutate **one**
//! block, evaluate": every MCTS expansion, rollout step, and candidate
//! scoring call produces a schedule differing from an already-evaluated
//! one in a single block. The schedule-level evaluation cache
//! ([`crate::mcts::evalcache`]) only helps when the *whole program* was
//! seen before; this memo makes the common partial-overlap case cheap by
//! memoizing each block's simulated contribution, so evaluating a fresh
//! candidate costs O(mutated blocks) simulator work instead of
//! O(all blocks) — the measurement-amortization COLT's shared tree
//! promises, carried down into the simulator.
//!
//! # Keying (what invalidates an entry)
//!
//! A block's contribution is memoized under an FNV-1a fold of:
//!
//! * the **precomputed simulator instance key**
//!   ([`Simulator::instance_key`](crate::sim::Simulator::instance_key))
//!   — target plus every spec field
//!   ([`CpuSpec`](crate::sim::cpu::CpuSpec) /
//!   [`GpuSpec`](crate::sim::gpu::GpuSpec) values, not identity), folded
//!   **once per simulator** at construction (and re-folded by the spec
//!   mutators), not once per lookup: `latency` extends the stored prefix
//!   with one `fnv_u64` per call, so two simulators configured alike
//!   share entries and an edited spec can never serve stale values;
//! * the **workload structural fingerprint**
//!   ([`Workload::fingerprint`](crate::tir::Workload::fingerprint)) —
//!   everything the per-block models read from the workload;
//! * the **block index**;
//! * the **block-schedule fingerprint**
//!   ([`BlockSched::fingerprint`](crate::schedule::BlockSched::fingerprint))
//!   — every schedule field of that block, invalidated by
//!   [`Schedule::block_mut`](crate::schedule::Schedule::block_mut).
//!
//! Cross-block audit: the per-block models (`cpu::block_latency`,
//! `gpu::block_latency`, `footprint::analyze`) and the `compute_at`
//! fusion credit read **only** the keyed inputs above — fusion charges
//! the *producer's* own `compute_at` depth against its own write
//! traffic; a consumer's latency never depends on another block's
//! schedule state. Any future cross-block input MUST be folded into the
//! key (see the contract notes on those functions); the debug-build
//! differential assert in `Simulator::latency` and the
//! `prop_incremental_latency_is_bit_identical_to_full` property exist to
//! catch exactly that class of regression.
//!
//! # Transparency & determinism
//!
//! Memoized values are pure functions of their keys and are summed in
//! the same block order as a full recompute, so `Simulator::latency` is
//! **bit-identical** with the memo hot, cold, full, or disabled. The
//! memo is **thread-local** (one per OS thread): search workers — driver
//! lanes and the tree-parallel
//! [`WorkerPool`](crate::runtime::driver::WorkerPool) — each warm their
//! own, nothing is shared, and since served values are bit-identical to
//! recomputation, every cross-thread determinism contract in the crate
//! is unaffected. A full memo degrades to compute-without-insert, never
//! to a wrong answer.

use std::cell::RefCell;
use std::collections::HashMap;

/// Hit/miss counters for the block memo (kept separate from
/// [`crate::mcts::evalcache::CacheStats`]: `sim` sits below `mcts` in
/// the layering and the two caches count different things — programs
/// there, block contributions here).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockStats {
    pub hits: u64,
    pub misses: u64,
}

impl BlockStats {
    /// Fraction of lookups served from the memo; 0.0 when never consulted.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Bounded memo over per-block latency contributions plus whole-baseline
/// latencies. Once a map is full, new values are computed and returned
/// but not inserted (same degradation contract as
/// [`crate::mcts::evalcache::EvalCache`]).
#[derive(Clone, Debug)]
pub struct BlockCache {
    block: HashMap<u64, f64>,
    baseline: HashMap<u64, f64>,
    stats: BlockStats,
    max_entries: usize,
}

impl Default for BlockCache {
    fn default() -> Self {
        BlockCache::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl BlockCache {
    /// Default per-map entry bound. An entry is a u64 key plus an f64
    /// value (~16 B payload before table overhead), so a full block map
    /// costs a few MB — sized for many searches' worth of distinct
    /// (workload, block, schedule) triples on one thread.
    pub const DEFAULT_CAPACITY: usize = 1 << 18;

    pub fn new() -> BlockCache {
        BlockCache::default()
    }

    pub fn with_capacity(max_entries: usize) -> BlockCache {
        BlockCache {
            block: HashMap::new(),
            baseline: HashMap::new(),
            stats: BlockStats::default(),
            max_entries,
        }
    }

    /// Entries currently held (both maps).
    pub fn len(&self) -> usize {
        self.block.len() + self.baseline.len()
    }

    pub fn is_empty(&self) -> bool {
        self.block.is_empty() && self.baseline.is_empty()
    }

    pub fn stats(&self) -> BlockStats {
        self.stats
    }

    /// Zero the hit/miss counters (entries are kept).
    pub fn reset_stats(&mut self) {
        self.stats = BlockStats::default();
    }

    /// Drop every entry (and the counters) — the memo rebuilds lazily.
    pub fn clear(&mut self) {
        self.block.clear();
        self.baseline.clear();
        self.stats = BlockStats::default();
    }

    /// Per-block contribution for `key`, computing (and caching) via `f`
    /// on a miss; also reports whether the memo served it (`true` = hit,
    /// `f` never ran) so the caller's debug differential check can target
    /// exactly the served path.
    pub fn block_or_served(&mut self, key: u64, f: impl FnOnce() -> f64) -> (f64, bool) {
        if let Some(&v) = self.block.get(&key) {
            self.stats.hits += 1;
            return (v, true);
        }
        self.stats.misses += 1;
        let v = f();
        if self.block.len() < self.max_entries {
            self.block.insert(key, v);
        }
        (v, false)
    }

    /// Memoized whole-baseline latency lookup (counts a hit). `None`
    /// means the caller must compute and [`BlockCache::baseline_insert`]
    /// it (split into get/insert rather than a closure so the compute
    /// path can re-enter the thread-local memo without double-borrowing).
    pub fn baseline_get(&mut self, key: u64) -> Option<f64> {
        let v = self.baseline.get(&key).copied();
        match v {
            Some(_) => self.stats.hits += 1,
            None => self.stats.misses += 1,
        }
        v
    }

    /// Store a computed baseline latency (miss already counted by
    /// [`BlockCache::baseline_get`]); respects the entry bound.
    pub fn baseline_insert(&mut self, key: u64, v: f64) {
        if self.baseline.len() < self.max_entries {
            self.baseline.insert(key, v);
        }
    }
}

thread_local! {
    static THREAD_CACHE: RefCell<BlockCache> = RefCell::new(BlockCache::default());
}

/// Run `f` with this thread's block memo. The borrow is held for the
/// duration of `f`; `f` must not re-enter `with_thread` (the simulator's
/// usage computes block contributions inside the borrow, and those never
/// touch the memo).
pub fn with_thread<R>(f: impl FnOnce(&mut BlockCache) -> R) -> R {
    THREAD_CACHE.with(|c| f(&mut c.borrow_mut()))
}

/// This thread's memo counters (e.g. for benches and the CI smoke gate).
pub fn thread_stats() -> BlockStats {
    with_thread(|c| c.stats())
}

/// Zero this thread's counters, keeping the entries warm.
pub fn reset_thread_stats() {
    with_thread(BlockCache::reset_stats)
}

/// Drop this thread's memo entirely (tests; never required for
/// correctness).
pub fn clear_thread() {
    with_thread(BlockCache::clear)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_memo_serves_and_charges_once() {
        let mut c = BlockCache::new();
        let (v, served) = c.block_or_served(7, || 1.25);
        assert!((v, served) == (1.25, false));
        let (v, served) = c.block_or_served(7, || unreachable!("cached"));
        assert!((v, served) == (1.25, true));
        assert_eq!(c.stats(), BlockStats { hits: 1, misses: 1 });
        assert!((c.stats().hit_rate() - 0.5).abs() < 1e-12);
        c.reset_stats();
        assert_eq!(c.stats(), BlockStats::default());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn capacity_zero_computes_without_insert() {
        let mut c = BlockCache::with_capacity(0);
        assert_eq!(c.block_or_served(1, || 2.0), (2.0, false));
        assert_eq!(c.block_or_served(1, || 2.0), (2.0, false), "never cached");
        assert!(c.is_empty());
        assert_eq!(c.stats().misses, 2);
    }

    #[test]
    fn baseline_get_insert_roundtrip() {
        let mut c = BlockCache::new();
        assert_eq!(c.baseline_get(9), None);
        c.baseline_insert(9, 0.5);
        assert_eq!(c.baseline_get(9), Some(0.5));
        assert_eq!(c.stats(), BlockStats { hits: 1, misses: 1 });
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.baseline_get(9), None);
    }

    #[test]
    fn thread_cache_persists_across_calls() {
        clear_thread();
        with_thread(|c| {
            c.block_or_served(42, || 3.0);
        });
        let (v, served) = with_thread(|c| c.block_or_served(42, || unreachable!()));
        assert!(served);
        assert_eq!(v, 3.0);
        assert_eq!(thread_stats(), BlockStats { hits: 1, misses: 1 });
        reset_thread_stats();
        assert_eq!(thread_stats(), BlockStats::default());
        clear_thread();
    }
}

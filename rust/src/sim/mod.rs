//! Hardware performance simulators — the measured-latency substitute for
//! the paper's NVIDIA 2080 Ti and Intel i9 testbeds (DESIGN.md
//! §Substitutions).
//!
//! `Simulator::latency` is the ground-truth objective f(p): deterministic,
//! schedule-sensitive, with realistic interactions (tiling ↔ cache fit,
//! vectorize ↔ contiguity, parallel ↔ core/SM saturation, fusion ↔
//! intermediate traffic). The learned cost model ([`crate::costmodel`]) is
//! trained against it exactly as TVM's XGBoost model is trained against
//! hardware runs.
//!
//! Evaluation is **incremental**: per-block contributions are memoized in
//! a thread-local [`blockcache`] keyed by ([`Simulator::instance_key`] —
//! a *precomputed* fold of the target and spec — then workload
//! fingerprint, block index, block-schedule fingerprint), so evaluating a
//! schedule that shares blocks with anything previously evaluated on this
//! thread re-simulates only the blocks that changed — bit-identical to
//! the full recompute ([`Simulator::latency_full`]), asserted per-hit in
//! debug builds and by the differential property test.

pub mod blockcache;
pub mod footprint;
pub mod cpu;
pub mod gpu;

use crate::schedule::Schedule;
use crate::tir::Workload;
use crate::util::fnv::{fnv_f64, fnv_i64, fnv_str, fnv_u64, FNV_OFFSET};
use std::sync::Arc;

/// Evaluation target.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Target {
    Cpu,
    Gpu,
}

impl Target {
    pub fn is_gpu(self) -> bool {
        matches!(self, Target::Gpu)
    }
    pub fn name(self) -> &'static str {
        match self {
            Target::Cpu => "CPU",
            Target::Gpu => "GPU",
        }
    }
}

/// A configured simulator for one target.
///
/// # Memo-key contract
///
/// The fields are private so that [`Simulator::instance_key`] — the FNV
/// fold of the target and every field of its active spec — can be
/// **precomputed once** at construction and kept coherent: every block
/// memo and baseline lookup starts from the stored key instead of
/// re-folding ten spec fields per lookup. Spec edits must go through
/// [`Simulator::edit_cpu`] / [`Simulator::edit_gpu`], which recompute the
/// key, so an edited spec can never be served another configuration's
/// memoized values.
#[derive(Clone, Debug)]
pub struct Simulator {
    target: Target,
    cpu: cpu::CpuSpec,
    gpu: gpu::GpuSpec,
    /// Precomputed memo-key prefix: see [`Simulator::instance_key`].
    instance_key: u64,
}

impl Simulator {
    pub fn new(target: Target) -> Simulator {
        let cpu = cpu::CpuSpec::default();
        let gpu = gpu::GpuSpec::default();
        let instance_key = compute_instance_key(target, &cpu, &gpu);
        Simulator {
            target,
            cpu,
            gpu,
            instance_key,
        }
    }

    /// The evaluation target this simulator models.
    pub fn target(&self) -> Target {
        self.target
    }

    /// The CPU spec (read-only; edit through [`Simulator::edit_cpu`]).
    pub fn cpu(&self) -> &cpu::CpuSpec {
        &self.cpu
    }

    /// The GPU spec (read-only; edit through [`Simulator::edit_gpu`]).
    pub fn gpu(&self) -> &gpu::GpuSpec {
        &self.gpu
    }

    /// Edit the CPU spec and recompute the precomputed memo key, keeping
    /// the key ↔ configuration invariant.
    pub fn edit_cpu(&mut self, f: impl FnOnce(&mut cpu::CpuSpec)) {
        f(&mut self.cpu);
        self.instance_key = compute_instance_key(self.target, &self.cpu, &self.gpu);
    }

    /// Edit the GPU spec and recompute the precomputed memo key, keeping
    /// the key ↔ configuration invariant.
    pub fn edit_gpu(&mut self, f: impl FnOnce(&mut gpu::GpuSpec)) {
        f(&mut self.gpu);
        self.instance_key = compute_instance_key(self.target, &self.cpu, &self.gpu);
    }

    /// One block's complete latency contribution (seconds): the target's
    /// per-block model plus the `compute_at` fusion credit. This is the
    /// unit the block memo caches, and it is a **pure function** of
    /// (spec, workload, block index, that block's [`BlockSched`]) — the
    /// fusion credit charges the producer's own `compute_at` depth
    /// against its own write traffic, never another block's state. Keep
    /// it that way: any new cross-block input must be folded into
    /// [`Simulator::latency`]'s memo key or it will serve stale values
    /// (the debug differential assert and
    /// `prop_incremental_latency_is_bit_identical_to_full` guard this).
    ///
    /// [`BlockSched`]: crate::schedule::BlockSched
    fn block_contrib(&self, s: &Schedule, b: usize) -> f64 {
        let (mut lat, traffic) = match self.target {
            Target::Cpu => cpu::block_latency(&self.cpu, s, b),
            Target::Gpu => gpu::block_latency(&self.gpu, s, b),
        };
        // fusion: producer computed inside its consumer's tile —
        // its output never round-trips DRAM. Model as removing the
        // write's DRAM time (and the consumer re-read, folded in the
        // same credit), when the tile actually fits (depth > 0).
        if let Some(depth) = s.blocks[b].compute_at {
            if depth > 0 {
                let bw = match self.target {
                    Target::Cpu => self.cpu.dram_gbs,
                    Target::Gpu => self.gpu.dram_gbs,
                } * 1e9;
                let saved = 2.0 * traffic.write_dram / bw;
                // fusing too deep re-computes the producer: small tax
                let tax = 1.0 + 0.03 * depth as f64;
                lat = ((lat - saved).max(lat * 0.15)) * tax;
            }
        }
        lat
    }

    /// Precomputed FNV fold of the target and every field of its active
    /// spec — the memo-key prefix that makes block-memo entries a
    /// function of the simulator's *configuration*, not its identity
    /// (equal specs share entries; an edited spec can never be served
    /// another spec's values). Computed **once** at construction (and on
    /// every [`Simulator::edit_cpu`] / [`Simulator::edit_gpu`]), so a
    /// block lookup is one `fnv_u64` fold of the workload fingerprint
    /// plus per-block folds — not a ten-field spec re-hash per call.
    pub fn instance_key(&self) -> u64 {
        self.instance_key
    }

    /// End-to-end latency (seconds) of a scheduled workload: per-block
    /// contributions summed (see [`Simulator::block_contrib`]).
    ///
    /// **Incremental**: each block's contribution is served from the
    /// thread-local [`blockcache`] when its key — the precomputed
    /// [`Simulator::instance_key`] folded with (workload fingerprint,
    /// block index, block-schedule fingerprint) — was
    /// evaluated before on this thread, so the common search pattern
    /// (child schedule = parent with one mutated block) re-simulates only
    /// the mutated block. Observationally transparent: values are pure
    /// functions of their keys and are summed in the same order as
    /// [`Simulator::latency_full`], so the result is **bit-identical**
    /// whether the memo is cold, warm, full, or absent (debug builds
    /// re-derive every served block and assert bit equality).
    pub fn latency(&self, s: &Schedule) -> f64 {
        let h0 = fnv_u64(self.instance_key, s.workload.fingerprint());
        blockcache::with_thread(|bc| {
            let mut total = 0.0;
            for b in 0..s.workload.blocks.len() {
                let key = fnv_u64(fnv_u64(h0, b as u64), s.blocks[b].fingerprint());
                let (lat, served) = bc.block_or_served(key, || self.block_contrib(s, b));
                if served {
                    debug_assert_eq!(
                        lat.to_bits(),
                        self.block_contrib(s, b).to_bits(),
                        "block memo served a value that differs from recomputation \
                         (workload {}, block {b}) — a cross-block dependency is \
                         missing from the memo key",
                        s.workload.name
                    );
                }
                total += lat;
            }
            total
        })
    }

    /// Reference full recompute of [`Simulator::latency`]: simulates
    /// every block, consults no memo. The differential checks (debug
    /// asserts, property tests, benches) compare against this; it is
    /// also the useful entry point when benchmarking the simulator
    /// itself.
    pub fn latency_full(&self, s: &Schedule) -> f64 {
        let mut total = 0.0;
        for b in 0..s.workload.blocks.len() {
            total += self.block_contrib(s, b);
        }
        total
    }

    /// Latency of the unoptimized initial schedule of `w`, memoized per
    /// (spec, workload fingerprint) in the thread-local [`blockcache`] —
    /// [`Simulator::speedup`] used to rebuild `Schedule::initial` and
    /// re-simulate it on every call.
    pub fn baseline_latency(&self, w: &Arc<Workload>) -> f64 {
        let key = fnv_u64(self.instance_key, w.fingerprint());
        // lookup and compute are separate borrows: computing the baseline
        // re-enters the thread-local memo through `latency`
        if let Some(v) = blockcache::with_thread(|bc| bc.baseline_get(key)) {
            debug_assert_eq!(
                v.to_bits(),
                self.latency_full(&Schedule::initial(Arc::clone(w))).to_bits(),
                "baseline memo served a value that differs from recomputation \
                 (workload {})",
                w.name
            );
            return v;
        }
        let v = self.latency(&Schedule::initial(Arc::clone(w)));
        blockcache::with_thread(|bc| bc.baseline_insert(key, v));
        v
    }

    /// Speedup of `s` over the unoptimized initial schedule. The baseline
    /// is served from the memo ([`Simulator::baseline_latency`]) instead
    /// of being rebuilt and re-simulated per call.
    pub fn speedup(&self, s: &Schedule) -> f64 {
        self.baseline_latency(&s.workload) / self.latency(s)
    }

    /// Achieved GFLOP/s of a schedule.
    pub fn gflops(&self, s: &Schedule) -> f64 {
        s.workload.flops() / self.latency(s) / 1e9
    }

    /// Roofline peak for this target (GFLOP/s).
    pub fn peak_gflops(&self) -> f64 {
        match self.target {
            Target::Cpu => self.cpu.peak_gflops(),
            Target::Gpu => self.gpu.peak_gflops(),
        }
    }
}

/// The instance-key fold itself: FNV-1a over the target name and every
/// field of the active spec, in declaration order. This is the single
/// definition of the configuration prefix of every block-memo and
/// baseline key; [`Simulator`] caches its result so the hot path never
/// re-runs it.
fn compute_instance_key(target: Target, cpu: &cpu::CpuSpec, gpu: &gpu::GpuSpec) -> u64 {
    let mut h = fnv_str(FNV_OFFSET, target.name());
    match target {
        Target::Cpu => {
            h = fnv_i64(h, cpu.cores);
            h = fnv_f64(h, cpu.freq_ghz);
            h = fnv_i64(h, cpu.simd_lanes);
            h = fnv_f64(h, cpu.fma_ports);
            h = fnv_f64(h, cpu.l1_bytes);
            h = fnv_f64(h, cpu.l2_bytes);
            h = fnv_f64(h, cpu.dram_gbs);
            h = fnv_f64(h, cpu.l2_gbs);
            h = fnv_f64(h, cpu.spawn_overhead);
        }
        Target::Gpu => {
            h = fnv_i64(h, gpu.sms);
            h = fnv_i64(h, gpu.cuda_cores_per_sm);
            h = fnv_f64(h, gpu.freq_ghz);
            h = fnv_i64(h, gpu.max_threads_per_sm);
            h = fnv_i64(h, gpu.max_threads_per_block);
            h = fnv_f64(h, gpu.smem_per_sm);
            h = fnv_f64(h, gpu.dram_gbs);
            h = fnv_f64(h, gpu.l2_bytes);
            h = fnv_f64(h, gpu.l2_gbs);
            h = fnv_f64(h, gpu.launch_overhead);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply_sequence, TransformKind};
    use crate::util::Rng;
    use crate::workloads;
    use std::sync::Arc;

    #[test]
    fn baselines_are_slow_but_finite() {
        for target in [Target::Cpu, Target::Gpu] {
            let sim = Simulator::new(target);
            for w in workloads::paper_benchmarks() {
                let s = Schedule::initial(Arc::new(w));
                let lat = sim.latency(&s);
                assert!(lat.is_finite() && lat > 0.0, "{:?}", target);
            }
        }
    }

    #[test]
    fn random_search_finds_speedups_on_all_benchmarks() {
        // sanity: the search space contains real improvements everywhere
        for target in [Target::Cpu, Target::Gpu] {
            let sim = Simulator::new(target);
            for w in workloads::paper_benchmarks() {
                let name = w.name.clone();
                let base = Schedule::initial(Arc::new(w));
                let base_lat = sim.latency(&base);
                let mut rng = Rng::new(42);
                let vocab = TransformKind::vocabulary(target.is_gpu());
                let mut best = f64::INFINITY;
                for _ in 0..60 {
                    let seq: Vec<_> = (0..4).map(|_| *rng.choice(&vocab)).collect();
                    if let Ok(s) = apply_sequence(&base, &seq, &mut rng, target.is_gpu()) {
                        best = best.min(sim.latency(&s));
                    }
                }
                let speedup = base_lat / best;
                assert!(
                    speedup > 1.2,
                    "{name} on {:?}: random search only reached {speedup:.2}x",
                    target
                );
            }
        }
    }

    #[test]
    fn fusion_helps_mlp() {
        let sim = Simulator::new(Target::Cpu);
        let w = Arc::new(workloads::mlp::llama4_mlp());
        let base = Schedule::initial(w.clone());
        let mut fused = base.clone();
        // fuse silu_mul into down_proj's tiles
        let silu = w.blocks.iter().position(|b| b.name == "silu_mul").unwrap();
        fused.block_mut(silu).compute_at = Some(1);
        assert!(sim.latency(&fused) < sim.latency(&base));
    }

    #[test]
    fn speedup_of_initial_is_one() {
        let sim = Simulator::new(Target::Cpu);
        let s = Schedule::initial(Arc::new(workloads::gemm::gemm(128, 128, 128)));
        assert!((sim.speedup(&s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_faster_than_cpu_when_tuned() {
        let cpu = Simulator::new(Target::Cpu);
        let gpu = Simulator::new(Target::Gpu);
        assert!(gpu.peak_gflops() > cpu.peak_gflops());
    }

    #[test]
    fn incremental_latency_bit_identical_to_full_under_storm() {
        // the core incremental-evaluation contract on both targets: with
        // the thread memo warming up across a transform storm, every
        // memoized evaluation equals the full recompute bit for bit
        for (target, seed) in [(Target::Cpu, 11u64), (Target::Gpu, 12)] {
            let sim = Simulator::new(target);
            let mut rng = Rng::new(seed);
            let vocab = TransformKind::vocabulary(target.is_gpu());
            let mut s = Schedule::initial(Arc::new(workloads::mlp::llama4_mlp()));
            let gpu = target.is_gpu();
            for step in 0..60 {
                if let Ok(n) = apply_sequence(&s, &[*rng.choice(&vocab)], &mut rng, gpu) {
                    s = n;
                }
                assert_eq!(
                    sim.latency(&s).to_bits(),
                    sim.latency_full(&s).to_bits(),
                    "{target:?} step {step}"
                );
            }
        }
    }

    #[test]
    fn block_memo_resimulates_only_mutated_blocks() {
        use super::blockcache;
        blockcache::clear_thread();
        let sim = Simulator::new(Target::Cpu);
        let w = Arc::new(workloads::mlp::llama4_mlp());
        let n_blocks = w.blocks.len() as u64;
        assert!(n_blocks >= 3, "need a multi-block workload");
        let base = Schedule::initial(w);
        sim.latency(&base); // cold: one miss per block
        assert_eq!(
            blockcache::thread_stats(),
            blockcache::BlockStats { hits: 0, misses: n_blocks }
        );
        let mut child = base.clone();
        child.block_mut(1).unroll = 2;
        blockcache::reset_thread_stats();
        let got = sim.latency(&child);
        // O(mutated blocks): every unchanged block served, one simulated
        assert_eq!(
            blockcache::thread_stats(),
            blockcache::BlockStats { hits: n_blocks - 1, misses: 1 }
        );
        assert_eq!(got.to_bits(), sim.latency_full(&child).to_bits());
        // re-evaluation is all hits and still bit-identical
        blockcache::reset_thread_stats();
        assert_eq!(sim.latency(&child).to_bits(), got.to_bits());
        assert_eq!(
            blockcache::thread_stats(),
            blockcache::BlockStats { hits: n_blocks, misses: 0 }
        );
        blockcache::clear_thread();
    }

    #[test]
    fn spec_edits_change_the_memo_key_not_serve_stale_values() {
        use super::blockcache;
        blockcache::clear_thread();
        let s = Schedule::initial(Arc::new(workloads::gemm::gemm(256, 256, 256)));
        let sim = Simulator::new(Target::Cpu);
        let l_default = sim.latency(&s);
        let mut slower = Simulator::new(Target::Cpu);
        slower.edit_cpu(|c| c.freq_ghz /= 2.0);
        // the edited spec folds into the key: fresh compute, not a stale hit
        let l_slow = slower.latency(&s);
        assert_ne!(l_default.to_bits(), l_slow.to_bits());
        assert_eq!(l_slow.to_bits(), slower.latency_full(&s).to_bits());
        // and two identically-configured simulators share entries
        blockcache::reset_thread_stats();
        assert_eq!(Simulator::new(Target::Cpu).latency(&s).to_bits(), l_default.to_bits());
        assert_eq!(blockcache::thread_stats().misses, 0, "equal specs share the memo");
        blockcache::clear_thread();
    }

    #[test]
    fn differently_specced_simulators_never_collide_on_instance_key() {
        // the precomputed key must separate every configuration a block
        // could be memoized under: same target with an edited spec, and
        // the two targets themselves
        let base = Simulator::new(Target::Cpu);
        let mut edited = Simulator::new(Target::Cpu);
        edited.edit_cpu(|c| c.freq_ghz /= 2.0);
        assert_ne!(base.instance_key(), edited.instance_key());
        let gpu = Simulator::new(Target::Gpu);
        let mut gpu_edited = Simulator::new(Target::Gpu);
        gpu_edited.edit_gpu(|g| g.sms += 1);
        assert_ne!(gpu.instance_key(), gpu_edited.instance_key());
        assert_ne!(base.instance_key(), gpu.instance_key());
        // editing the *inactive* spec leaves the key alone (only the
        // active spec is folded), and identical configs share a key
        let mut cpu_with_gpu_edit = Simulator::new(Target::Cpu);
        cpu_with_gpu_edit.edit_gpu(|g| g.sms += 1);
        assert_eq!(base.instance_key(), cpu_with_gpu_edit.instance_key());
        assert_eq!(base.instance_key(), Simulator::new(Target::Cpu).instance_key());
        // reverting an edit restores the original key bit for bit
        let mut round_trip = Simulator::new(Target::Cpu);
        round_trip.edit_cpu(|c| c.freq_ghz /= 2.0);
        round_trip.edit_cpu(|c| c.freq_ghz *= 2.0);
        assert_eq!(base.instance_key(), round_trip.instance_key());
    }

    #[test]
    fn baseline_memo_makes_speedup_cheap_and_stable() {
        use super::blockcache;
        blockcache::clear_thread();
        let sim = Simulator::new(Target::Cpu);
        let w = Arc::new(workloads::mlp::llama4_mlp());
        let mut tuned = Schedule::initial(w.clone());
        tuned.block_mut(0).parallel = 1;
        let a = sim.speedup(&tuned);
        // reference value: baseline recomputed from scratch
        let expect = sim.latency_full(&Schedule::initial(w.clone())) / sim.latency_full(&tuned);
        assert_eq!(a.to_bits(), expect.to_bits());
        // the repeat serves the baseline from the memo (no block misses at
        // all: baseline hit + per-block hits for `tuned`)
        blockcache::reset_thread_stats();
        let b = sim.speedup(&tuned);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(blockcache::thread_stats().misses, 0);
        blockcache::clear_thread();
    }
}

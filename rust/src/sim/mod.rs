//! Hardware performance simulators — the measured-latency substitute for
//! the paper's NVIDIA 2080 Ti and Intel i9 testbeds (DESIGN.md
//! §Substitutions).
//!
//! `Simulator::latency` is the ground-truth objective f(p): deterministic,
//! schedule-sensitive, with realistic interactions (tiling ↔ cache fit,
//! vectorize ↔ contiguity, parallel ↔ core/SM saturation, fusion ↔
//! intermediate traffic). The learned cost model ([`crate::costmodel`]) is
//! trained against it exactly as TVM's XGBoost model is trained against
//! hardware runs.

pub mod footprint;
pub mod cpu;
pub mod gpu;

use crate::schedule::Schedule;

/// Evaluation target.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Target {
    Cpu,
    Gpu,
}

impl Target {
    pub fn is_gpu(self) -> bool {
        matches!(self, Target::Gpu)
    }
    pub fn name(self) -> &'static str {
        match self {
            Target::Cpu => "CPU",
            Target::Gpu => "GPU",
        }
    }
}

/// A configured simulator for one target.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub target: Target,
    pub cpu: cpu::CpuSpec,
    pub gpu: gpu::GpuSpec,
}

impl Simulator {
    pub fn new(target: Target) -> Simulator {
        Simulator {
            target,
            cpu: cpu::CpuSpec::default(),
            gpu: gpu::GpuSpec::default(),
        }
    }

    /// End-to-end latency (seconds) of a scheduled workload: per-block
    /// latencies summed, with compute_at fusion removing the intermediate
    /// buffer's DRAM traffic between producer and consumer.
    pub fn latency(&self, s: &Schedule) -> f64 {
        let mut total = 0.0;
        for b in 0..s.workload.blocks.len() {
            let (mut lat, traffic) = match self.target {
                Target::Cpu => cpu::block_latency(&self.cpu, s, b),
                Target::Gpu => gpu::block_latency(&self.gpu, s, b),
            };
            // fusion: producer computed inside its consumer's tile —
            // its output never round-trips DRAM. Model as removing the
            // write's DRAM time (and the consumer re-read, folded in the
            // same credit), when the tile actually fits (depth > 0).
            if let Some(depth) = s.blocks[b].compute_at {
                if depth > 0 {
                    let bw = match self.target {
                        Target::Cpu => self.cpu.dram_gbs,
                        Target::Gpu => self.gpu.dram_gbs,
                    } * 1e9;
                    let saved = 2.0 * traffic.write_dram / bw;
                    // fusing too deep re-computes the producer: small tax
                    let tax = 1.0 + 0.03 * depth as f64;
                    lat = ((lat - saved).max(lat * 0.15)) * tax;
                }
            }
            total += lat;
        }
        total
    }

    /// Speedup of `s` over the unoptimized initial schedule.
    pub fn speedup(&self, s: &Schedule) -> f64 {
        let base = Schedule::initial(s.workload.clone());
        self.latency(&base) / self.latency(s)
    }

    /// Achieved GFLOP/s of a schedule.
    pub fn gflops(&self, s: &Schedule) -> f64 {
        s.workload.flops() / self.latency(s) / 1e9
    }

    /// Roofline peak for this target (GFLOP/s).
    pub fn peak_gflops(&self) -> f64 {
        match self.target {
            Target::Cpu => self.cpu.peak_gflops(),
            Target::Gpu => self.gpu.peak_gflops(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::transforms::{apply_sequence, TransformKind};
    use crate::util::Rng;
    use crate::workloads;
    use std::sync::Arc;

    #[test]
    fn baselines_are_slow_but_finite() {
        for target in [Target::Cpu, Target::Gpu] {
            let sim = Simulator::new(target);
            for w in workloads::paper_benchmarks() {
                let s = Schedule::initial(Arc::new(w));
                let lat = sim.latency(&s);
                assert!(lat.is_finite() && lat > 0.0, "{:?}", target);
            }
        }
    }

    #[test]
    fn random_search_finds_speedups_on_all_benchmarks() {
        // sanity: the search space contains real improvements everywhere
        for target in [Target::Cpu, Target::Gpu] {
            let sim = Simulator::new(target);
            for w in workloads::paper_benchmarks() {
                let name = w.name.clone();
                let base = Schedule::initial(Arc::new(w));
                let base_lat = sim.latency(&base);
                let mut rng = Rng::new(42);
                let vocab = TransformKind::vocabulary(target.is_gpu());
                let mut best = f64::INFINITY;
                for _ in 0..60 {
                    let seq: Vec<_> = (0..4).map(|_| *rng.choice(&vocab)).collect();
                    if let Ok(s) = apply_sequence(&base, &seq, &mut rng, target.is_gpu()) {
                        best = best.min(sim.latency(&s));
                    }
                }
                let speedup = base_lat / best;
                assert!(
                    speedup > 1.2,
                    "{name} on {:?}: random search only reached {speedup:.2}x",
                    target
                );
            }
        }
    }

    #[test]
    fn fusion_helps_mlp() {
        let sim = Simulator::new(Target::Cpu);
        let w = Arc::new(workloads::mlp::llama4_mlp());
        let base = Schedule::initial(w.clone());
        let mut fused = base.clone();
        // fuse silu_mul into down_proj's tiles
        let silu = w.blocks.iter().position(|b| b.name == "silu_mul").unwrap();
        fused.block_mut(silu).compute_at = Some(1);
        assert!(sim.latency(&fused) < sim.latency(&base));
    }

    #[test]
    fn speedup_of_initial_is_one() {
        let sim = Simulator::new(Target::Cpu);
        let s = Schedule::initial(Arc::new(workloads::gemm::gemm(128, 128, 128)));
        assert!((sim.speedup(&s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_faster_than_cpu_when_tuned() {
        let cpu = Simulator::new(Target::Cpu);
        let gpu = Simulator::new(Target::Gpu);
        assert!(gpu.peak_gflops() > cpu.peak_gflops());
    }
}

//! Tile-footprint and memory-traffic analysis over a scheduled loop nest —
//! the analytical cache model both hardware simulators share (the same
//! style of analysis Ansor/MetaSchedule extract as cost-model features).
//!
//! Per-access reuse model: an access's traffic through a cache is the
//! product of the extents of (a) every loop that indexes it (distinct
//! elements) and (b) every non-indexing loop across which its inner
//! footprint does NOT fit in the cache share (the tile cannot stay
//! resident, so each iteration re-streams it). Loops whose body footprint
//! fits are free: the data is re-touched every iteration and survives.

use crate::schedule::{LoopNest, Schedule};
use crate::tir::Access;

/// Live extent of each original axis over loops at positions >= depth.
fn live_axis_extents(nest: &LoopNest, n_axes: usize, depth: usize) -> Vec<i64> {
    let mut ext = vec![1i64; n_axes];
    for l in &nest.loops[depth.min(nest.loops.len())..] {
        ext[l.axis] *= l.extent;
    }
    ext
}

/// Footprint (elements) of one access at the given depth (= the distinct
/// elements it touches during one iteration of the loop at depth-1).
pub fn access_footprint(nest: &LoopNest, acc: &Access, n_axes: usize, depth: usize) -> i64 {
    let live = live_axis_extents(nest, n_axes, depth);
    acc.dim_axes
        .iter()
        .map(|dims| {
            if dims.is_empty() {
                1
            } else {
                // sliding-window dims (sum of axes): extents add (minus overlap)
                dims.iter().map(|&a| live[a]).sum::<i64>() - (dims.len() as i64 - 1)
            }
        })
        .product()
}

/// Iterations of the loops strictly outside `depth`.
pub fn outer_iterations(nest: &LoopNest, depth: usize) -> i64 {
    nest.loops[..depth.min(nest.loops.len())]
        .iter()
        .map(|l| l.extent)
        .product()
}

/// Traffic (bytes) of one access through a cache of per-access share
/// `cap_share` bytes.
pub fn access_traffic(
    nest: &LoopNest,
    acc: &Access,
    n_axes: usize,
    elem_bytes: f64,
    cap_share: f64,
) -> f64 {
    let n = nest.loops.len();
    let mut traffic = elem_bytes;
    for d in (0..n).rev() {
        let l = &nest.loops[d];
        if acc.uses_axis(l.axis) {
            traffic *= l.extent as f64;
        } else {
            // body footprint of one iteration of loop d
            let fp = access_footprint(nest, acc, n_axes, d + 1) as f64 * elem_bytes;
            if fp > cap_share {
                traffic *= l.extent as f64;
            }
        }
    }
    // raw upper bound: one touch per loop iteration
    let raw: f64 = nest.loops.iter().map(|l| l.extent as f64).product::<f64>() * elem_bytes;
    traffic.min(raw)
}

/// Result of the traffic analysis for one block.
#[derive(Clone, Debug, Default)]
pub struct Traffic {
    /// Bytes moved from DRAM (all accesses).
    pub dram_bytes: f64,
    /// Bytes moved through the mid-level cache (L2 / shared-memory feed).
    pub l2_bytes: f64,
    /// Footprint (bytes) of the innermost two-loop tile (register / VMEM
    /// pressure proxy).
    pub inner_tile_bytes: f64,
    /// Per-read-access DRAM bytes (order matches `BlockDef::reads`).
    pub per_access_dram: Vec<f64>,
    /// DRAM bytes attributable to the write access.
    pub write_dram: f64,
}

/// Analyze one block's scheduled nest against a two-level cache hierarchy
/// (`l1_capacity` and `l2_capacity` in bytes).
///
/// Memo-key contract (audited): reads the block's own definition, the
/// nest materialized from its own schedule state, and buffer dtypes —
/// never another block's schedule. See
/// [`crate::sim::cpu::block_latency`] for the full contract the
/// incremental evaluator relies on.
pub fn analyze(
    s: &Schedule,
    block: usize,
    nest: &LoopNest,
    l1_capacity: f64,
    l2_capacity: f64,
) -> Traffic {
    let blk = &s.workload.blocks[block];
    let n_axes = blk.axes.len();
    let n_acc = blk.reads.len() + blk.writes.len();
    let l1_share = l1_capacity / n_acc as f64;
    let l2_share = l2_capacity / n_acc as f64;

    let mut t = Traffic::default();
    // inner tile: footprint of the innermost two loops, all accesses
    let inner_depth = nest.loops.len().saturating_sub(2);
    for acc in blk.reads.iter().chain(blk.writes.iter()) {
        let eb = s.workload.buffers[acc.buffer].dtype.bytes() as f64;
        t.inner_tile_bytes += access_footprint(nest, acc, n_axes, inner_depth) as f64 * eb;
    }

    for acc in &blk.reads {
        let eb = s.workload.buffers[acc.buffer].dtype.bytes() as f64;
        let dram = access_traffic(nest, acc, n_axes, eb, l2_share);
        let l2 = access_traffic(nest, acc, n_axes, eb, l1_share).max(dram);
        t.dram_bytes += dram;
        t.l2_bytes += l2;
        t.per_access_dram.push(dram);
    }
    for acc in &blk.writes {
        let eb = s.workload.buffers[acc.buffer].dtype.bytes() as f64;
        let dram = access_traffic(nest, acc, n_axes, eb, l2_share);
        let l2 = access_traffic(nest, acc, n_axes, eb, l1_share).max(dram);
        t.dram_bytes += dram;
        t.l2_bytes += l2;
        t.write_dram += dram;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::workloads::gemm;
    use std::sync::Arc;

    const L1: f64 = 32.0 * 1024.0;
    const L2: f64 = 2.0 * 1024.0 * 1024.0;

    fn base(n: i64) -> Schedule {
        Schedule::initial(Arc::new(gemm::gemm(n, n, n)))
    }

    #[test]
    fn untiled_big_gemm_restreams_b() {
        let s = base(2048);
        let nest = s.loop_nest(0, false);
        let t = analyze(&s, 0, &nest, L1, L2);
        let buffers = (3.0 * 2048.0 * 2048.0 * 4.0) as f64;
        // B (16MB) cannot stay resident across the i loop -> re-streamed
        assert!(t.dram_bytes > buffers * 10.0, "dram {}", t.dram_bytes);
    }

    #[test]
    fn small_gemm_fits_and_streams_once() {
        let s = base(256);
        let nest = s.loop_nest(0, false);
        let t = analyze(&s, 0, &nest, L1, L2);
        let buffers = (3 * 256 * 256 * 4) as f64;
        // everything resident in L2: each buffer touched ~once
        assert!(
            t.dram_bytes < buffers * 1.5,
            "dram {} vs buffers {}",
            t.dram_bytes,
            buffers
        );
    }

    #[test]
    fn tiling_reduces_dram_traffic() {
        let naive = base(1024);
        let nest_n = naive.loop_nest(0, false);
        let t_n = analyze(&naive, 0, &nest_n, L1, L2);

        let mut tiled = base(1024);
        tiled.block_mut(0).retile(0, vec![32, 32]);
        tiled.block_mut(0).retile(1, vec![32, 32]);
        tiled.block_mut(0).retile(2, vec![4, 256]);
        tiled.block_mut(0).order = vec![(0, 0), (1, 0), (2, 0), (0, 1), (1, 1), (2, 1)];
        tiled.validate().unwrap();
        let nest_t = tiled.loop_nest(0, false);
        let t_t = analyze(&tiled, 0, &nest_t, L1, L2);

        assert!(
            t_t.dram_bytes < t_n.dram_bytes * 0.5,
            "tiled {} vs naive {}",
            t_t.dram_bytes,
            t_n.dram_bytes
        );
    }

    #[test]
    fn traffic_floor_is_distinct_elements() {
        // with infinite cache every access moves exactly its buffer once
        let s = base(128);
        let nest = s.loop_nest(0, false);
        let t = analyze(&s, 0, &nest, 1e12, 1e12);
        let expect = (3 * 128 * 128 * 4) as f64;
        assert!((t.dram_bytes - expect).abs() < 1.0, "{}", t.dram_bytes);
    }

    #[test]
    fn sliding_window_footprint() {
        let w = crate::workloads::conv::flux_conv();
        let s = Schedule::initial(Arc::new(w));
        let nest = s.loop_nest(0, false);
        let blk = &s.workload.blocks[0];
        let fp = access_footprint(&nest, &blk.reads[0], blk.axes.len(), 0);
        assert_eq!(fp, 64 * 64 * 320);
    }

    #[test]
    fn write_traffic_tracked_separately() {
        let s = base(512);
        let nest = s.loop_nest(0, false);
        let t = analyze(&s, 0, &nest, L1, L2);
        assert!(t.write_dram > 0.0);
        assert_eq!(t.per_access_dram.len(), 2);
        let sum: f64 = t.per_access_dram.iter().sum::<f64>() + t.write_dram;
        assert!((sum - t.dram_bytes).abs() < 1.0);
    }
}

//! The hot-path benchmark suite as a library function, shared by the
//! `hot_paths` bench target and the `experiments perfgate` CI gate:
//! MCTS iteration components, GBT inference (scalar vs SoA-batched),
//! simulator eval (full recompute vs incremental block-memo), the
//! legality-analyzer gate (`first_deny` runs inside every `apply`),
//! featurization, schedule apply, prompt render, and the
//! allocation-light search-loop primitives (O(1) trace keys,
//! copy-on-write schedule apply/clone, iteration throughput at depth).
//!
//! [`run_suite`] takes an optional allocation probe (a `fn` reading a
//! process-wide allocation counter — the bench binary installs a
//! counting `#[global_allocator]` and passes its reader; `perfgate`
//! passes `None`). With a probe, the allocation-sensitive benches
//! (`mcts_iteration_at_depth14`, `sim_latency_incremental_*`) report
//! heap allocations per iteration in their [`Summary`], which the JSON
//! report carries as `allocs_per_iter`.

use super::{bench_fn, Summary};
use crate::costmodel::{features, CostModel};
use crate::llm::prompts;
use crate::llm::registry::paper_config;
use crate::llm::ModelSet;
use crate::mcts::evalcache::trace_key;
use crate::mcts::{Mcts, SearchConfig};
use crate::schedule::printer::print_dominant;
use crate::schedule::transforms::{apply, TransformKind};
use crate::schedule::Schedule;
use crate::sim::{Simulator, Target};
use crate::util::Rng;
use crate::workloads;
use std::sync::Arc;
use std::time::Duration;

/// Apply `n` random (applicable) transforms to `base`.
fn transformed(base: &Schedule, n: usize, seed: u64) -> Schedule {
    let mut rng = Rng::new(seed);
    let vocab = TransformKind::vocabulary(false);
    let mut s = base.clone();
    let mut applied = 0;
    while applied < n {
        if let Ok(next) = apply(&s, *rng.choice(&vocab), &mut rng, false) {
            s = next;
            applied += 1;
        }
    }
    s
}

/// Steady-state heap allocations per call of `f`, via the caller's
/// allocation-counter probe. One unprobed warm-up call absorbs lazy
/// one-time allocations (memo tables, fingerprint caches) so the number
/// reflects the loop steady state the perf contract is about.
fn allocs_per_iter(probe: Option<fn() -> u64>, iters: u64, mut f: impl FnMut()) -> Option<f64> {
    let probe = probe?;
    f();
    let before = probe();
    for _ in 0..iters {
        f();
    }
    Some((probe() - before) as f64 / iters as f64)
}

/// Run every hot-path benchmark and return the summaries in run order
/// (the caller decides where the JSON report goes). `alloc_count`, when
/// provided, must read a monotone count of heap allocations performed by
/// this thread's process — see the module docs.
pub fn run_suite(alloc_count: Option<fn() -> u64>) -> Vec<Summary> {
    let budget = Duration::from_millis(400);
    let mut all: Vec<Summary> = Vec::new();
    let w = Arc::new(workloads::attention::llama3_attention());
    let base = Schedule::initial(w.clone());
    let sim_cpu = Simulator::new(Target::Cpu);
    let sim_gpu = Simulator::new(Target::Gpu);
    let mut rng = Rng::new(1);

    // a moderately-transformed schedule (realistic hot-path input)
    let sched = transformed(&base, 12, 1);

    all.push(bench_fn("schedule_apply_tilesize", budget, || {
        let _ = apply(&sched, TransformKind::TileSize, &mut rng, false);
    }));

    // ---- static legality analyzer ------------------------------------------
    // `first_deny` runs inside every `apply` (the Deny gate), so its cost
    // lands on the search hot path; `analyze` is the full-registry sweep
    // the lint CLI / audit pay per schedule.
    all.push(bench_fn("lint_first_deny_attention", budget, || {
        std::hint::black_box(crate::analysis::first_deny(&sched, false));
    }));
    all.push(bench_fn("lint_analyze_attention", budget, || {
        std::hint::black_box(crate::analysis::analyze(&sched, false));
    }));

    // ---- allocation-light search-loop primitives ---------------------------
    // trace_key must be O(1) in trace depth: it reads the trace's cached
    // running hash and the schedule's cached fingerprint. The depth-2 /
    // depth-16 / depth-48 numbers should be flat (within noise).
    let shallow = transformed(&base, 2, 2);
    let deep16 = transformed(&base, 16, 3);
    let deep48 = transformed(&base, 48, 4);
    shallow.fingerprint(); // warm the lazy fingerprint caches so the
    deep16.fingerprint(); // bench isolates steady-state key cost
    deep48.fingerprint();
    all.push(bench_fn("trace_key_depth2", budget, || {
        std::hint::black_box(trace_key(&shallow, Target::Cpu));
    }));
    all.push(bench_fn("trace_key_depth16", budget, || {
        std::hint::black_box(trace_key(&deep16, Target::Cpu));
    }));
    all.push(bench_fn("trace_key_depth48", budget, || {
        std::hint::black_box(trace_key(&deep48, Target::Cpu));
    }));

    // copy-on-write: cloning a deep schedule copies Arcs, applying a
    // transform deep-clones only the mutated block
    all.push(bench_fn("schedule_clone_depth48", budget, || {
        std::hint::black_box(deep48.clone());
    }));
    all.push(bench_fn("schedule_apply_deep48_unroll", budget, || {
        let _ = apply(&deep48, TransformKind::Unroll, &mut rng, false);
    }));

    // the simulator itself (full recompute — `latency_full` bypasses the
    // block memo so these keep measuring per-block model cost, not cache
    // lookups)
    all.push(bench_fn("sim_latency_cpu_attention", budget, || {
        std::hint::black_box(sim_cpu.latency_full(&sched));
    }));
    all.push(bench_fn("sim_latency_gpu_attention", budget, || {
        std::hint::black_box(sim_gpu.latency_full(&sched));
    }));

    // ---- incremental block-level evaluation --------------------------------
    // llama_e2e (the fused decoder layer — the block-count-heavy scenario)
    // at trace depth ≥ 32: `sim_latency_full_*` recomputes every block per
    // call; `sim_latency_incremental_*` serves unchanged blocks from the
    // warmed thread-local memo (the steady state of the search hot loop,
    // where each candidate shares all-but-one block with an evaluated
    // ancestor). The printed speedup is the headline incremental-eval win;
    // with an allocation probe, the warm-memo path also reports its
    // allocations per evaluation (the precomputed instance key + served
    // lookups should hold it at zero).
    {
        let wl =
            Arc::new(workloads::by_name("llama_e2e").expect("llama_e2e scenario family resolves"));
        let deep_e2e = {
            let mut rng = Rng::new(7);
            let vocab = TransformKind::vocabulary(false);
            let mut s = Schedule::initial(wl.clone());
            let mut applied = 0;
            while applied < 32 {
                if let Ok(next) = apply(&s, *rng.choice(&vocab), &mut rng, false) {
                    s = next;
                    applied += 1;
                }
            }
            s
        };
        assert!(deep_e2e.trace.len() >= 32, "bench needs trace depth >= 32");
        let full = bench_fn("sim_latency_full_llama_e2e_depth32", budget, || {
            std::hint::black_box(sim_cpu.latency_full(&deep_e2e));
        });
        crate::sim::blockcache::clear_thread();
        sim_cpu.latency(&deep_e2e); // warm the memo
        let mut incr = bench_fn("sim_latency_incremental_llama_e2e_depth32", budget, || {
            std::hint::black_box(sim_cpu.latency(&deep_e2e));
        });
        incr.allocs_per_iter = allocs_per_iter(alloc_count, 256, || {
            std::hint::black_box(sim_cpu.latency(&deep_e2e));
        });
        assert_eq!(
            sim_cpu.latency(&deep_e2e).to_bits(),
            sim_cpu.latency_full(&deep_e2e).to_bits(),
            "incremental evaluation must stay bit-identical"
        );
        println!(
            "bench {:<44} speedup vs full recompute {:.2}x",
            "sim_latency_full_vs_incremental",
            full.mean_ns / incr.mean_ns
        );
        all.push(full);
        all.push(incr);
    }

    all.push(bench_fn("featurize_attention", budget, || {
        std::hint::black_box(features::featurize(&sched, Target::Cpu));
    }));

    // trained cost model inference
    let mut cm = CostModel::new(Target::Cpu, 7);
    let mut r2 = Rng::new(2);
    let vocab = TransformKind::vocabulary(false);
    for _ in 0..120 {
        let seq: Vec<_> = (0..3).map(|_| *r2.choice(&vocab)).collect();
        if let Ok(s) = crate::schedule::transforms::apply_sequence(&base, &seq, &mut r2, false) {
            cm.measure(&sim_cpu, &s);
        }
    }
    all.push(bench_fn("costmodel_predict", budget, || {
        std::hint::black_box(cm.predict_latency(&sched));
    }));

    // SoA-flattened GBT: scalar predict per row vs one chunked-lane batch
    // pass over a candidate-lane-sized batch (trees outer, lanes inner,
    // node arrays cache-hot). The batch entry reuses one FeatureMatrix +
    // output buffer across rounds — the allocation-free scoring path.
    {
        use crate::costmodel::features::FeatureMatrix;
        use crate::costmodel::gbt::{Gbt, GbtParams};
        let mut gr = Rng::new(13);
        let rows: Vec<Vec<f64>> = (0..256usize)
            .map(|i| {
                features::featurize(&transformed(&base, 2 + (i % 6), 100 + i as u64), Target::Cpu)
            })
            .collect();
        let ys: Vec<f64> = rows.iter().map(|r| r.iter().sum::<f64>().sin()).collect();
        let gbt = Gbt::fit(GbtParams::default(), &rows, &ys, &mut gr);
        let scalar = bench_fn("gbt_predict_scalar_256rows", budget, || {
            let mut acc = 0.0;
            for r in &rows {
                acc += gbt.predict(r);
            }
            std::hint::black_box(acc);
        });
        let mut m = FeatureMatrix::new();
        m.reset(features::N_FEATURES);
        for r in &rows {
            m.push_row(r);
        }
        let mut out: Vec<f64> = Vec::new();
        let batch = bench_fn("gbt_predict_batch_256rows", budget, || {
            gbt.predict_batch_into(&m, &mut out);
            std::hint::black_box(out.last().copied());
        });
        println!(
            "bench {:<44} speedup vs scalar {:.2}x",
            "gbt_predict_batch_vs_scalar",
            scalar.mean_ns / batch.mean_ns
        );
        all.push(scalar);
        all.push(batch);
    }

    // prompt rendering
    let set = ModelSet::new(paper_config(8, "gpt-5.2"));
    let ctx = prompts::PromptCtx {
        current: prompts::VariantCtx {
            code: print_dominant(&sched, false).into(),
            trace_tail: sched.trace.render_tail(8).into(),
            score: 0.42,
        },
        parent: None,
        grandparent: None,
        vocabulary: vocab.clone(),
        leaf_depth: 4,
        trials_done: 100,
        trials_budget: 300,
        model_stats: set.stat_lines(),
        local_models: [None, None, None],
    };
    all.push(bench_fn("prompt_render_regular", budget, || {
        std::hint::black_box(prompts::regular_prompt(&ctx));
    }));

    // one full MCTS iteration (selection→expansion→rollout→backprop)
    let models = ModelSet::new(paper_config(8, "gpt-5.2"));
    let cfg = SearchConfig {
        budget: usize::MAX / 2,
        seed: 3,
        checkpoints: vec![],
        ..SearchConfig::default()
    };
    let mut engine = Mcts::new(cfg, models, Simulator::new(Target::Cpu), base.clone());
    all.push(bench_fn("mcts_full_iteration", Duration::from_millis(800), || {
        engine.step();
    }));

    // iteration throughput at depth: branching=1 forces a single chain, so
    // every measured iteration selects through (and extends) a path at
    // least 14 nodes deep — the regime where deep-clone schedules and
    // O(depth) trace keys used to make each step O(depth). Timed by hand
    // rather than through bench_fn: each 8-step window stays below the
    // engine's depth cap (past it, expansions pile children onto one node
    // and per-step cost grows with iteration count), and the engine
    // rebuild between windows happens OUTSIDE the timed region so the
    // reported numbers measure iteration cost only.
    let mk_deep = || {
        let cfg = SearchConfig {
            branching: 1,
            budget: usize::MAX / 2,
            seed: 5,
            checkpoints: vec![],
            ..SearchConfig::default()
        };
        let models = ModelSet::new(paper_config(8, "gpt-5.2"));
        let mut e = Mcts::new(cfg, models, Simulator::new(Target::Cpu), base.clone());
        for _ in 0..14 {
            e.step();
        }
        e
    };
    const DEEP_WINDOW: usize = 8;
    const DEEP_ROUNDS: usize = 40;
    let mut samples_ns = Vec::with_capacity(DEEP_ROUNDS);
    for _ in 0..DEEP_ROUNDS {
        let mut deep_engine = mk_deep();
        let t = std::time::Instant::now();
        for _ in 0..DEEP_WINDOW {
            deep_engine.step();
        }
        samples_ns.push(t.elapsed().as_nanos() as f64 / DEEP_WINDOW as f64);
    }
    let mut deep_summary = Summary::from_samples(
        "mcts_iteration_at_depth14",
        &samples_ns,
        DEEP_ROUNDS * DEEP_WINDOW,
    );
    // allocation census for the same window shape (tree growth makes a
    // step inherently allocating — node, schedule, children vec — so this
    // tracks a budget rather than zero; the engine rebuild again happens
    // outside the probed region)
    deep_summary.allocs_per_iter = alloc_count.and_then(|probe| {
        let mut deep_engine = mk_deep();
        allocs_per_iter(Some(probe), DEEP_WINDOW as u64, move || {
            deep_engine.step();
        })
    });
    println!("{}", deep_summary.line());
    all.push(deep_summary);

    // ---- tree-parallel search: one search across N workers -----------------
    // `parallel_search_serial_baseline` is the serial engine (run_parallel(1)
    // delegates to run()); the `parallel_search_speedup_{2,4,8}` entries time
    // the identical configuration at 2/4/8 workers — each value is wall-clock
    // for one full search, so speedup = serial_mean / parallel_mean (also
    // printed). Deterministic per (seed, threads); thread counts explore
    // different but equally valid trees, so this measures throughput, not
    // result equivalence (the determinism tests pin that).
    let mk_par = || {
        let cfg = SearchConfig {
            budget: 64,
            seed: 11,
            checkpoints: vec![],
            ..SearchConfig::default()
        };
        let models = ModelSet::new(paper_config(4, "gpt-5.2"));
        Mcts::new(cfg, models, Simulator::new(Target::Cpu), base.clone())
    };
    const PAR_ROUNDS: usize = 3;
    let mut serial_mean_ns = 0.0f64;
    for t in [1usize, 2, 4, 8] {
        let mut par_samples_ns = Vec::with_capacity(PAR_ROUNDS);
        for _ in 0..PAR_ROUNDS {
            let engine = mk_par();
            let t0 = std::time::Instant::now();
            let r = engine.run_parallel("llama3_attention", t);
            std::hint::black_box(r.best_speedup);
            par_samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let name = if t == 1 {
            "parallel_search_serial_baseline".to_string()
        } else {
            format!("parallel_search_speedup_{t}")
        };
        let s = Summary::from_samples(&name, &par_samples_ns, PAR_ROUNDS);
        println!("{}", s.line());
        if t == 1 {
            serial_mean_ns = s.mean_ns;
        } else {
            println!(
                "bench {:<44} speedup vs serial {:.2}x",
                name,
                serial_mean_ns / s.mean_ns
            );
        }
        all.push(s);
    }

    // ---- persistent eval cache: serialization + warm-start payoff ----------
    // `cache_{save,load}_10k` time the file round-trip of a 10k-entry
    // ground-truth map (the sweep driver pays this once per process).
    // `search_warm_vs_cold` times one full fixed-seed search cold and
    // again warm-started from its own cache — the wall-clock payoff a
    // second process gets from `--cache-file` on overlapping scenarios.
    {
        use crate::mcts::evalcache::EvalCache;
        let mut big = EvalCache::new();
        for i in 0..10_000u64 {
            big.latency_or(i.wrapping_mul(0x9E37_79B9_7F4A_7C15), || {
                (i as f64).mul_add(1e-9, 1e-4)
            });
        }
        let path =
            std::env::temp_dir().join(format!("litecoop_bench_cache_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        all.push(bench_fn("cache_save_10k", budget, || {
            big.save_file(&path).expect("save cache");
        }));
        all.push(bench_fn("cache_load_10k", budget, || {
            let c = EvalCache::load_file(&path).expect("load cache");
            std::hint::black_box(c.len());
        }));
        let _ = std::fs::remove_file(&path);

        let mk_search = |cache: EvalCache| {
            let cfg = SearchConfig {
                budget: 80,
                seed: 17,
                checkpoints: vec![],
                ..SearchConfig::default()
            };
            let models = ModelSet::new(paper_config(4, "gpt-5.2"));
            Mcts::with_cache(cfg, models, Simulator::new(Target::Cpu), base.clone(), cache)
        };
        let (_, warm) = mk_search(EvalCache::new()).run_with_cache("llama3_attention");
        all.push(bench_fn("search_cold_80samples", budget, || {
            let (r, _) = mk_search(EvalCache::new()).run_with_cache("llama3_attention");
            std::hint::black_box(r.best_speedup);
        }));
        all.push(bench_fn("search_warm_80samples", budget, || {
            let (r, _) = mk_search(warm.clone()).run_with_cache("llama3_attention");
            std::hint::black_box(r.best_speedup);
        }));
    }

    all
}

//! Bench wrapper for Tables 10-12 (Appendix G): runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- llm_selection`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table10_llm_selection(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["llm_selection", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "llm_selection failed");
    });
}

//! Bench wrapper for Figure 3: runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- fig3`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("fig3_llama70b(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["fig3", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "fig3 failed");
    });
}

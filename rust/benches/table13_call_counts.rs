//! Bench wrapper for Tables 13-15 (Appendix H): runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- call_counts`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table13_call_counts(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["call_counts", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "call_counts failed");
    });
}

//! Bench wrapper for Table 1: runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- table1`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table1_cost(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["table1", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "table1 failed");
    });
}

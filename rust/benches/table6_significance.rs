//! Bench wrapper for Table 6 (Appendix E): runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- significance`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table6_significance(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["significance", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "significance failed");
    });
}

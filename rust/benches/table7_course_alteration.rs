//! Bench wrapper for Tables 7-9 (Appendix F): runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- course_alteration`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table7_course_alteration(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["course_alteration", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "course_alteration failed");
    });
}

//! Hot-path microbenchmarks (§Perf): MCTS iteration components, GBT
//! inference, simulator eval, featurization, schedule apply, prompt
//! render. Run with `cargo bench --bench hot_paths`.

use litecoop::benchutil::bench_fn;
use litecoop::costmodel::{features, CostModel};
use litecoop::llm::prompts;
use litecoop::llm::registry::paper_config;
use litecoop::llm::ModelSet;
use litecoop::mcts::{Mcts, SearchConfig};
use litecoop::schedule::printer::print_dominant;
use litecoop::schedule::transforms::{apply, TransformKind};
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::util::Rng;
use litecoop::workloads;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let w = Arc::new(workloads::attention::llama3_attention());
    let base = Schedule::initial(w.clone());
    let sim_cpu = Simulator::new(Target::Cpu);
    let sim_gpu = Simulator::new(Target::Gpu);
    let mut rng = Rng::new(1);

    // a moderately-transformed schedule (realistic hot-path input)
    let mut sched = base.clone();
    let vocab = TransformKind::vocabulary(false);
    for _ in 0..12 {
        if let Ok(n) = apply(&sched, *rng.choice(&vocab), &mut rng, false) {
            sched = n;
        }
    }

    bench_fn("schedule_apply_tilesize", budget, || {
        let _ = apply(&sched, TransformKind::TileSize, &mut rng, false);
    });

    bench_fn("sim_latency_cpu_attention", budget, || {
        std::hint::black_box(sim_cpu.latency(&sched));
    });
    bench_fn("sim_latency_gpu_attention", budget, || {
        std::hint::black_box(sim_gpu.latency(&sched));
    });

    bench_fn("featurize_attention", budget, || {
        std::hint::black_box(features::featurize(&sched, Target::Cpu));
    });

    // trained cost model inference
    let mut cm = CostModel::new(Target::Cpu, 7);
    let mut r2 = Rng::new(2);
    for _ in 0..120 {
        let seq: Vec<_> = (0..3).map(|_| *r2.choice(&vocab)).collect();
        if let Ok(s) =
            litecoop::schedule::transforms::apply_sequence(&base, &seq, &mut r2, false)
        {
            cm.measure(&sim_cpu, &s);
        }
    }
    bench_fn("costmodel_predict", budget, || {
        std::hint::black_box(cm.predict_latency(&sched));
    });

    // prompt rendering
    let set = ModelSet::new(paper_config(8, "gpt-5.2"));
    let ctx = prompts::PromptCtx {
        current: prompts::VariantCtx {
            code: print_dominant(&sched, false),
            trace_tail: sched.trace.render_tail(8),
            score: 0.42,
        },
        parent: None,
        grandparent: None,
        vocabulary: vocab.clone(),
        leaf_depth: 4,
        trials_done: 100,
        trials_budget: 300,
        model_stats: set.stat_lines(),
        local_models: [None, None, None],
    };
    bench_fn("prompt_render_regular", budget, || {
        std::hint::black_box(prompts::regular_prompt(&ctx));
    });

    // one full MCTS iteration (selection→expansion→rollout→backprop)
    let models = ModelSet::new(paper_config(8, "gpt-5.2"));
    let cfg = SearchConfig {
        budget: usize::MAX / 2,
        seed: 3,
        checkpoints: vec![],
        ..SearchConfig::default()
    };
    let mut engine = Mcts::new(cfg, models, Simulator::new(Target::Cpu), base.clone());
    bench_fn("mcts_full_iteration", Duration::from_millis(800), || {
        engine.step();
    });
}

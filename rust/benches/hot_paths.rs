//! Hot-path microbenchmarks (§Perf). The suite itself lives in the
//! library ([`litecoop::benchutil::hotpaths::run_suite`]) so the
//! `experiments perfgate` CI gate can run the identical benchmarks; this
//! target adds the one thing a library can't: a process-wide counting
//! `#[global_allocator]`, so the allocation-sensitive benches
//! (`mcts_iteration_at_depth14`, `sim_latency_incremental_*`) report
//! heap allocations per iteration. Run with
//! `cargo bench --bench hot_paths`.
//!
//! Besides the human-readable `bench ...` lines, this target writes every
//! summary to `BENCH_hotpaths.json` (machine-readable, stable layout) so
//! the perf trajectory of the hot loop is tracked across PRs; refreshing
//! the committed `BENCH_baseline.json` perf-gate baseline goes through
//! `experiments perfgate --write-baseline` instead (see the README's
//! Performance section).

use litecoop::benchutil::write_json_report;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Dependency-free counting allocator: defers all real work to
/// [`System`] and counts every allocation (alloc / realloc /
/// alloc_zeroed — frees don't allocate). A relaxed counter is exact
/// here: the probed bench regions run on this thread only.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

fn main() {
    let all = litecoop::benchutil::hotpaths::run_suite(Some(allocation_count));
    write_json_report("BENCH_hotpaths.json", "hot_paths", &all)
        .expect("write BENCH_hotpaths.json");
    println!("wrote BENCH_hotpaths.json ({} benchmarks)", all.len());
}

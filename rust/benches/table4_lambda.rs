//! Bench wrapper for Table 4/5 (Appendix D): runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- lambda`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table4_lambda(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["lambda", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "lambda failed");
    });
}

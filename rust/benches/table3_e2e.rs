//! Bench wrapper for Table 3: runs the experiment harness end-to-end at a
//! reduced budget and reports wall-clock (cargo bench target per paper
//! artifact — see DESIGN.md §Experiment-index). Full-fidelity numbers come
//! from `cargo run --release --bin experiments -- table3`.

use litecoop::benchutil::time_once;
use std::process::Command;

fn main() {
    let exe = env!("CARGO_BIN_EXE_experiments");
    time_once("table3_e2e(end-to-end, reduced budget)", || {
        let status = Command::new(exe)
            .args(["table3", "--budget", "60", "--reps", "1"])
            .status()
            .expect("spawn experiments");
        assert!(status.success(), "table3 failed");
    });
}
